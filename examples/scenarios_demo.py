"""Scenario subsystem demo: Justin vs DS2 under dynamic workloads.

Runs a ramp, a spike with a mid-flight straggler, and a diurnal cycle, and
prints the per-window controller history — target vs achieved rate, CPU
cores and memory as the policies chase the moving load.

    PYTHONPATH=src python examples/scenarios_demo.py [query]
"""
from __future__ import annotations

import sys

from repro.scenarios import SetStraggler, run_scenario


def show(result) -> None:
    s = result.summary()
    print(f"\n=== {s['query']} / {s['policy']} — steps={s['steps']} "
          f"faults={s['faults_fired']} recovered={s['recovered']}")
    print(f"{'t':>6} {'target':>10} {'achieved':>10} "
          f"{'cpu':>4} {'mem MB':>8}  config")
    for h in result.history:
        cfg = {op: pc for op, pc in h.config.items()
               if op not in ("source", "sink")}
        print(f"{h.t:6.0f} {h.target:10.0f} {h.achieved_rate:10.0f} "
              f"{h.cpu_cores:4d} {h.memory_mb:8.0f}  {cfg}")


def main() -> None:
    query = sys.argv[1] if len(sys.argv) > 1 else "q5"

    # 1. ramp: load climbs to the paper's target — scale-out staircase.
    # One episode per registered policy family: model-based ds2/justin,
    # reactive threshold, and the fixed static baseline (which shows what
    # "no autoscaler" costs under the same ramp).
    for policy in ("ds2", "justin", "threshold", "static"):
        show(run_scenario(policy, query, "ramp", windows=6))

    # 2. spike with a straggler appearing mid-spike (and recovering).
    # Target the query's stateful operator — sources ignore slowdown.
    straggler_op = {"q5": "hot_auctions", "q11": "user_sessions",
                    "q8": "window_join", "q3": "incr_join"}.get(query)
    faults = [] if straggler_op is None else \
        [SetStraggler(t=30.0, op=straggler_op, idx=0, factor=20.0,
                      duration_s=24.0)]
    show(run_scenario("justin", query, "spike", windows=6, faults=faults))

    # 3. diurnal cycle: the controller follows the day/night load curve
    show(run_scenario("justin", query, "diurnal", windows=8))


if __name__ == "__main__":
    main()
