"""Paper §5 demo: hybrid elastic scaling on Nexmark, with the Fig. 5-style
reconfiguration trace printed per decision window.

``policy`` may be any registered scaling policy (ds2, justin, static,
threshold, or your own ``@register_policy`` — see docs/policies.md).

Run:  PYTHONPATH=src python examples/nexmark_autoscale.py [query] [policy]
      (defaults: q11 justin)
"""
import sys

from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.core.policy import available_policies, make_policy
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.streaming.engine import StreamEngine

qname = sys.argv[1] if len(sys.argv) > 1 else "q11"
policy = sys.argv[2] if len(sys.argv) > 2 else "justin"
if policy not in available_policies():
    sys.exit(f"unknown policy {policy!r}; "
             f"registered: {', '.join(available_policies())}")

flow = QUERIES[qname]()
print(f"query {qname}: operators "
      f"{[(n, d.op.stateful) for n, d in flow.nodes.items()]}")
eng = StreamEngine(flow, seed=3)
cfg = ControllerConfig(policy=policy, justin=JustinParams(max_level=2))
ctl = AutoScaler(eng, TARGET_RATES[qname], cfg,
                 policy=make_policy(policy, cfg))
history = ctl.run()

print(f"\n{'t':>6} {'step':>4} {'rate':>10} {'cpu':>4} {'mem MB':>8}  config")
for row in history:
    cfg = {k: v for k, v in row.config.items() if k != "source"}
    print(f"{row.t:6.0f} {row.step:4d} {row.achieved_rate:10,.0f} "
          f"{row.cpu_cores:4d} {row.memory_mb:8,.0f}  {cfg}")
s = ctl.summary()
print(f"\nfinal: {s['achieved_rate']:,.0f}/{s['target']:,} ev/s with "
      f"{s['cpu_cores']} cores, {s['memory_mb']:,.0f} MB, "
      f"{s['steps']} reconfigurations")
