"""Quickstart: the three layers of the framework in one script.

1. train a reduced assigned-architecture for a few steps (CPU),
2. run the Justin autoscaler on a Nexmark query vs the DS2 baseline,
3. validate one Pallas kernel against its oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

print("=== 1. train a reduced arch (llama3.2-3b family) ===")
from repro.launch.train import train                      # noqa: E402

result = train("llama3.2-3b", steps=10, verbose=False)
print(f"10 steps: loss {result['first_loss']:.3f} -> "
      f"{result['final_loss']:.3f} ({result['wall_s']}s)\n")

print("=== 2. Justin vs DS2 on Nexmark q11 ===")
from repro.core.controller import AutoScaler, ControllerConfig  # noqa: E402
from repro.core.justin import JustinParams                # noqa: E402
from repro.core.policy import available_policies          # noqa: E402
from repro.data.nexmark import QUERIES, TARGET_RATES      # noqa: E402
from repro.streaming.engine import StreamEngine           # noqa: E402

print(f"(registered scaling policies: {', '.join(available_policies())})")
for policy in ("ds2", "justin"):
    eng = StreamEngine(QUERIES["q11"](), seed=3)
    ctl = AutoScaler(eng, TARGET_RATES["q11"], ControllerConfig(
        policy=policy, justin=JustinParams(max_level=2)))
    ctl.run()
    s = ctl.summary()
    print(f"{policy:6s}: steps={s['steps']} "
          f"rate={s['achieved_rate']:,.0f}/{s['target']:,} "
          f"cpu={s['cpu_cores']} cores mem={s['memory_mb']:,.0f} MB "
          f"config={ {k: v for k, v in s['config'].items() if k != 'source'} }")
print()

print("=== 3. Pallas kernel vs oracle (sorted-run probe) ===")
import jax.numpy as jnp                                   # noqa: E402
from repro.kernels.sorted_probe.ops import probe          # noqa: E402

rng = np.random.default_rng(0)
table = jnp.asarray(np.unique(rng.integers(0, 1 << 20, 4096)).astype(np.int32))
queries = jnp.asarray(rng.integers(0, 1 << 20, 512).astype(np.int32))
p1, f1 = probe(table, queries)                 # Pallas (interpret on CPU)
p2, f2 = probe(table, queries, impl="ref")     # jnp oracle
print(f"positions match: {bool((p1 == p2).all())}, "
      f"found match: {bool((f1 == f2).all())}")
