"""End-to-end training driver: the full mamba2-130m (~130M params) for a few
hundred steps on CPU with checkpointing and an injected preemption mid-run.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--quick]
(--quick trains the reduced config — seconds instead of tens of minutes.)
"""
import argparse
import tempfile

from repro.launch.train import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--quick", action="store_true")
args = ap.parse_args()

with tempfile.TemporaryDirectory() as ckpt:
    result = train(
        "mamba2-130m",
        reduced=args.quick,
        steps=args.steps,
        batch=4 if args.quick else 8,
        seq=128 if args.quick else 512,
        ckpt_dir=ckpt,
        ckpt_every=max(10, args.steps // 10),
        fail_at=args.steps // 2,          # injected preemption mid-run
        lr=3e-4,
    )
print(f"\nresult: {result}")
assert result["restarts"] == 1, "fault-tolerance path must have triggered"
print("loss improved:", result["final_loss"] < result["first_loss"])
