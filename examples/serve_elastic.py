"""JustinServe demo (beyond-paper): Algorithm 1 arbitrating LLM-serving
replica count vs per-replica prefix-cache HBM budget.

Run:  PYTHONPATH=src python examples/serve_elastic.py
"""
from repro.serve.engine import JustinServeController

TARGET_RPS = 120

for policy in ("ds2", "justin"):
    ctl = JustinServeController(TARGET_RPS, policy=policy)
    res = ctl.autoscale()
    print(f"{policy:6s}: replicas={res['replicas']} "
          f"cache-level={res['level']} busy={res['busyness']:.2f} "
          f"prefix-hit-rate={res['theta']:.2f} "
          f"hbm-cache={res['hbm_cache_gb']:.1f} GB")
    for h in ctl.history:
        print(f"    window: replicas={h['replicas']} level={h['level']} "
              f"busy={h['busyness']:.2f} theta={h['theta']:.2f} "
              f"tau={h['tau_ms']:.2f}ms")
