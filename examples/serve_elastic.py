"""JustinServe demo (beyond-paper): a registry ScalingPolicy arbitrating
LLM-serving replica count vs per-replica prefix-cache HBM budget.

Any registered policy name works (``available_policies()``); the classic
comparison is the paper's pair plus the Dhalion-style reactive baseline.

Run:  PYTHONPATH=src python examples/serve_elastic.py
"""
from repro.core.policy import available_policies
from repro.serve.engine import JustinServeController

TARGET_RPS = 120
POLICIES = [p for p in ("ds2", "justin", "threshold")
            if p in available_policies()]

for policy in POLICIES:
    ctl = JustinServeController(TARGET_RPS, policy=policy)
    res = ctl.autoscale()
    print(f"{policy:9s}: replicas={res['replicas']} "
          f"cache-level={res['level']} busy={res['busyness']:.2f} "
          f"prefix-hit-rate={res['theta']:.2f} "
          f"hbm-cache={res['hbm_cache_gb']:.1f} GB")
    for h in ctl.history:
        print(f"    window: replicas={h['replicas']} level={h['level']} "
              f"busy={h['busyness']:.2f} theta={h['theta']:.2f} "
              f"tau={h['tau_ms']:.2f}ms")
