"""Paper §3 demo: how memory allocation shapes operator capacity for
Read / Write / Update state-access patterns (Fig. 4 reproduction, subset).

Run:  PYTHONPATH=src python examples/microbench.py
"""
from benchmarks.microbench_grid import TARGETS, run_point

GRID = [(1, 128), (4, 512), (4, 1024), (8, 256), (8, 512)]

for mode in ("read", "write", "update"):
    print(f"--- {mode} (target {TARGETS[mode]:,} ev/s) ---")
    for p, mem in GRID:
        r = run_point(mode, p, mem, seconds=8)
        mark = "SUSTAINED" if r["sustained"] else "below    "
        th = f"{r['theta']:.2f}" if r["theta"] is not None else "  - "
        print(f"  ({p};{mem:5.0f}) -> {r['rate']:9,.0f} ev/s {mark} "
              f"theta={th} tau={r['tau_ms'] or 0:.3f} ms")
    print()
print("Takeaways (paper §3): reads benefit from memory; writes do not; "
      "updates need a minimum then plateau.")
