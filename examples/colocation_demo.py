"""Multi-query co-location demo: the paper's resource-efficiency headline
as an observable event.

Two copies of Nexmark q1 share one cluster whose memory budget holds both
tenants only if the first one scales the Justin way.  Run A: both tenants
DS2 — A's packaged allocation exhausts the budget and B's scale-up is
denied window after window, leaving B below its target.  Run B: tenant A
switches to Justin — same query, same target — and B's identical request
is admitted, because Justin's stateless tasks hold no managed grant.

The closing **preemption act** shows admission-aware placement v2: a
static low-priority tenant pinned at a raised storage level starves a
high-priority DS2 tenant forever under ``priority`` admission; under
``preemption`` the arbiter forces the victim's storage level down
(``AutoScaler.shrink_memory``) until the request fits, and the
high-priority tenant recovers.

    PYTHONPATH=src python examples/colocation_demo.py
    PYTHONPATH=src python examples/colocation_demo.py --admission preemption
"""
from __future__ import annotations

import argparse

from repro.core.controller import ControllerConfig
from repro.core.justin import JustinParams
from repro.core.policy import available_policies
from repro.scenarios import ADMISSION_POLICIES, Cluster, ColocatedSpec, \
    run_colocated


def show(res) -> None:
    s = res.summary()
    print(f"  cluster: {s['cluster']['cpu_slots']} slots, "
          f"{s['cluster']['memory_mb']:,.0f} MB  "
          f"(peak used: {s['peak_cpu']} slots, {s['peak_mem']:,.0f} MB)")
    for name, t in s["tenants"].items():
        slo = t["slo"]
        print(f"  {name} ({t['policy']:6s} on {t['query']}): "
              f"steps={t['steps']} denied_windows={t['denied_windows']} "
              f"preempted_windows={t['preempted_windows']} "
              f"violations={slo['violations']} "
              f"recovered={slo['recovered']} "
              f"cpu_slot_windows={slo['cpu_slot_windows']} "
              f"mb_windows={slo['mb_windows']:,.0f}")


def preemption_act(windows: int) -> None:
    """Priority starves the high-priority tenant; preemption re-shapes the
    neighbor (forced storage-level give-backs) and it recovers."""
    print("\n=== preemption act: high-priority DS2 vs a static tenant "
          "pinned at storage level 2 ===")
    cfg = ControllerConfig(decision_window_s=60.0, stabilization_s=30.0,
                           justin=JustinParams(max_level=2))
    for adm in ("priority", "preemption"):
        specs = [ColocatedSpec("ds2", "q1", name="H"),
                 ColocatedSpec("static", "q11", name="V", target=5_000,
                               config={"user_sessions": (6, 2)})]
        print(f"\n--- admission={adm} ---")
        res = run_colocated(specs, Cluster(cpu_slots=16, memory_mb=8500.0),
                            windows=windows, cfg=cfg, admission=adm)
        show(res)
    print("\nUnder priority, V's pinned grants leave H denied every "
          "window; under preemption the arbiter\nreclaims V's storage "
          "levels (2 -> 1 -> 0) and H's scale-out is admitted.")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--windows", type=int, default=5)
    ap.add_argument("--cpu-slots", type=int, default=16)
    ap.add_argument("--memory-mb", type=float, default=7000.0)
    ap.add_argument("--admission", default="priority",
                    choices=list(ADMISSION_POLICIES))
    ap.add_argument("--tenant-a", nargs="+", default=["ds2", "justin"],
                    choices=available_policies(),
                    help="policies to try as tenant A (B stays ds2); any "
                         "registered policy works")
    ap.add_argument("--no-preemption-act", action="store_true",
                    help="skip the closing preemptive-admission act")
    args = ap.parse_args()

    cfg = ControllerConfig(decision_window_s=60.0, stabilization_s=30.0,
                           justin=JustinParams(max_level=2))
    for a_policy in args.tenant_a:
        print(f"\n=== tenant A runs {a_policy}; tenant B always ds2 ===")
        cluster = Cluster(cpu_slots=args.cpu_slots,
                          memory_mb=args.memory_mb)
        res = run_colocated(
            [ColocatedSpec(a_policy, "q1", name="A"),
             ColocatedSpec("ds2", "q1", name="B")],
            cluster, windows=args.windows, cfg=cfg,
            admission=args.admission)
        show(res)
    if args.tenant_a == ["ds2", "justin"]:
        print("\nDS2's one-size-fits-all grants exhaust the shared budget "
              "and block the neighbor;\nJustin meets the same target while "
              "leaving room for B's scale-up.")
    if not args.no_preemption_act:
        preemption_act(args.windows)


if __name__ == "__main__":
    main()
