"""Distributed streaming data plane on a device mesh (subprocess-free demo).

The control plane (Justin/DS2, placement) is host-side Python — like Flink's
JobManager; this shows the DATA plane running on devices: keyed events are
hash-partitioned over the mesh with shard_map and each shard aggregates its
keys with the MXU-native window_agg kernel (one-hot matmul segment-sum, see
src/repro/kernels/window_agg/).

Run:  PYTHONPATH=src python examples/streaming_on_mesh.py
(uses 8 virtual CPU devices)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels.window_agg.ops import aggregate

N_TASKS = 8                      # operator parallelism = mesh size
N_KEYS = 256                     # keyspace (per-task segment range)

mesh = jax.make_mesh((N_TASKS,), ("tasks",))
rng = np.random.default_rng(0)

# one tick of events, already hash-partitioned to tasks (the engine's job)
events_per_task = 4096
keys = rng.integers(0, N_KEYS, (N_TASKS, events_per_task)).astype(np.int32)
vals = rng.normal(size=(N_TASKS, events_per_task, 4)).astype(np.float32)


def task_fn(k, v):
    """One task's window aggregation (runs per mesh shard)."""
    sums, counts = aggregate(k[0], v[0], N_KEYS)
    return sums[None], counts[None]


agg = jax.jit(jax.shard_map(task_fn, mesh=mesh,
                            in_specs=(P("tasks", None), P("tasks", None, None)),
                            out_specs=(P("tasks", None, None), P("tasks", None)),
                            check_vma=False))   # pallas_call returns no vma
sums, counts = agg(jnp.asarray(keys), jnp.asarray(vals))
print(f"mesh: {mesh.shape}; per-task sums {sums.shape}, counts {counts.shape}")

# verify against a host-side oracle
ref_counts = np.zeros((N_TASKS, N_KEYS))
for t in range(N_TASKS):
    ref_counts[t] = np.bincount(keys[t], minlength=N_KEYS)
assert np.allclose(np.asarray(counts), ref_counts), "count mismatch"
total = np.asarray(counts).sum()
print(f"aggregated {int(total)} events across {N_TASKS} mesh shards — OK")
