"""Fleet-scale co-location demo: a sampled tenant population on one
shared cluster, driven by the vectorized ``run_colocated``.

Where ``colocation_demo.py`` stages two hand-picked tenants, this samples
a production-shaped population — heavy-tailed rates, a stateless-heavy
query mix, staggered diurnal phases, a correlated flash crowd, a few
faults — sizes a cluster with bounded headroom, and runs every tenant's
control loop in lockstep under admission arbitration.  The printout is
the fleet operator's view: outcome counts, peak usage, the busiest
denied tenants, and simulated tenant-windows per wall-clock second.

    PYTHONPATH=src python examples/fleet_demo.py
    PYTHONPATH=src python examples/fleet_demo.py --tenants 256 --windows 30
    PYTHONPATH=src python examples/fleet_demo.py --admission preemption
"""
from __future__ import annotations

import argparse
import time

from repro.scenarios import ADMISSION_POLICIES, DRIVERS, fleet_stats, \
    run_fleet


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tenants", type=int, default=128)
    ap.add_argument("--windows", type=int, default=20)
    ap.add_argument("--admission", default="preemption",
                    choices=list(ADMISSION_POLICIES))
    ap.add_argument("--driver", default="vectorized",
                    choices=list(DRIVERS),
                    help="scalar = the reference oracle loop "
                         "(decision-identical, slower)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--migration-budget-mb", type=float, default=None)
    args = ap.parse_args()

    print(f"=== fleet: {args.tenants} tenants x {args.windows} windows, "
          f"admission={args.admission}, driver={args.driver} ===")
    t0 = time.time()
    res = run_fleet(args.tenants, args.windows, admission=args.admission,
                    seed=args.seed, driver=args.driver,
                    migration_budget_mb=args.migration_budget_mb)
    st = fleet_stats(res, time.time() - t0)

    print(f"cluster: {st['cluster_cpu_slots']} slots, "
          f"{st['cluster_memory_mb']:,.0f} MB "
          f"(peak used: {st['peak_cpu']} slots, "
          f"{st['peak_mem_mb']:,.0f} MB)")
    print(f"outcomes over {st['tenant_windows']:,} tenant-windows: "
          f"denied={st['denied_tenant_windows']} "
          f"deferred={st['deferred_tenant_windows']} "
          f"preempted={st['preempted_tenant_windows']} "
          f"policy_steps={st['policy_steps']}")
    contended = sorted((t for t in res.tenants
                        if t.denials or t.preemptions),
                       key=lambda t: -(len(t.denials)
                                       + len(t.preemptions)))
    for t in contended[:8]:
        print(f"  {t.name} ({t.spec.policy:9s} on {t.spec.query}): "
              f"denied={len(t.denials)} deferred={len(t.deferrals)} "
              f"preempted={len(t.preemptions)} "
              f"recovered={t.slo().recovered}")
    if not contended:
        print("  (no contention at this scale — try more windows)")
    print(f"throughput: {st['tenant_windows_per_s']:,.0f} simulated "
          f"tenant-windows/s ({st['seconds']:.1f}s wall)")


if __name__ == "__main__":
    main()
