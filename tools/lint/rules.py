"""The reprolint rule panel: repo-specific invariants as AST checks.

Four families (docs/static-analysis.md has the user-facing table):

* **D — determinism.**  Simulation, policy, scenario, state and
  arbitration code must be a pure function of (seed, inputs): no unseeded
  RNGs, no wall clock, no unstable sorts deciding order among ties, no
  iteration over sets feeding ordering-sensitive logic.  These are the
  invariants behind the byte-identical golden traces and the
  scalar-vs-vectorized fleet oracle.
* **F — float accounting.**  Resource footprints (MB) are accumulated
  floats; comparing them bare reproduces the ``Cluster.fits`` phantom-
  denial bug PR 6 fixed.  All MB comparisons go through the blessed
  epsilon helpers (``repro.core.units``) or carry an explicit ``_EPS``
  term; O(1)-incremental budget counters must be audited in the function
  that mutates them.
* **R — registry discipline.**  Policies are constructed through
  ``@register_policy``/``make_policy`` (never ``cfg.policy`` string
  dispatch), stores through ``make_store`` (never direct ``LSMStore``
  construction), and ``HistoryRow``\\ s are immutable once appended except
  in the two blessed driver modules.  Golden-trace-critical modules
  import no nondeterminism sources at all.
* **U — units.**  MB, CPU slots and seconds must not cross call
  boundaries: a parameter named ``*_mb`` fed an argument named ``*_s``
  (or ``slots``/``cores``) is flagged, using parameter-name conventions
  collected from the linted tree itself.

Every rule has a known-bad and known-good fixture under
``tools/lint/fixtures/`` (the CI self-check and ``tests/test_lint.py``
both run them).
"""
from __future__ import annotations

import ast
import re

from tools.lint.core import (FileUnit, Finding, Rule, dotted, identifiers,
                             register_rule, terminal_name)

# The default scope for determinism rules: everything that feeds the
# simulation's decision traces.  models/, kernels/, launch/, train/ and
# configs/ are jax-side code whose randomness is explicitly keyed and
# whose wall-clock use is benchmarking, not simulation.
SIM_SCOPE = ("src/repro/streaming/", "src/repro/core/",
             "src/repro/scenarios/", "src/repro/state/",
             "src/repro/migration/", "src/repro/serve/",
             "src/repro/data/")

# Accounting code where MB quantities are budget-compared.
ACCOUNTING_SCOPE = ("src/repro/core/", "src/repro/scenarios/",
                    "src/repro/migration/", "src/repro/serve/")

# Modules whose decisions the four golden traces pin byte-for-byte.
GOLDEN_MODULES = (
    "src/repro/streaming/engine.py",
    "src/repro/streaming/operators.py",
    "src/repro/streaming/events.py",
    "src/repro/streaming/graph.py",
    "src/repro/core/controller.py",
    "src/repro/core/policy.py",
    "src/repro/core/justin.py",
    "src/repro/core/ds2.py",
    "src/repro/core/placement.py",
    "src/repro/state/lsm.py",
    "src/repro/data/nexmark.py",
)

# Modules allowed to mutate HistoryRow after append: the controller owns
# the rows; the co-location drivers back-fill admission outcomes on the
# window that produced them.
HISTORY_OWNERS = ("src/repro/core/controller.py",
                  "src/repro/scenarios/cluster.py")


def _is_np_random(chain: tuple[str, ...]) -> bool:
    return len(chain) >= 2 and chain[0] in ("np", "numpy") \
        and chain[1] == "random"


# ---------------------------------------------------------------------------
# D — determinism
# ---------------------------------------------------------------------------

@register_rule
class UnseededRandom(Rule):
    """Unseeded or global-state RNG in simulation code."""
    id = "D101"
    title = "unseeded / global-state RNG in sim code"
    scope = SIM_SCOPE

    # stdlib `random` module-level functions share one global, implicitly
    # seeded generator; any use in sim code is a determinism leak
    _RANDOM_FNS = {"random", "randint", "randrange", "choice", "choices",
                   "shuffle", "sample", "uniform", "gauss", "normalvariate",
                   "betavariate", "expovariate", "seed", "getrandbits"}
    # numpy legacy global-state API (np.random.<fn> other than default_rng
    # and the Generator/Random types)
    _NP_GLOBAL_FNS = {"rand", "randn", "randint", "random", "random_sample",
                      "choice", "shuffle", "permutation", "seed", "uniform",
                      "normal", "lognormal", "poisson", "exponential"}

    def visit(self, unit: FileUnit) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(unit.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = dotted(node.func)
            if not chain:
                continue
            if _is_np_random(chain) and chain[-1] == "default_rng" \
                    and not node.args and not node.keywords:
                out.append(unit.finding(
                    self, node,
                    "np.random.default_rng() without a seed — sim code "
                    "must derive every stream from an explicit seed"))
            elif _is_np_random(chain) and len(chain) == 3 \
                    and chain[-1] in self._NP_GLOBAL_FNS:
                out.append(unit.finding(
                    self, node,
                    f"numpy global-state RNG np.random.{chain[-1]}(...) — "
                    f"use a seeded np.random.default_rng(seed) generator"))
            elif chain == ("random", "Random") and not node.args \
                    and not node.keywords:
                out.append(unit.finding(
                    self, node,
                    "random.Random() without a seed — pass an explicit "
                    "seed"))
            elif len(chain) == 2 and chain[0] == "random" \
                    and chain[1] in self._RANDOM_FNS:
                out.append(unit.finding(
                    self, node,
                    f"stdlib global RNG random.{chain[1]}(...) — use a "
                    f"seeded random.Random(seed) instance"))
        return out


@register_rule
class WallClock(Rule):
    """Wall-clock reads inside engine/controller/scenario paths."""
    id = "D102"
    title = "wall clock in sim code"
    scope = SIM_SCOPE

    _BANNED = {("time", "time"), ("time", "time_ns"),
               ("time", "perf_counter"), ("time", "perf_counter_ns"),
               ("time", "monotonic"), ("time", "monotonic_ns"),
               ("datetime", "now"), ("datetime", "utcnow"),
               ("datetime", "today"), ("date", "today")}

    def visit(self, unit: FileUnit) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(unit.tree):
            if isinstance(node, ast.Call):
                chain = dotted(node.func)
                if chain and chain[-2:] in self._BANNED:
                    out.append(unit.finding(
                        self, node,
                        f"wall-clock read {'.'.join(chain)}(...) in sim "
                        f"code — simulated time lives on the engine "
                        f"(engine.now); wall-clock belongs in benchmarks"))
        return out


@register_rule
class UnstableArgsort(Rule):
    """np.argsort without kind="stable" deciding order in sim code."""
    id = "D103"
    title = "non-stable argsort in arbitration/sim code"
    scope = SIM_SCOPE

    _STABLE_KINDS = {"stable", "mergesort"}

    def visit(self, unit: FileUnit) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(unit.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = dotted(node.func)
            if not chain or chain[-1] != "argsort":
                continue
            kind = next((kw.value for kw in node.keywords
                         if kw.arg == "kind"), None)
            if kind is None:
                out.append(unit.finding(
                    self, node,
                    "argsort without kind=\"stable\" — tie order depends "
                    "on the sort algorithm (quicksort diverges from stable "
                    "order at >=17 tied elements); arbitration and "
                    "partitioning must rank ties deterministically"))
            elif not (isinstance(kind, ast.Constant)
                      and kind.value in self._STABLE_KINDS):
                out.append(unit.finding(
                    self, node,
                    "argsort with a non-stable kind= — use "
                    "kind=\"stable\""))
        return out


@register_rule
class SetIteration(Rule):
    """Iterating a set (or materializing one into a sequence) feeds
    ordering-sensitive logic with hash order."""
    id = "D104"
    title = "set iteration feeding ordering-sensitive logic"
    scope = SIM_SCOPE

    def _is_set_expr(self, node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            chain = dotted(node.func)
            return chain in (("set",), ("frozenset",))
        return False

    def visit(self, unit: FileUnit) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(unit.tree):
            if isinstance(node, ast.For) and self._is_set_expr(node.iter):
                out.append(unit.finding(
                    self, node.iter,
                    "for-loop over a set: iteration order is hash order — "
                    "sort it (sorted(...)) or keep a dict/list"))
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp)):
                for gen in node.generators:
                    if self._is_set_expr(gen.iter):
                        out.append(unit.finding(
                            self, gen.iter,
                            "comprehension over a set: iteration order is "
                            "hash order — sort it or keep a dict/list"))
            elif isinstance(node, ast.Call):
                chain = dotted(node.func)
                if chain in (("list",), ("tuple",), ("enumerate",)) \
                        and node.args and self._is_set_expr(node.args[0]):
                    out.append(unit.finding(
                        self, node,
                        f"{chain[0]}() over a set materializes hash order "
                        f"— use sorted(...)"))
        return out


# ---------------------------------------------------------------------------
# F — float accounting
# ---------------------------------------------------------------------------

_MEM_TOKEN = re.compile(r"(?:^|_)(?:mb|mem|memory|payload)(?:$|_)|_mb$")


def _memish(name: str) -> bool:
    return bool(_MEM_TOKEN.search(name.lower()))


def _side_is_memish(node: ast.AST) -> bool:
    return any(_memish(i) for i in identifiers(node))


def _is_zero_const(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) \
        and isinstance(node.value, (int, float)) and node.value == 0


@register_rule
class BareFloatComparison(Rule):
    """Bare ==/<=/</>=/> between MB footprints outside the blessed epsilon
    helpers — the Cluster.fits phantom-denial class (PR 6)."""
    id = "F201"
    title = "bare float comparison on MB footprints"
    scope = ACCOUNTING_SCOPE

    _CMP = (ast.Eq, ast.NotEq, ast.Lt, ast.LtE, ast.Gt, ast.GtE)

    def visit(self, unit: FileUnit) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(unit.tree):
            if not isinstance(node, ast.Compare):
                continue
            if not any(isinstance(op, self._CMP) for op in node.ops):
                continue
            # blessed: an epsilon term anywhere in the comparison
            if any("eps" in i.lower() for i in identifiers(node)):
                continue
            sides = [node.left, *node.comparators]
            memish = [_side_is_memish(s) for s in sides]
            if not any(memish):
                continue
            # `x_mb > 0` / `x_mb == 0` emptiness checks are drift-safe
            if len(sides) == 2 and any(
                    _is_zero_const(s) for m, s in zip(memish, sides)
                    if not m):
                continue
            # int-typed sides (len(), counts) don't drift; skip when every
            # mem-ish side is wrapped in len()/int()
            if all(isinstance(s, ast.Call)
                   and dotted(s.func) in (("len",), ("int",))
                   for m, s in zip(memish, sides) if m):
                continue
            out.append(unit.finding(
                self, node,
                "bare comparison on an MB footprint — accumulated float "
                "attribution drifts; use repro.core.units (mem_fits/"
                "mem_exceeds/mem_close) or an explicit _EPS term"))
        return out


@register_rule
class UnauditedCounterUpdate(Rule):
    """In-place += / -= on an O(1)-incremental budget counter in a
    function with no audit (assert or _recount) — the invariant that
    keeps the incremental totals honest against the dict sums."""
    id = "F202"
    title = "unaudited in-place budget-counter update"
    scope = ("src/repro/core/", "src/repro/scenarios/")

    _COUNTER = re.compile(r"(?:^|_)(?:cpu|mem|slots)_(?:total|in_use)$")
    _AUDIT_CALLS = {"_recount", "refresh", "audit"}

    def _audited(self, fn: ast.AST) -> bool:
        for n in ast.walk(fn):
            if isinstance(n, ast.Assert):
                return True
            if isinstance(n, ast.Call):
                t = terminal_name(n.func)
                if t in self._AUDIT_CALLS:
                    return True
        return False

    def visit(self, unit: FileUnit) -> list[Finding]:
        out: list[Finding] = []
        for fn in ast.walk(unit.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            hits = [n for n in ast.walk(fn)
                    if isinstance(n, ast.AugAssign)
                    and isinstance(n.op, (ast.Add, ast.Sub))
                    and (t := terminal_name(n.target)) is not None
                    and self._COUNTER.search(t)]
            if hits and not self._audited(fn):
                for n in hits:
                    out.append(unit.finding(
                        self, n,
                        f"in-place update of budget counter "
                        f"'{terminal_name(n.target)}' in "
                        f"{fn.name}() with no audit — pair O(1) counter "
                        f"maintenance with an assert against the budget "
                        f"or a _recount()"))
        return out


# ---------------------------------------------------------------------------
# R — registry discipline
# ---------------------------------------------------------------------------

@register_rule
class PolicyStringDispatch(Rule):
    """String dispatch on a `.policy` attribute — the pre-PR-3 pattern the
    registry replaced (behavior forks silently for unregistered names)."""
    id = "R301"
    title = "string dispatch on cfg.policy"

    def _policy_attr(self, node: ast.AST) -> bool:
        return isinstance(node, ast.Attribute) and node.attr == "policy"

    def _str_const(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return True
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return all(self._str_const(e) for e in node.elts)
        return False

    def visit(self, unit: FileUnit) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(unit.tree):
            if not isinstance(node, ast.Compare):
                continue
            sides = [node.left, *node.comparators]
            if any(self._policy_attr(s) for s in sides) \
                    and any(self._str_const(s) for s in sides):
                out.append(unit.finding(
                    self, node,
                    "string dispatch on a .policy name — construct the "
                    "policy via make_policy(...) and dispatch on the "
                    "instance (isinstance / protocol hooks), or register "
                    "a policy subclass"))
        return out


@register_rule
class DirectStoreConstruction(Rule):
    """Direct LSMStore/LegacyLSMStore construction outside repro.state —
    bypassing make_store breaks the A/B store-impl switch the
    differential harness relies on."""
    id = "R302"
    title = "direct store construction bypassing make_store"
    exempt = ("src/repro/state/",)

    _STORES = {"LSMStore", "LegacyLSMStore"}

    def visit(self, unit: FileUnit) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(unit.tree):
            if isinstance(node, ast.Call):
                t = terminal_name(node.func)
                if t in self._STORES:
                    out.append(unit.finding(
                        self, node,
                        f"direct {t}(...) construction — build stores via "
                        f"repro.state.lsm.make_store so set_store_impl "
                        f"(the legacy/columnar A/B switch) keeps working"))
        return out


@register_rule
class UnregisteredPolicy(Rule):
    """A ScalingPolicy subclass without @register_policy is invisible to
    every --policy flag, the grid, and the co-location driver."""
    id = "R303"
    title = "ScalingPolicy subclass not registered"

    def visit(self, unit: FileUnit) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(unit.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not any(terminal_name(b) == "ScalingPolicy"
                       for b in node.bases):
                continue
            registered = False
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                if terminal_name(target) == "register_policy":
                    registered = True
            if not registered:
                out.append(unit.finding(
                    self, node,
                    f"policy class {node.name} subclasses ScalingPolicy "
                    f"but is not @register_policy(...)-decorated — it is "
                    f"unreachable from every --policy flag and driver"))
        return out


@register_rule
class HistoryRowMutation(Rule):
    """Mutating HistoryRow fields after append outside the blessed owner
    modules — downstream SLO metrics treat histories as immutable."""
    id = "R304"
    title = "HistoryRow mutated after append"
    exempt = HISTORY_OWNERS

    def _history_subscript(self, node: ast.AST) -> bool:
        return isinstance(node, ast.Subscript) \
            and terminal_name(node.value) == "history"

    def _scan_block(self, unit: FileUnit, block: ast.AST,
                    out: list[Finding]) -> None:
        aliases: set[str] = set()
        for node in ast.walk(block):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not block:
                continue
            if isinstance(node, ast.Assign):
                # row = xxx.history[...]
                if self._history_subscript(node.value):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            aliases.add(t.id)
                targets = node.targets
            elif isinstance(node, ast.AugAssign):
                targets = [node.target]
            else:
                continue
            for t in targets:
                if isinstance(t, ast.Attribute) and (
                        self._history_subscript(t.value)
                        or (isinstance(t.value, ast.Name)
                            and t.value.id in aliases)):
                    out.append(unit.finding(
                        self, t,
                        f"assignment to HistoryRow field '{t.attr}' after "
                        f"append — rows are immutable outside the "
                        f"controller/cluster drivers; derive metrics "
                        f"instead of patching history"))

    def visit(self, unit: FileUnit) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(unit.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_block(unit, node, out)
        return out


@register_rule
class GoldenImportBan(Rule):
    """Golden-trace-critical modules must not even import nondeterminism
    sources — the standing-notes invariant, machine-checked."""
    id = "R305"
    title = "banned import in a golden-trace-critical module"
    scope = GOLDEN_MODULES

    _BANNED = {"random", "time", "datetime", "uuid", "secrets"}

    def visit(self, unit: FileUnit) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(unit.tree):
            names: list[str] = []
            if isinstance(node, ast.Import):
                names = [a.name.split(".")[0] for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                names = [node.module.split(".")[0]]
            for name in names:
                if name in self._BANNED:
                    out.append(unit.finding(
                        self, node,
                        f"golden-trace-critical module imports {name!r} — "
                        f"the four golden traces pin this module's "
                        f"decisions byte-for-byte; nondeterminism sources "
                        f"are banned here outright (see "
                        f"docs/golden-traces.md)"))
        return out


# ---------------------------------------------------------------------------
# U — units
# ---------------------------------------------------------------------------

MB, SLOTS, SECONDS = "MB", "slots", "seconds"

_SEC_PARTS = {"s", "sec", "secs", "seconds", "duration", "downtime"}
_SLOT_PARTS = {"slots", "slot", "cores", "core", "cpus", "parallelism"}
_MB_PARTS = {"mb", "mem", "memory", "payload"}


def unit_hint(name: str | None) -> str | None:
    """The unit a parameter/argument name conventionally carries, or None
    when the convention is silent (``rate``, ``n``, ``factor``...)."""
    if not name:
        return None
    parts = name.lower().split("_")
    if parts[-1] in ("factor", "frac", "fraction", "share", "ratio"):
        return None                    # dimensionless multipliers
    if any(p in _MB_PARTS for p in parts):
        return MB
    if parts[-1] in _SEC_PARTS or any(p in ("duration", "downtime", "seconds")
                                      for p in parts):
        return SECONDS
    if any(p in _SLOT_PARTS for p in parts) or parts[-1] == "cpu" \
            or parts[0] == "cpu":
        return SLOTS
    return None


@register_rule
class UnitMixing(Rule):
    """MB / slots / seconds crossing a call boundary: an argument whose
    name conventionally carries one unit bound to a parameter that
    conventionally carries another."""
    id = "U401"
    title = "MB/slots/seconds unit mixing at a call site"
    severity = "warning"
    scope = ("src/repro/core/", "src/repro/scenarios/",
             "src/repro/migration/")

    def __init__(self) -> None:
        self._sigs: dict[str, tuple[list[str], bool]] = {}

    def prepare(self, units) -> None:
        # collect (params, is_method) per function name across the linted
        # tree; a name defined twice with different param lists is dropped
        # (ambiguous — stay conservative)
        sigs: dict[str, tuple[list[str], bool] | None] = {}
        for unit in units:
            for node in ast.walk(unit.tree):
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                params = [a.arg for a in node.args.args]
                is_method = bool(params) and params[0] in ("self", "cls")
                if is_method:
                    params = params[1:]
                prev = sigs.get(node.name)
                if node.name in sigs and (prev is None
                                          or prev[0] != params):
                    sigs[node.name] = None
                else:
                    sigs[node.name] = (params, is_method)
        self._sigs = {k: v for k, v in sigs.items() if v is not None}

    def _check(self, unit: FileUnit, call: ast.Call, param: str,
               arg: ast.AST, out: list[Finding]) -> None:
        want = unit_hint(param)
        got = unit_hint(terminal_name(arg))
        if want and got and want != got:
            out.append(unit.finding(
                self, arg,
                f"argument '{terminal_name(arg)}' ({got}) bound to "
                f"parameter '{param}' ({want}) — MB, CPU slots and "
                f"seconds must not cross a call boundary unconverted"))

    def visit(self, unit: FileUnit) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(unit.tree):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if kw.arg is not None:
                    self._check(unit, node, kw.arg, kw.value, out)
            fname = terminal_name(node.func)
            sig = self._sigs.get(fname) if fname else None
            if sig is not None:
                params, _is_method = sig
                for param, arg in zip(params, node.args):
                    if isinstance(arg, (ast.Name, ast.Attribute)):
                        self._check(unit, node, param, arg, out)
        return out
