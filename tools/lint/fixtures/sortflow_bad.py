"""Known-bad transitive-sort fixture (no ``# as:`` — the fixture path
counts as sim scope AND jax-side at once, the cross-file convention).
``rank_raw`` is D103's per-file catch; the two call sites reaching it
are what only T502's call-graph sweep can see: tie order at the caller
silently depends on the callee's sort algorithm."""
import numpy as np


def rank_raw(xs):
    return np.argsort(xs)                            # expect: D103


def _shuffle_rank(xs):
    return rank_raw(xs)                              # expect: T502


def arbitrate(xs):
    return _shuffle_rank(xs)                         # expect: T502
