# as: src/repro/serve/registry_bad.py
"""Known-bad registry-discipline fixture: string dispatch on .policy,
direct store construction, an unregistered policy, history patching."""
from repro.core.policy import ScalingPolicy
from repro.state.lsm import LSMStore


def build(cfg, capacity_mb):
    if cfg.policy == "justin":                       # expect: R301
        mode = "hybrid"
    elif cfg.policy in ("ds2", "static"):            # expect: R301
        mode = "cpu-only"
    store = LSMStore(capacity_mb)                    # expect: R302
    return mode, store


class ShadowPolicy(ScalingPolicy):                   # expect: R303
    def decide(self, window):
        return None


def patch_history(run):
    run.history[-1].admitted = True                  # expect: R304
    row = run.history[-1]
    row.downtime_s = 0.0                             # expect: R304
