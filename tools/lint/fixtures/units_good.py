# as: src/repro/migration/units_good.py
"""Known-good units fixture: units agree across the call boundary, and
dimensionless names (factors/ratios) carry no unit at all."""


def schedule_move(task, downtime_s, cpu_slots):
    return task, downtime_s, cpu_slots


def plan(task, pause_s, n_cores, rate_factor):
    moved = schedule_move(task, pause_s, n_cores)
    scaled = schedule_move(task, downtime_s=pause_s,
                          cpu_slots=n_cores)
    return moved, scaled, rate_factor
