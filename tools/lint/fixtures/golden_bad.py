# as: src/repro/core/justin.py
"""Known-bad golden-module fixture: the pretend path is one of the
golden-trace-critical modules, where nondeterminism imports are banned
outright (R305) — even unused ones."""
import random                                        # expect: R305
import time                                          # expect: R305
from datetime import datetime                        # expect: R305

import numpy as np


def jitter(xs):
    return np.asarray(xs)
