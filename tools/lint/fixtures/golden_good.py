# as: src/repro/streaming/engine.py
"""Known-good golden-module fixture: numpy and repro imports are fine in
golden-trace-critical modules; stable sorts pass D103."""
import numpy as np

from repro.core.units import mem_fits


def level_rank(levels):
    return np.argsort(levels, kind="stable")


def fits(used_mb, pool_mb):
    return mem_fits(used_mb, pool_mb)
