# as: src/repro/state/bw_bad.py
"""Known-bad bit-width fixture: packed-key arithmetic with NO static
proof.  ``pack_unguarded`` bounds neither field; ``pack_overflow``
proves the low field (mod) but leaves the shifted rank count unbounded,
so the int64 can overflow; ``radix_cast`` narrows an unbounded sort key
to uint16."""
import numpy as np

_SHIFT = np.int64(45)


def pack_unguarded(ranks, keys):
    return (ranks << _SHIFT) | keys                  # expect: B601


def pack_overflow(n, keys):
    keys = keys % np.int64(1 << 45)
    ranks = np.arange(n)
    return (ranks << _SHIFT) + keys                  # expect: B601


def radix_cast(part):
    return np.argsort(part.astype(np.uint16), kind="stable")  # expect: B601
