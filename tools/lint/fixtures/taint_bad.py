# as: src/repro/streaming/events.py
"""Known-bad taint fixture: the pretend path is golden-trace-critical,
and wall-clock nondeterminism reaches it TRANSITIVELY — the helpers the
per-file rules flag directly (R305 import ban, D102 call ban) leak into
``_stamp``/``emit`` through call edges only T501's reachability proof
can see."""
import time                                          # expect: R305


def _now_wall():
    return time.time()                               # expect: D102


def _stamp(batch):
    return batch, _now_wall()                        # expect: T501


def emit(batch):
    return _stamp(batch)                             # expect: T501
