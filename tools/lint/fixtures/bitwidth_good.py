# as: src/repro/state/bw_good.py
"""Known-good bit-width fixture: the same packing shapes as bw_bad, but
with the guards B601's abstract interpretation accepts as proofs —
an assert bounding the rank count, an early-return range check on the
(sorted) key array, and a modulus bounding the radix-cast sort key."""
import numpy as np

_SHIFT = np.int64(45)
_LIM = np.int64(1) << _SHIFT


def pack_guarded(srcs, keys):
    n = len(srcs)
    assert n < (1 << 18)
    if len(keys) and (keys[0] < 0 or keys[-1] >= _LIM):
        raise ValueError("key outside the 45-bit band")
    ranks = np.arange(n)
    return (ranks << _SHIFT) + keys


def radix_cast(part, p):
    assert p <= (1 << 16)
    part = part % p
    return np.argsort(part.astype(np.uint16), kind="stable")
