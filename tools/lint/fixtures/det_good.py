# as: src/repro/core/det_good.py
"""Known-good determinism fixture: seeded streams, simulated time, stable
sorts, sorted set iteration — nothing fires."""
import numpy as np


def pick_tasks(tasks, ids, seed, engine):
    rng = np.random.default_rng(seed)
    noise = rng.normal(0.0, 1.0)
    now = engine.now
    order = np.argsort([t.load for t in tasks], kind="stable")
    for tid in sorted({1, 2, 3}):
        tasks.append(tid)
    picked = [t for t in sorted(set(ids))]
    return rng, noise, now, order, picked
