# as: src/repro/models/scope_out.py
"""Out-of-scope fixture: the pretend path (models/) is jax-side code,
outside every D/F scope — wall clock, unseeded RNGs and unstable sorts
are benchmarking concerns there, not determinism leaks."""
import time

import numpy as np


def benchmark(f, xs):
    t0 = time.time()
    rng = np.random.default_rng()
    order = np.argsort(xs)
    return f(xs), time.time() - t0, rng, order
