# as: src/repro/scenarios/acct_good.py
"""Known-good float-accounting fixture: the blessed epsilon helpers (or
an explicit eps term) and audited counter maintenance."""
from repro.core.units import MB_EPS, mem_fits


class Pool:
    def fits(self, used_mem, budget_mb):
        return mem_fits(used_mem, budget_mb)

    def grew(self, mem_new, mem_cur, eps=MB_EPS):
        return mem_new > mem_cur + eps

    def empty(self, used_mem):
        return used_mem == 0                         # zero checks are safe

    def reserve(self, tenant, mem_mb):
        self._mem_total += mem_mb
        self._cpu_total += 1
        assert self._mem_total <= self.budget_mb + MB_EPS
