# as: src/repro/data/nexmark.py
"""Known-good taint fixture: randomness in a golden module is fine when
the generator is explicitly SEEDED and threaded through the call chain —
every function stays a pure function of (seed, inputs), so neither D101
nor T501 fires."""
import numpy as np


def make_rng(seed):
    return np.random.default_rng(seed)


def _draw(rng, n):
    return rng.integers(0, 10, size=n)


def sample(seed, n):
    return _draw(make_rng(seed), n)
