# as: src/repro/migration/units_bad.py
"""Known-bad units fixture: MB crossing a seconds-typed call boundary,
caught by parameter-name conventions (keyword and positional binding)."""


def schedule_move(task, downtime_s, cpu_slots):
    return task, downtime_s, cpu_slots


def plan(task, shard_mb, n_cores):
    moved = schedule_move(task, shard_mb, n_cores)   # expect: U401
    retry = schedule_move(task, downtime_s=shard_mb,  # expect: U401
                          cpu_slots=n_cores)
    return moved, retry
