# as: src/repro/serve/registry_good.py
"""Known-good registry-discipline fixture: registry construction paths
and read-only history access."""
from repro.core.policy import ScalingPolicy, make_policy, register_policy
from repro.state.lsm import make_store


def build(cfg, capacity_mb):
    policy = make_policy(cfg.policy)
    store = make_store(capacity_mb)
    return policy, store


@register_policy("shadow")
class ShadowPolicy(ScalingPolicy):
    def decide(self, window):
        return None


def read_history(run):
    latest = run.history[-1]
    return latest.admitted
