# as: src/repro/state/fx_bad.py
"""Known-bad aliasing fixture: the PR 4 ``items()`` bug class.  Public
methods hand out views of live internal arrays (directly, through a
private helper, and as a slice), and a history row freezes a reference
instead of a copy — every later in-place update rewrites what the
caller/auditor already holds."""
import numpy as np


class Store:
    def __init__(self, n):
        self._keys = np.arange(n)
        self._vals = np.zeros(n)
        self.history = []

    def _live_pair(self):
        return self._keys, self._vals

    def items(self):
        return self._live_pair()                     # expect: A701

    def tail(self, k):
        return self._vals[-k:]                       # expect: A701

    def log_state(self, now):
        self.history.append((now, self._vals))       # expect: A701
