# as: src/repro/scenarios/acct_bad.py
"""Known-bad float-accounting fixture: bare MB comparisons and unaudited
incremental budget counters (the Cluster.fits phantom-denial class)."""


class Pool:
    def fits(self, used_mem, budget_mb):
        return used_mem <= budget_mb                 # expect: F201

    def grew(self, mem_new, mem_cur):
        return mem_new > mem_cur                     # expect: F201

    def reserve(self, tenant, mem_mb):
        self._mem_total += mem_mb                    # expect: F202
        self._cpu_total += 1                         # expect: F202
