# as: src/repro/state/fx_good.py
"""Known-good aliasing fixture: the same surface as fx_bad, but every
value crossing the public boundary (or frozen into a history row) is
laundered through ``.copy()`` / ``np.array``, which the escape analysis
recognizes as allocation."""
import numpy as np


class Store:
    def __init__(self, n):
        self._keys = np.arange(n)
        self._vals = np.zeros(n)
        self.history = []

    def items(self):
        return self._keys.copy(), np.array(self._vals)

    def tail(self, k):
        return self._vals[-k:].copy()

    def log_state(self, now):
        self.history.append((now, self._vals.copy()))
