# as: src/repro/core/det_bad.py
"""Known-bad determinism fixture: every D-rule fires where annotated."""
import random
import time
from datetime import datetime

import numpy as np


def pick_tasks(tasks, ids):
    rng = np.random.default_rng()                    # expect: D101
    noise = np.random.normal(0.0, 1.0)               # expect: D101
    jitter = random.random()                         # expect: D101
    t0 = time.time()                                 # expect: D102
    stamp = datetime.now()                           # expect: D102
    order = np.argsort([t.load for t in tasks])      # expect: D103
    order2 = np.argsort(ids, kind="quicksort")       # expect: D103
    for tid in {1, 2, 3}:                            # expect: D104
        tasks.append(tid)
    picked = [t for t in set(ids)]                   # expect: D104
    listed = list({4, 5})                            # expect: D104
    return rng, noise, jitter, t0, stamp, order, order2, picked, listed
