# as: src/repro/core/suppression.py
"""Suppression fixture: inline ignores silence findings (and are counted
in the run summary, so they can't hide silently)."""
import numpy as np


def arbitrary_rank(xs):
    return np.argsort(xs)  # reprolint: ignore[D103]


def any_rule(xs):
    return np.argsort(xs)  # reprolint: ignore
