"""reprolint core: AST-based invariant linting for the repro codebase.

The repo's headline guarantees are *exactness* invariants — byte-identical
golden traces, scalar-vs-vectorized fleet oracles, bit-for-bit repack
reconciliation — and the nastiest shipped bugs (``Cluster.fits`` float-drift
phantom denials, the LSM stale-snapshot aliasing) were classes of error a
repo-aware static pass can flag before review.  This module is the
framework; the rule panel lives in :mod:`tools.lint.rules`:

* :class:`Rule` — one invariant check.  A rule declares an id
  (``D103``, ``F201``, ...), a severity, and a path *scope* (repo-relative
  prefixes it applies to) or *exemption* list; ``visit(ctx)`` walks the
  file's AST and yields :class:`Finding`\\ s.
* :class:`Finding` — (rule, path, line, col, message).  Its baseline
  ``key`` is ``rule:path:stripped-source-line`` — resilient to line
  renumbering, so unrelated edits don't churn the committed baseline.
* **Baseline** — a committed JSON multiset of finding keys
  (``tools/lint/baseline.json``) grandfathers findings that are real but
  deliberately not fixed (e.g. the frozen ``state/legacy.py`` store, which
  is the A/B baseline and must never be edited).  ``--fail-on-new`` fails
  only on findings whose key is NOT in the baseline.
* **Suppression** — ``# reprolint: ignore[D103]`` on the offending line
  silences that rule there (bare ``# reprolint: ignore`` silences all);
  suppressions are counted and reported so they can't hide silently.

Rules apply their path scope only to files under ``src/repro/`` — any
other path (test snippets, the self-check fixtures) gets the full panel,
with :func:`lint_source` accepting a *pretend* path so fixtures can also
exercise the scoping logic itself.
"""
from __future__ import annotations

import ast
import json
import os
import re
from collections import Counter
from dataclasses import dataclass, field

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
DEFAULT_BASELINE = os.path.join(REPO, "tools", "lint", "baseline.json")
DEFAULT_PATHS = (os.path.join("src", "repro"),)

_SUPPRESS = re.compile(r"#\s*reprolint:\s*ignore(?:\[([A-Z0-9,\s]+)\])?")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a file:line."""
    rule: str
    severity: str            # "error" | "warning"
    path: str                # repo-relative, forward slashes
    line: int                # 1-based
    col: int                 # 0-based
    message: str
    line_text: str = ""      # stripped source line (baseline key material)

    @property
    def key(self) -> str:
        """Baseline identity: stable across pure line-number shifts."""
        return f"{self.rule}:{self.path}:{self.line_text}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line, "col": self.col,
                "message": self.message, "key": self.key}

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} [{self.severity}] {self.message}")


@dataclass
class FileUnit:
    """One parsed source file handed to every applicable rule."""
    relpath: str             # repo-relative, forward slashes
    tree: ast.AST
    lines: list[str]         # source lines (1-based access via line_at)

    def in_repro(self) -> bool:
        return self.relpath.startswith("src/repro/")

    def line_at(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: "Rule", node: ast.AST, message: str) -> Finding:
        return Finding(rule=rule.id, severity=rule.severity,
                       path=self.relpath, line=node.lineno,
                       col=node.col_offset, message=message,
                       line_text=self.line_at(node.lineno))


class Rule:
    """Base class for one invariant check.

    ``scope`` / ``exempt`` are repo-relative path prefixes.  The scope is
    enforced only for files under ``src/repro/`` — fixture and test
    snippets (any other path) always get the rule, and may opt into a
    pretend path via :func:`lint_source` to exercise the scoping.
    """
    id: str = "X000"
    title: str = ""
    severity: str = "error"
    scope: tuple[str, ...] = ()      # empty == everywhere (in src/repro)
    exempt: tuple[str, ...] = ()     # always wins over scope

    def applies(self, relpath: str) -> bool:
        if any(relpath.startswith(e) for e in self.exempt):
            return False
        if not relpath.startswith("src/repro/"):
            return True              # fixtures/tests get the full panel
        return not self.scope or any(relpath.startswith(s)
                                     for s in self.scope)

    def prepare(self, units: list[FileUnit]) -> None:
        """Optional whole-program pre-pass (e.g. signature collection)."""

    def visit(self, unit: FileUnit) -> list[Finding]:
        raise NotImplementedError


_RULES: dict[str, type[Rule]] = {}


def register_rule(cls: type[Rule]) -> type[Rule]:
    """Class decorator adding a rule to the panel (id must be unique)."""
    if cls.id in _RULES:
        raise ValueError(f"duplicate rule id {cls.id}")
    _RULES[cls.id] = cls
    return cls


def all_rules(only: set[str] | None = None) -> list[Rule]:
    """Fresh instances of the registered panel, sorted by id."""
    import tools.lint.rules    # noqa: F401  (registers the per-file panel)
    import tools.lint.taint    # noqa: F401  (T501/T502)
    import tools.lint.bitwidth  # noqa: F401  (B601)
    import tools.lint.effects  # noqa: F401  (A701)
    ids = sorted(_RULES)
    if only is not None:
        unknown = only - set(ids)
        if unknown:
            raise ValueError(f"unknown rule id(s): {', '.join(sorted(unknown))}"
                             f" (have: {', '.join(ids)})")
        ids = [i for i in ids if i in only]
    return [_RULES[i]() for i in ids]


# ---------------------------------------------------------------------------
# Shared AST helpers (used by the rule panel)
# ---------------------------------------------------------------------------

def dotted(node: ast.AST) -> tuple[str, ...]:
    """The dotted-name chain of a Name/Attribute expression, outermost
    first: ``np.random.default_rng`` -> ('np', 'random', 'default_rng').
    Empty tuple for anything that is not a pure name chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def terminal_name(node: ast.AST) -> str | None:
    """The last identifier of a name chain (``self.used_mem`` ->
    ``used_mem``), or None for non-name expressions."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def identifiers(node: ast.AST) -> list[str]:
    """Every identifier mentioned anywhere inside an expression."""
    out: list[str] = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.append(n.id)
        elif isinstance(n, ast.Attribute):
            out.append(n.attr)
        elif isinstance(n, ast.keyword) and n.arg:
            out.append(n.arg)
    return out


# ---------------------------------------------------------------------------
# Program: the whole-unit set, with shared interprocedural state
# ---------------------------------------------------------------------------

class Program(list):
    """The list of :class:`FileUnit`\\ s for one lint run, carrying lazily
    built whole-program state shared by every interprocedural pass.  The
    call graph is built at most once per run no matter how many passes
    ask for it — the per-pass cost is the analysis, not the parse or the
    graph."""

    def __init__(self, units: list[FileUnit]) -> None:
        super().__init__(units)
        self._callgraph = None

    def callgraph(self):
        if self._callgraph is None:
            from tools.lint.callgraph import build_callgraph
            self._callgraph = build_callgraph(list(self))
        return self._callgraph


def get_callgraph(units: list[FileUnit]):
    """The shared call graph when ``units`` is a :class:`Program` (the
    normal case inside ``lint_units``); a fresh one otherwise."""
    if isinstance(units, Program):
        return units.callgraph()
    from tools.lint.callgraph import build_callgraph
    return build_callgraph(list(units))


# ---------------------------------------------------------------------------
# Running
# ---------------------------------------------------------------------------

def _suppressed_rules(line_text: str) -> set[str] | None:
    """None == no suppression; empty set == suppress everything."""
    m = _SUPPRESS.search(line_text)
    if m is None:
        return None
    if m.group(1) is None:
        return set()
    return {r.strip() for r in m.group(1).split(",") if r.strip()}


@dataclass
class LintResult:
    findings: list[Finding] = field(default_factory=list)
    suppressed: int = 0
    files: int = 0

    def extend(self, other: "LintResult") -> None:
        self.findings.extend(other.findings)
        self.suppressed += other.suppressed
        self.files += other.files


def _apply_suppressions(unit: FileUnit,
                        findings: list[Finding]) -> tuple[list[Finding], int]:
    kept: list[Finding] = []
    dropped = 0
    for f in findings:
        sup = _suppressed_rules(unit.line_at(f.line))
        if sup is not None and (not sup or f.rule in sup):
            dropped += 1
        else:
            kept.append(f)
    return kept, dropped


def lint_units(units: list[FileUnit],
               rules: list[Rule] | None = None,
               emit_only: set[str] | None = None) -> LintResult:
    """Run the panel.  ``emit_only`` restricts *reported* findings to the
    given relpaths while every unit still participates in whole-program
    pre-passes — the ``--changed-only`` contract: interprocedural facts
    come from the full program, the diff decides what is reported."""
    rules = rules if rules is not None else all_rules()
    if not isinstance(units, Program):
        units = Program(units)
    for rule in rules:
        rule.prepare(units)
    res = LintResult(files=len(units))
    for unit in units:
        if emit_only is not None and unit.relpath not in emit_only:
            continue
        found: list[Finding] = []
        for rule in rules:
            if rule.applies(unit.relpath):
                found.extend(rule.visit(unit))
        found.sort(key=lambda f: (f.line, f.col, f.rule))
        kept, dropped = _apply_suppressions(unit, found)
        res.findings.extend(kept)
        res.suppressed += dropped
    return res


def parse_source(src: str, relpath: str) -> FileUnit:
    tree = ast.parse(src, filename=relpath)
    return FileUnit(relpath=relpath.replace(os.sep, "/"), tree=tree,
                    lines=src.splitlines())


# (abspath) -> (mtime_ns, size, FileUnit): every rule AND every
# interprocedural pass in a process shares one parse per file — repeated
# lint entry points (CLI + self-check, test harnesses) hit the cache.
_PARSE_CACHE: dict[str, tuple[int, int, FileUnit]] = {}


def parse_file(relpath: str) -> FileUnit:
    absp = os.path.join(REPO, relpath)
    st = os.stat(absp)
    hit = _PARSE_CACHE.get(absp)
    if hit is not None and hit[0] == st.st_mtime_ns and hit[1] == st.st_size:
        return hit[2]
    with open(absp, encoding="utf-8") as f:
        unit = parse_source(f.read(), relpath)
    _PARSE_CACHE[absp] = (st.st_mtime_ns, st.st_size, unit)
    return unit


def lint_source(src: str, relpath: str,
                rules: list[Rule] | None = None) -> LintResult:
    """Lint one source string as if it lived at ``relpath`` (the fixture /
    unit-test entry point — pretend paths exercise rule scoping)."""
    return lint_units([parse_source(src, relpath)], rules)


def collect_files(paths: list[str]) -> list[str]:
    """Expand files/directories into a sorted repo-relative .py file list."""
    out: set[str] = set()
    for p in paths:
        absp = p if os.path.isabs(p) else os.path.join(REPO, p)
        if os.path.isfile(absp):
            out.add(os.path.relpath(absp, REPO))
        elif os.path.isdir(absp):
            for root, dirs, files in os.walk(absp):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        out.add(os.path.relpath(os.path.join(root, f), REPO))
        else:
            raise FileNotFoundError(f"no such lint path: {p}")
    return sorted(o.replace(os.sep, "/") for o in out)


def lint_paths(paths: list[str],
               rules: list[Rule] | None = None,
               emit_only: set[str] | None = None) -> LintResult:
    units = Program([parse_file(rel) for rel in collect_files(paths)])
    return lint_units(units, rules, emit_only=emit_only)


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------

BASELINE_VERSION = 1


def baseline_counts(findings: list[Finding]) -> dict[str, int]:
    return dict(sorted(Counter(f.key for f in findings).items()))


def write_baseline(path: str, findings: list[Finding]) -> None:
    doc = {"version": BASELINE_VERSION,
           "comment": "Grandfathered reprolint findings. Keys are "
                      "rule:path:stripped-source-line; regenerate with "
                      "`python -m tools.lint --write-baseline` and commit "
                      "deliberately (docs/static-analysis.md).",
           "findings": baseline_counts(findings)}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")


def load_baseline(path: str) -> Counter:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline version in {path}: "
                         f"{doc.get('version')!r}")
    counts = doc.get("findings", {})
    if not isinstance(counts, dict) \
            or not all(isinstance(v, int) and v > 0 for v in counts.values()):
        raise ValueError(f"malformed baseline {path}: 'findings' must map "
                         f"key -> positive count")
    return Counter(counts)


def split_new(findings: list[Finding],
              baseline: Counter) -> tuple[list[Finding], list[Finding]]:
    """(new, grandfathered): each baseline key absorbs up to its count."""
    budget = Counter(baseline)
    new: list[Finding] = []
    old: list[Finding] = []
    for f in findings:
        if budget[f.key] > 0:
            budget[f.key] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old
