"""B601 — bit-width / overflow proofs for the packed-key kernels.

The columnar LSM and the engine's partition installer both pack two
quantities into one int64 word so a single stable sort / searchsorted
can do the work of many (``(i << 45) | key`` source-major probes,
``(set << 47) | key`` fused prewarm sorts, uint16 radix casts of
partition ids).  The packing is silently wrong the moment the low field
leaves ``[0, 2**shift)`` (bands collide) or the shifted field overflows
int64 (sign flips reorder the sort) — and nothing at runtime notices,
because the result is still a perfectly sortable array of ints.

B601 walks each function with a forward abstract interpretation over an
integer interval domain and demands a *proof* at every packing site:

* ``(A << C) | B`` / ``(A << C) + B`` with a resolvable constant
  ``C >= 8``:  requires ``B ⊆ [0, 2**C)`` and ``0 <= A < 2**(63 - C)``.
* ``x.astype(<narrow dtype>)`` feeding ``np.argsort`` (directly or
  through one local assignment): requires ``x`` within the dtype's
  range — a truncating cast reorders the radix sort.

Facts come from ``assert``s, early-return ``if`` guards and guarding
conditions (``and`` conjunctions, negated ``or``s), with repo-specific
conventions documented here because the proofs lean on them:

* ``x[0]``/``x[-1]``/``x.min()``/``x.max()``/``int(...)`` comparisons
  bound ``x`` **elementwise** — the packed-key arrays are sorted by
  construction, so first/last-element guards are total.
* ``len(x) and <cond>`` guards refine ``<cond>`` on negation: an empty
  array satisfies any elementwise bound vacuously.
* A boolean assigned from a comparison (``ok = bool(a <= b)``) carries
  its refinement to a later ``if ok:`` (the condition's names are
  evaluated in the environment captured at the assignment).
* ``x % e`` with a non-constant divisor yields the *symbolic* interval
  ``[0, e - 1]``, resolved against ``e``'s bounds at the use site — how
  ``hash_partition``/``_sets`` results inherit ``p <= 2**16`` /
  ``cache_sets <= 2**15`` guards.
* Calls to in-program functions (via the shared call graph) are
  summarized by inlining their bodies one level deep with the caller's
  argument intervals — checks are only *emitted* at inline depth 0, so
  each site is reported in its own function exactly once.
"""
from __future__ import annotations

import ast

from tools.lint.core import FileUnit, Finding, Rule, dotted, get_callgraph, \
    register_rule

INT64_MAX = (1 << 63) - 1
_NARROW = {"uint8": (0, 255), "int8": (-128, 127),
           "uint16": (0, (1 << 16) - 1), "int16": (-(1 << 15), (1 << 15) - 1),
           "uint32": (0, (1 << 32) - 1), "int32": (-(1 << 31), (1 << 31) - 1)}
_IDENT_METHODS = {"ravel", "min", "max", "copy", "reshape", "flatten",
                  "astype", "sum"}          # sum only via explicit handling

Bound = object    # int | None (unbounded) | ("sym", key, delta)


def _is_sym(b) -> bool:
    return isinstance(b, tuple) and len(b) == 3 and b[0] == "sym"


class Interval:
    __slots__ = ("lo", "hi")

    def __init__(self, lo=None, hi=None):
        self.lo, self.hi = lo, hi

    def __repr__(self):
        return f"[{self.lo}, {self.hi}]"

    def copy(self) -> "Interval":
        return Interval(self.lo, self.hi)


TOP = Interval()


def const(v: int) -> Interval:
    return Interval(v, v)


def _resolve(b, env) -> int | None:
    """Concretize a bound against the current environment (symbolic
    ``[0, e-1]`` moduli pick up later guards on ``e``)."""
    if _is_sym(b):
        ref = env.get(b[1])
        if ref is None or not isinstance(ref.hi, int):
            return None
        return ref.hi + b[2]
    return b if isinstance(b, int) else None


def _hull(a: Interval, b: Interval) -> Interval:
    lo = a.lo if (isinstance(a.lo, int) and isinstance(b.lo, int)
                  and a.lo <= b.lo) else (b.lo if (
                      isinstance(a.lo, int) and isinstance(b.lo, int))
                      else None)
    hi = None
    if isinstance(a.hi, int) and isinstance(b.hi, int):
        hi = max(a.hi, b.hi)
    elif _is_sym(a.hi) and a.hi == b.hi:
        hi = a.hi
    if _is_sym(a.lo) or _is_sym(b.lo):
        lo = None
    return Interval(lo, hi)


def _key_of(node: ast.AST) -> str | None:
    """The environment key an expression's *elementwise* bounds live
    under: bare names, dotted self-attrs, and the blessed elementwise
    wrappers (``x[0]``, ``x[-1]``, ``x.min()``, ``x.max()``, ``int()``,
    ``np.int64()``)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        chain = dotted(node)
        if chain and chain[0] in ("self", "cls"):
            return ".".join(chain)
        return None
    if isinstance(node, ast.Subscript):
        sl = node.slice
        if isinstance(sl, ast.Constant) and isinstance(sl.value, int):
            return _key_of(node.value)
        if isinstance(sl, ast.UnaryOp) and isinstance(sl.op, ast.USub) \
                and isinstance(sl.operand, ast.Constant):
            return _key_of(node.value)
        return None
    if isinstance(node, ast.Call):
        chain = dotted(node.func)
        if chain and chain[-1] in ("min", "max") and len(chain) >= 2 \
                and not node.args:
            return _key_of(node.func.value)
        if chain in (("int",), ("np", "int64"), ("numpy", "int64")) \
                and len(node.args) == 1:
            return _key_of(node.args[0])
        return None
    return None


# predicate binding for a variable that is statically falsy on this path
# (``fast = False``): its ``if fast:`` branch can only be entered via a
# REAL binding from another path, so merged preds keep the other side
_NEVER = ("__never__", None)


class State:
    def __init__(self):
        self.env: dict[str, Interval] = {}
        self.lens: dict[str, str] = {}      # container name -> len() var
        self.preds: dict[str, tuple[ast.AST, dict]] = {}  # bool var -> cond

    def copy(self) -> "State":
        s = State()
        s.env = {k: v.copy() for k, v in self.env.items()}
        s.lens = dict(self.lens)
        s.preds = dict(self.preds)
        return s


class _Analyzer:
    """One function's forward walk.  ``emit`` collects findings; inline
    summaries run with ``emit=False`` (depth > 0)."""

    def __init__(self, rule: "PackedKeyBitwidth", unit: FileUnit,
                 consts: dict[str, int], cls_consts: dict[str, int],
                 call_targets, depth: int = 0):
        self.rule = rule
        self.unit = unit
        self.consts = consts            # module-level integer constants
        self.cls_consts = cls_consts    # "self.X" -> int for this class
        self.call_targets = call_targets  # id(Call) -> list[FuncNode]
        self.depth = depth
        self.returns: list[Interval] = []
        self.argsort_args: set[int] = set()   # id() of argsort arg subtrees
        self.argsort_names: set[str] = set()  # names later fed to argsort
        self.flagged: set[int] = set()        # one finding per site

    # ------------------------------------------------------------- findings
    def _flag(self, node: ast.AST, msg: str) -> None:
        if self.depth == 0 and id(node) not in self.flagged:
            self.flagged.add(id(node))
            self.rule._found.append(self.unit.finding(self.rule, node, msg))

    # ------------------------------------------------------------- evaluate
    def eval(self, node: ast.AST, st: State) -> Interval:
        if isinstance(node, ast.Constant):
            if isinstance(node.value, bool):
                return const(int(node.value))
            if isinstance(node.value, int):
                return const(node.value)
            return TOP
        if isinstance(node, ast.Name):
            if node.id in st.env:
                return st.env[node.id]
            if node.id in self.consts:
                return const(self.consts[node.id])
            return TOP
        if isinstance(node, ast.Attribute):
            chain = dotted(node)
            if chain and chain[0] in ("self", "cls"):
                key = ".".join(chain)
                if key in st.env:
                    return st.env[key]
                ckey = "self." + chain[-1]
                if ckey in self.cls_consts:
                    return const(self.cls_consts[ckey])
            return TOP
        if isinstance(node, ast.UnaryOp):
            v = self.eval(node.operand, st)
            if isinstance(node.op, ast.USub):
                lo = -v.hi if isinstance(v.hi, int) else None
                hi = -v.lo if isinstance(v.lo, int) else None
                return Interval(lo, hi)
            return TOP
        if isinstance(node, ast.Subscript):
            return self.eval(node.value, st)     # elementwise bounds
        if isinstance(node, ast.IfExp):
            a, b = st.copy(), st.copy()
            refine(a, node.test, True, self)
            refine(b, node.test, False, self)
            return _hull(self.eval(node.body, a), self.eval(node.orelse, b))
        if isinstance(node, ast.BoolOp):
            for v in node.values:
                self.eval(v, st)
            return TOP
        if isinstance(node, ast.Compare):
            self.eval(node.left, st)
            for c in node.comparators:
                self.eval(c, st)
            return Interval(0, 1)
        if isinstance(node, (ast.ListComp, ast.GeneratorExp, ast.SetComp)):
            return self._comp(node, st)
        if isinstance(node, ast.BinOp):
            return self._binop(node, st)
        if isinstance(node, ast.Call):
            return self._call(node, st)
        if isinstance(node, (ast.Tuple, ast.List)):
            out = None
            for e in node.elts:
                v = self.eval(e, st)
                out = v if out is None else _hull(out, v)
            return out or TOP
        return TOP

    def _binop(self, node: ast.BinOp, st: State) -> Interval:
        l, r = self.eval(node.left, st), self.eval(node.right, st)
        llo, lhi = _resolve(l.lo, st.env), _resolve(l.hi, st.env)
        rlo, rhi = _resolve(r.lo, st.env), _resolve(r.hi, st.env)
        if isinstance(node.op, (ast.BitOr, ast.Add)):
            self._check_packing(node, st)
        if isinstance(node.op, ast.Add):
            return Interval(
                llo + rlo if None not in (llo, rlo) else None,
                lhi + rhi if None not in (lhi, rhi) else None)
        if isinstance(node.op, ast.Sub):
            return Interval(
                llo - rhi if None not in (llo, rhi) else None,
                lhi - rlo if None not in (lhi, rlo) else None)
        if isinstance(node.op, ast.Mult):
            if None not in (llo, lhi, rlo, rhi):
                corners = [llo * rlo, llo * rhi, lhi * rlo, lhi * rhi]
                return Interval(min(corners), max(corners))
            return TOP
        if isinstance(node.op, ast.LShift):
            if rlo is not None and rlo == rhi and rlo >= 0:
                return Interval(
                    llo << rlo if llo is not None and llo >= 0 else None,
                    lhi << rlo if lhi is not None and lhi >= 0 else None)
            return TOP
        if isinstance(node.op, ast.RShift):
            if rlo is not None and rlo == rhi and rlo >= 0 \
                    and llo is not None and llo >= 0:
                return Interval(llo >> rlo,
                                lhi >> rlo if lhi is not None else None)
            return TOP
        if isinstance(node.op, ast.BitOr):
            if llo is not None and llo >= 0 and rlo is not None and rlo >= 0:
                if lhi is not None and rhi is not None:
                    bits = max(lhi.bit_length(), rhi.bit_length())
                    return Interval(max(llo, rlo), (1 << bits) - 1)
                return Interval(0, None)
            return TOP
        if isinstance(node.op, ast.BitAnd):
            for mhi in (lhi, rhi):
                if mhi is not None and mhi >= 0 and (
                        (mhi is lhi and llo == lhi) or
                        (mhi is rhi and rlo == rhi)):
                    return Interval(0, mhi)
            return TOP
        if isinstance(node.op, ast.Mod):
            if rlo is not None and rlo == rhi and rlo > 0:
                return Interval(0, rlo - 1)
            key = _key_of(node.right)
            if key is not None:
                return Interval(0, ("sym", key, -1))
            return TOP
        if isinstance(node.op, ast.FloorDiv):
            if rlo is not None and rlo == rhi and rlo > 0 \
                    and llo is not None and llo >= 0:
                return Interval(llo // rlo,
                                lhi // rlo if lhi is not None else None)
            return TOP
        if isinstance(node.op, ast.Pow):
            if None not in (llo, rlo) and llo == lhi and rlo == rhi \
                    and 0 <= rlo <= 64 and abs(llo) <= 4096:
                return const(llo ** rlo)
            return TOP
        return TOP

    def _call(self, node: ast.Call, st: State) -> Interval:
        chain = dotted(node.func)
        if chain in (("len",),) and len(node.args) == 1:
            return Interval(0, None)
        if chain in (("int",), ("bool",), ("abs",)) and len(node.args) == 1:
            v = self.eval(node.args[0], st)
            if chain == ("abs",):
                hi = _resolve(v.hi, st.env)
                lo = _resolve(v.lo, st.env)
                if hi is not None and lo is not None:
                    return Interval(0, max(abs(lo), abs(hi)))
                return Interval(0, None)
            return v
        if chain and chain[-1] in ("int64", "uint64", "int32", "uint32",
                                   "int16", "uint16", "int8", "uint8") \
                and len(chain) >= 2 and chain[0] in ("np", "numpy") \
                and len(node.args) == 1:
            return self.eval(node.args[0], st)
        if chain and chain[-1] == "arange" and node.args:
            if len(node.args) == 1:
                n = self.eval(node.args[0], st)
                hi = _resolve(n.hi, st.env)
                return Interval(0, hi - 1 if hi is not None else None)
            a = self.eval(node.args[0], st)
            b = self.eval(node.args[1], st)
            bhi = _resolve(b.hi, st.env)
            return Interval(_resolve(a.lo, st.env),
                            bhi - 1 if bhi is not None else None)
        if chain in (("min",), ("max",)) and len(node.args) >= 2:
            vals = [self.eval(a, st) for a in node.args]
            los = [_resolve(v.lo, st.env) for v in vals]
            his = [_resolve(v.hi, st.env) for v in vals]
            if chain == ("max",):
                lo = max([l for l in los if l is not None], default=None)
                hi = None if None in his else max(his)
            else:
                lo = None if None in los else min(los)
                hi = min([h for h in his if h is not None], default=None)
            return Interval(lo, hi)
        if chain and chain[-1] == "astype":
            v = self.eval(node.func.value, st)
            self._check_astype(node, v, st)
            tgt = self._astype_dtype(node)
            if tgt in _NARROW:
                lo, hi = _NARROW[tgt]
                vlo, vhi = _resolve(v.lo, st.env), _resolve(v.hi, st.env)
                return Interval(max(lo, vlo) if vlo is not None else lo,
                                min(hi, vhi) if vhi is not None else hi)
            return v
        if chain and len(chain) >= 2 and chain[-1] in _IDENT_METHODS \
                and chain[-1] not in ("astype", "sum"):
            return self.eval(node.func.value, st)
        # uninterpreted call: still DESCEND so packing/astype checks
        # inside receivers and arguments run ((x << 45 | y).ravel(),
        # np.argsort(part.astype(...)), parts.append(pack))
        if isinstance(node.func, ast.Attribute):
            self.eval(node.func.value, st)
        for a in node.args:
            self.eval(a.value if isinstance(a, ast.Starred) else a, st)
        for kw in node.keywords:
            self.eval(kw.value, st)
        # in-program callee: inline one level with the caller's arguments
        return self._inline(node, st)

    def _comp(self, node, st: State) -> Interval:
        """Evaluate a comprehension body with its targets bound, so
        packing checks inside list-comp elements run (the ``parts``
        pack loop in ``_mem_concat``)."""
        cst = st.copy()
        for gen in node.generators:
            shim = ast.For(target=gen.target, iter=gen.iter, body=[],
                           orelse=[])
            self._bind_loop_target(shim, cst)
            for cond in gen.ifs:
                self.eval(cond, cst)
                refine(cst, cond, True, self)
        return self.eval(node.elt, cst)

    def _inline(self, node: ast.Call, st: State) -> Interval:
        if self.depth >= 2:
            return TOP
        targets = self.call_targets.get(id(node), ())
        if len(targets) != 1:
            return TOP
        fn = targets[0]
        body = getattr(fn.node, "body", None)
        if body is None or len(body) > 60:
            return TOP
        args = list(fn.node.args.args)
        is_method = bool(args) and args[0].arg in ("self", "cls")
        params = args[1:] if is_method else args
        sub = _Analyzer(self.rule, self.rule._unit_of.get(fn.relpath,
                                                          self.unit),
                        self.rule._consts.get(fn.relpath, {}),
                        self.rule._cls_consts.get((fn.relpath, fn.cls), {}),
                        self.rule._targets_of(fn.relpath),
                        depth=self.depth + 1)
        cst = State()
        for p, a in zip(params, node.args):
            cst.env[p.arg] = self.eval(a, st)
        chain = dotted(node.func)
        if chain and chain[0] in ("self", "cls"):
            # same receiver: self.* facts carry into the callee
            for k, v in st.env.items():
                if k.startswith("self."):
                    cst.env[k] = v.copy()
        sub.process(body, cst)
        out = None
        for r in sub.returns:
            out = r if out is None else _hull(out, r)
        if out is None:
            return TOP
        # symbolic bounds in the callee's frame must be translated into
        # the caller's: a param key maps through the argument's name
        # (hash_partition's [0, p-1] follows the caller's p); self.* keys
        # survive only for a self.m() call (same receiver); anything
        # else resolves concretely in the callee env or drops to None
        argmap = {p.arg: _key_of(a)
                  for p, a in zip(params, node.args) if _key_of(a)}
        same_self = bool(chain) and chain[0] in ("self", "cls")

        def xlate(bound):
            if not _is_sym(bound):
                return bound
            key = bound[1]
            if key in argmap:
                return ("sym", argmap[key], bound[2])
            if same_self and key.startswith("self."):
                return bound
            return _resolve(bound, cst.env)
        return Interval(xlate(out.lo), xlate(out.hi))

    # --------------------------------------------------------------- checks
    def _astype_dtype(self, node: ast.Call) -> str | None:
        arg = node.args[0] if node.args else None
        if arg is None:
            return None
        chain = dotted(arg)
        if chain and chain[-1] in _NARROW:
            return chain[-1]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            return arg.value if arg.value in _NARROW else None
        return None

    def _check_astype(self, node: ast.Call, v: Interval, st: State) -> None:
        tgt = self._astype_dtype(node)
        if tgt is None:
            return
        if id(node) not in self.argsort_args \
                and not (self._cur_target
                         and self._cur_target in self.argsort_names):
            return          # only sort-key narrowing decides ordering
        lo, hi = _NARROW[tgt]
        vlo, vhi = _resolve(v.lo, st.env), _resolve(v.hi, st.env)
        if vlo is None or vhi is None or vlo < lo or vhi > hi:
            src = _key_of(node.func.value) or "value"
            self._flag(node,
                       f"astype(np.{tgt}) on argsort key '{src}' not "
                       f"provably within [{lo}, {hi}] (have {Interval(vlo, vhi)}) "
                       f"— a truncating cast silently reorders the radix "
                       f"sort; guard or assert the range first")

    def _check_packing(self, node: ast.BinOp, st: State) -> None:
        sides = [(node.left, node.right), (node.right, node.left)]
        for shift_side, low_side in sides:
            if isinstance(shift_side, ast.BinOp) \
                    and isinstance(shift_side.op, ast.LShift):
                break
        else:
            return
        c = self.eval(shift_side.right, st)
        clo, chi = _resolve(c.lo, st.env), _resolve(c.hi, st.env)
        if clo is None or clo != chi or clo < 8:
            return          # not a wide-field packing (small bit tricks)
        shift = clo
        a = self.eval(shift_side.left, st)
        b = self.eval(low_side, st)
        alo, ahi = _resolve(a.lo, st.env), _resolve(a.hi, st.env)
        blo, bhi = _resolve(b.lo, st.env), _resolve(b.hi, st.env)
        op = "|" if isinstance(node.op, ast.BitOr) else "+"
        problems = []
        if blo is None or blo < 0 or bhi is None or bhi > (1 << shift) - 1:
            bname = _key_of(low_side) or "low field"
            problems.append(
                f"'{bname}' not provably in [0, 2**{shift}) "
                f"(have {Interval(blo, bhi)})")
        amax = (1 << (63 - shift)) - 1
        if alo is None or alo < 0 or ahi is None or ahi > amax:
            aname = _key_of(shift_side.left) or "shifted field"
            problems.append(
                f"'{aname}' << {shift} not provably within int64 "
                f"(need [0, 2**{63 - shift}), have {Interval(alo, ahi)})")
        if problems:
            self._flag(node,
                       f"unproven packed-key `(A << {shift}) {op} B`: "
                       + "; ".join(problems)
                       + " — bands collide or the sort order flips "
                         "silently; guard or assert before packing")

    # ------------------------------------------------------------ statements
    _cur_target: str | None = None

    def collect_argsort(self, fn: ast.AST) -> None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                chain = dotted(node.func)
                if chain and chain[-1] == "argsort" and node.args:
                    for sub in ast.walk(node.args[0]):
                        self.argsort_args.add(id(sub))
                    if isinstance(node.args[0], ast.Name):
                        self.argsort_names.add(node.args[0].id)

    def process(self, body: list[ast.stmt], st: State) -> bool:
        """Walk statements; True when every path terminates (return /
        raise / continue / break) — the caller then keeps the negated
        guard."""
        for stmt in body:
            if isinstance(stmt, (ast.Return,)):
                if stmt.value is not None:
                    self.returns.append(self.eval(stmt.value, st))
                else:
                    self.returns.append(TOP)
                return True
            if isinstance(stmt, (ast.Raise, ast.Continue, ast.Break)):
                return True
            if isinstance(stmt, ast.Assert):
                self.eval(stmt.test, st)
                refine(st, stmt.test, True, self)
            elif isinstance(stmt, ast.Assign):
                self._assign(stmt, st)
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None \
                    and isinstance(stmt.target, ast.Name):
                st.env[stmt.target.id] = self.eval(stmt.value, st)
            elif isinstance(stmt, ast.AugAssign):
                self.eval(stmt.value, st)
                key = _key_of(stmt.target)
                if key:
                    st.env.pop(key, None)
            elif isinstance(stmt, ast.If):
                if self._if(stmt, st):
                    return True
            elif isinstance(stmt, (ast.For, ast.While)):
                self._loop(stmt, st)
            elif isinstance(stmt, ast.With):
                for it in stmt.items:
                    self.eval(it.context_expr, st)
                if self.process(stmt.body, st):
                    return True
            elif isinstance(stmt, ast.Try):
                if self.process(stmt.body, st.copy()):
                    pass
                for h in stmt.handlers:
                    self.process(h.body, st.copy())
                self.process(stmt.finalbody, st)
                # after try: conservative — drop nothing (checks already ran)
            elif isinstance(stmt, ast.Expr):
                self.eval(stmt.value, st)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                continue        # nested defs analyzed as their own functions
        return False

    def _assign(self, stmt: ast.Assign, st: State) -> None:
        val = self.eval(stmt.value, st)
        for tgt in stmt.targets:
            if isinstance(tgt, ast.Name):
                self._cur_target = tgt.id
                st.env[tgt.id] = self.eval(stmt.value, st) \
                    if tgt is stmt.targets[0] else val
                self._cur_target = None
                self._track(tgt.id, stmt.value, st)
            elif isinstance(tgt, ast.Attribute):
                key = _key_of(tgt)
                if key:
                    st.env[key] = val
            elif isinstance(tgt, ast.Tuple) and isinstance(stmt.value,
                                                           ast.Tuple) \
                    and len(tgt.elts) == len(stmt.value.elts):
                for t, v in zip(tgt.elts, stmt.value.elts):
                    if isinstance(t, ast.Name):
                        st.env[t.id] = self.eval(v, st)
                        self._track(t.id, v, st)
            elif isinstance(tgt, ast.Tuple):
                for t in tgt.elts:
                    if isinstance(t, ast.Name):
                        st.env[t.id] = TOP
                        st.preds.pop(t.id, None)

    def _track(self, name: str, value: ast.AST, st: State) -> None:
        """len-aliases and predicate bindings for later ``if name:``."""
        if isinstance(value, ast.Call) and dotted(value.func) == ("len",) \
                and len(value.args) == 1:
            src = _key_of(value.args[0])
            if src:
                st.lens[src] = name
        inner = value
        if isinstance(inner, ast.Call) and dotted(inner.func) == ("bool",) \
                and len(inner.args) == 1:
            inner = inner.args[0]
        if isinstance(inner, (ast.Compare, ast.BoolOp)):
            st.preds[name] = (inner, {k: v.copy()
                                      for k, v in st.env.items()})
        elif isinstance(inner, ast.Constant) and not inner.value:
            st.preds[name] = _NEVER
        else:
            st.preds.pop(name, None)

    @staticmethod
    def _merge_preds(a: dict, b: dict) -> dict:
        out = {}
        for k in set(a) & set(b):
            pa, pb = a[k], b[k]
            if pa == pb:
                out[k] = pa
            elif pb == _NEVER:
                out[k] = pa       # other path can't make the var truthy
            elif pa == _NEVER:
                out[k] = pb
        return out

    @staticmethod
    def _merge_lens(a: dict, b: dict) -> dict:
        return {k: v for k, v in a.items() if b.get(k) == v}

    def _join(self, st: State, a: State, b: State) -> None:
        joined = {}
        for k in set(a.env) | set(b.env):
            joined[k] = _hull(a.env.get(k, TOP), b.env.get(k, TOP))
        st.env = joined
        st.preds = self._merge_preds(a.preds, b.preds)
        st.lens = self._merge_lens(a.lens, b.lens)

    def _if(self, stmt: ast.If, st: State) -> bool:
        body_st = st.copy()
        refine(body_st, stmt.test, True, self)
        term_body = self.process(stmt.body, body_st)
        if stmt.orelse:
            else_st = st.copy()
            refine(else_st, stmt.test, False, self)
            term_else = self.process(stmt.orelse, else_st)
            if term_body and term_else:
                return True
            if term_body:
                st.env = else_st.env
                st.lens, st.preds = else_st.lens, else_st.preds
                return False
            if term_else:
                st.env = body_st.env
                st.lens, st.preds = body_st.lens, body_st.preds
                return False
            self._join(st, body_st, else_st)
            return False
        if term_body:
            refine(st, stmt.test, False, self)
            return False
        self._join(st, body_st, st.copy())
        return False

    def _loop(self, stmt, st: State) -> None:
        body_st = st.copy()
        if isinstance(stmt, ast.For):
            self._bind_loop_target(stmt, body_st)
        else:
            refine(body_st, stmt.test, True, self)
        self.process(stmt.body, body_st)
        self.process(stmt.orelse, st.copy())
        for n in ast.walk(stmt):
            if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                tgts = n.targets if isinstance(n, ast.Assign) else [n.target]
                for t in tgts:
                    for nn in ast.walk(t):
                        if isinstance(nn, ast.Name):
                            st.env.pop(nn.id, None)
                            st.preds.pop(nn.id, None)

    def _bind_loop_target(self, stmt: ast.For, st: State) -> None:
        it, tgt = stmt.iter, stmt.target
        names = [n.id for n in ast.walk(tgt) if isinstance(n, ast.Name)]
        for n in names:
            st.env[n] = TOP
            st.preds.pop(n, None)
        if not isinstance(it, ast.Call):
            return
        chain = dotted(it.func)
        if chain == ("range",) and it.args:
            iv = (Interval(0, self._upper_minus_one(it.args[0], st))
                  if len(it.args) == 1 else
                  Interval(_resolve(self.eval(it.args[0], st).lo, st.env),
                           self._upper_minus_one(it.args[1], st)))
            if isinstance(tgt, ast.Name):
                st.env[tgt.id] = iv
        elif chain == ("enumerate",) and it.args \
                and isinstance(tgt, ast.Tuple) and tgt.elts \
                and isinstance(tgt.elts[0], ast.Name):
            src = _key_of(it.args[0])
            hi = None
            if src and src in st.lens and st.lens[src] in st.env:
                nhi = _resolve(st.env[st.lens[src]].hi, st.env)
                hi = nhi - 1 if nhi is not None else None
            st.env[tgt.elts[0].id] = Interval(0, hi)

    def _upper_minus_one(self, node: ast.AST, st: State) -> int | None:
        hi = _resolve(self.eval(node, st).hi, st.env)
        return hi - 1 if hi is not None else None


def refine(st: State, cond: ast.AST, truth: bool, an: _Analyzer,
           eval_env: dict | None = None) -> None:
    """Apply what a condition (known ``truth``) implies to ``st.env``."""
    if isinstance(cond, ast.UnaryOp) and isinstance(cond.op, ast.Not):
        refine(st, cond.operand, not truth, an, eval_env)
        return
    if isinstance(cond, ast.Call) and dotted(cond.func) == ("bool",) \
            and len(cond.args) == 1:
        refine(st, cond.args[0], truth, an, eval_env)
        return
    if isinstance(cond, ast.Name) and truth and cond.id in st.preds:
        entry = st.preds[cond.id]
        if entry == _NEVER:
            return                 # branch statically unreachable here
        pred, captured = entry
        refine(st, pred, True, an, captured)
        return
    if isinstance(cond, ast.BoolOp):
        if isinstance(cond.op, ast.And) and truth:
            for v in cond.values:
                refine(st, v, True, an, eval_env)
        elif isinstance(cond.op, ast.Or) and not truth:
            for v in cond.values:
                refine(st, v, False, an, eval_env)
        elif isinstance(cond.op, ast.And) and not truth:
            # ¬(len(x) and C) refines ¬C for ELEMENTWISE facts: an empty
            # array satisfies any elementwise bound vacuously.  Only when
            # exactly one conjunct is not a len()-truthiness test.
            rest = [v for v in cond.values
                    if not (isinstance(v, ast.Call)
                            and dotted(v.func) == ("len",))]
            if len(rest) == 1:
                refine(st, rest[0], False, an, eval_env)
        return
    if not isinstance(cond, ast.Compare):
        return
    sides = [cond.left, *cond.comparators]
    ops = list(cond.ops)
    for i, op in enumerate(ops):
        left, right = sides[i], sides[i + 1]
        _refine_pair(st, left, op, right, truth, an, eval_env)


_FLIP = {ast.Lt: ast.Gt, ast.Gt: ast.Lt, ast.LtE: ast.GtE, ast.GtE: ast.LtE,
         ast.Eq: ast.Eq, ast.NotEq: ast.NotEq}
_NEG = {ast.Lt: ast.GtE, ast.LtE: ast.Gt, ast.Gt: ast.LtE, ast.GtE: ast.Lt,
        ast.Eq: ast.NotEq, ast.NotEq: ast.Eq}


def _refine_pair(st: State, left: ast.AST, op: ast.cmpop, right: ast.AST,
                 truth: bool, an: _Analyzer, eval_env: dict | None) -> None:
    opt = type(op)
    if opt not in _FLIP:
        return
    if not truth:
        opt = _NEG[opt]
    ev = State()
    ev.env = eval_env if eval_env is not None else st.env
    for key_side, bound_side, o in ((left, right, opt),
                                    (right, left, _FLIP[opt])):
        key = _key_of(key_side)
        if key is None:
            continue
        b = an.eval(bound_side, ev)
        blo, bhi = _resolve(b.lo, ev.env), _resolve(b.hi, ev.env)
        cur = st.env.get(key, TOP).copy()
        if bhi is None:
            # bound is a named quantity with no concrete range yet
            # (``assert sets.max() < self.cache_sets``): keep it as a
            # SYMBOLIC upper bound, resolved wherever the name later
            # gains one (e.g. under ``if self.cache_sets <= 2**16``)
            bkey = _key_of(bound_side)
            if bkey is not None and not isinstance(cur.hi, int):
                if o is ast.Lt:
                    cur.hi = ("sym", bkey, -1)
                    st.env[key] = cur
                elif o is ast.LtE:
                    cur.hi = ("sym", bkey, 0)
                    st.env[key] = cur
        if o is ast.Lt and bhi is not None:
            cur.hi = bhi - 1 if not isinstance(cur.hi, int) \
                else min(cur.hi, bhi - 1)
        elif o is ast.LtE and bhi is not None:
            cur.hi = bhi if not isinstance(cur.hi, int) \
                else min(cur.hi, bhi)
        elif o is ast.Gt and blo is not None:
            cur.lo = blo + 1 if not isinstance(cur.lo, int) \
                else max(cur.lo, blo + 1)
        elif o is ast.GtE and blo is not None:
            cur.lo = blo if not isinstance(cur.lo, int) \
                else max(cur.lo, blo)
        elif o is ast.Eq and blo is not None and blo == bhi:
            cur = const(blo)
        else:
            continue
        st.env[key] = cur


def _int_const_expr(node: ast.AST, known: dict[str, int]) -> int | None:
    """Tiny const-folder for module/class-level integer definitions
    (``_SHIFT = np.int64(45)``, ``_LIM = np.int64(1) << _SHIFT``)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name):
        return known.get(node.id)
    if isinstance(node, ast.Call) and len(node.args) == 1:
        chain = dotted(node.func)
        if chain and chain[-1] in ("int64", "uint64", "int32", "int16",
                                   "int8", "uint32", "uint16", "uint8",
                                   "int"):
            return _int_const_expr(node.args[0], known)
    if isinstance(node, ast.BinOp):
        l = _int_const_expr(node.left, known)
        r = _int_const_expr(node.right, known)
        if l is None or r is None:
            return None
        if isinstance(node.op, ast.LShift) and 0 <= r <= 62:
            return l << r
        if isinstance(node.op, ast.Add):
            return l + r
        if isinstance(node.op, ast.Sub):
            return l - r
        if isinstance(node.op, ast.Mult):
            return l * r
        if isinstance(node.op, ast.Pow) and 0 <= r <= 64 and abs(l) <= 4096:
            return l ** r
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _int_const_expr(node.operand, known)
        return -v if v is not None else None
    return None


@register_rule
class PackedKeyBitwidth(Rule):
    """Unproven packed-key arithmetic / truncating sort-key cast."""
    id = "B601"
    title = "packed-key bit-width not statically proven"
    scope = ("src/repro/state/", "src/repro/streaming/")

    def __init__(self) -> None:
        self._found: list[Finding] = []
        self._by_path: dict[str, list[Finding]] = {}
        self._consts: dict[str, dict[str, int]] = {}
        self._cls_consts: dict[tuple[str, str | None], dict[str, int]] = {}
        self._unit_of: dict[str, FileUnit] = {}
        self._cg = None

    def _targets_of(self, relpath: str):
        return self._site_targets.get(relpath, {})

    def prepare(self, units: list[FileUnit]) -> None:
        self._found = []
        self._by_path = {}
        self._cg = get_callgraph(units)
        self._unit_of = {u.relpath: u for u in units}
        # integer constant tables (module level + class attributes)
        for u in units:
            known: dict[str, int] = {}
            for stmt in u.tree.body:
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name):
                    v = _int_const_expr(stmt.value, known)
                    if v is not None:
                        known[stmt.targets[0].id] = v
                if isinstance(stmt, ast.ClassDef):
                    cknown: dict[str, int] = {}
                    for cs in stmt.body:
                        if isinstance(cs, ast.Assign) \
                                and len(cs.targets) == 1 \
                                and isinstance(cs.targets[0], ast.Name):
                            v = _int_const_expr(cs.value,
                                                dict(known, **cknown))
                            if v is not None:
                                cknown[cs.targets[0].id] = v
                    self._cls_consts[(u.relpath, stmt.name)] = {
                        "self." + k: v for k, v in cknown.items()}
            self._consts[u.relpath] = known
        # per-file call-node -> resolved FuncNode targets (for inlining)
        self._site_targets: dict[str, dict[int, list]] = {}
        for site in self._cg.sites:
            rel = self._cg.nodes[site.caller].relpath
            self._site_targets.setdefault(rel, {})[id(site.call)] = [
                self._cg.nodes[t] for t in site.targets]
        # analyze every function in every APPLICABLE unit
        for fid, fn in sorted(self._cg.nodes.items()):
            if fn.node is None or not self.applies(fn.relpath):
                continue
            unit = self._unit_of[fn.relpath]
            an = _Analyzer(self, unit, self._consts.get(fn.relpath, {}),
                           self._cls_consts.get((fn.relpath, fn.cls), {}),
                           self._targets_of(fn.relpath))
            an.collect_argsort(fn.node)
            an.process(fn.node.body, State())
        for f in self._found:
            self._by_path.setdefault(f.path, []).append(f)

    def visit(self, unit: FileUnit) -> list[Finding]:
        return list(self._by_path.get(unit.relpath, ()))
