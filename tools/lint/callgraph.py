"""Module-qualified call graph over the linted program.

The interprocedural passes (taint, effects, the transitive-sort sweep)
all need the same three things the per-file rules cannot see: *who calls
whom* across module boundaries, *which import alias means which module*,
and *which attribute call can land on which method*.  This module builds
that once per lint run (shared through ``Program.callgraph()``) from the
already-parsed :class:`FileUnit` list — no re-parsing.

Resolution policy (deliberately conservative, documented here because
the passes inherit its precision):

* **Bare names** (``f(...)``) resolve to a top-level def or class in the
  *same file*, else through a ``from M import f`` alias; never by global
  name union — a bare ``benchmark()`` in sim code must not link to an
  unrelated ``benchmark`` in jax-side code.
* **``self.m(...)`` / ``cls.m(...)``** resolves to the enclosing class's
  method, walking program-visible base classes; if the class doesn't
  define it anywhere visible, it falls back to the union of all methods
  named ``m`` (the U401-style whole-program convention).
* **Module-alias chains** (``lsm.make_store(...)``, ``t.time(...)``)
  expand through the import-alias table.  In-program targets become
  edges; the rest are recorded verbatim as *external chains* so sink
  predicates (``time.*``, ``numpy.random.*``) can match them even
  through ``import time as t``.
* **Other attribute calls** (``store.items(...)``) union over every
  method with that terminal name — over-approximate by design: taint
  must not miss an edge because the receiver's type is unknown.

Calls at module level are attributed to a synthetic ``<module>``
function per file, so import-time nondeterminism is reachable too.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

from tools.lint.core import FileUnit, dotted

MODULE_BODY = "<module>"


def module_name(relpath: str) -> str:
    """``src/repro/state/lsm.py`` -> ``repro.state.lsm`` (the name the
    import system sees, so alias chains resolve against it)."""
    p = relpath[:-3] if relpath.endswith(".py") else relpath
    if p.startswith("src/"):
        p = p[len("src/"):]
    if p.endswith("/__init__"):
        p = p[: -len("/__init__")]
    return p.replace("/", ".")


@dataclass
class FuncNode:
    """One function/method (or the synthetic module body) in the graph."""
    fid: str                 # "relpath::qualname"
    relpath: str
    qualname: str            # "Class.method", "func", "func.inner", "<module>"
    name: str                # terminal name
    cls: str | None          # enclosing class name, if a method
    node: ast.AST | None     # None for the synthetic module body
    lineno: int = 0

    @property
    def label(self) -> str:
        return f"{module_name(self.relpath)}:{self.qualname}"


@dataclass
class CallSite:
    """One call expression, attributed to its innermost enclosing def."""
    caller: str                        # caller fid
    call: ast.Call
    targets: tuple[str, ...] = ()      # resolved in-program callee fids
    external: tuple[str, ...] = ()     # expanded dotted chain if unresolved


@dataclass
class CallGraph:
    nodes: dict[str, FuncNode] = field(default_factory=dict)
    sites: list[CallSite] = field(default_factory=list)
    edges: dict[str, set[str]] = field(default_factory=dict)
    redges: dict[str, set[str]] = field(default_factory=dict)
    sites_by_caller: dict[str, list[CallSite]] = field(default_factory=dict)
    unit_of: dict[str, FileUnit] = field(default_factory=dict)  # fid -> unit
    methods_by_name: dict[str, list[str]] = field(default_factory=dict)

    def funcs_in(self, relpath: str) -> list[FuncNode]:
        return [n for n in self.nodes.values() if n.relpath == relpath]

    def forward_closure(self, roots: set[str]) -> set[str]:
        seen, todo = set(roots), list(roots)
        while todo:
            f = todo.pop()
            for g in self.edges.get(f, ()):
                if g not in seen:
                    seen.add(g)
                    todo.append(g)
        return seen

    def reverse_closure(self, roots: set[str]
                        ) -> tuple[set[str], dict[str, str]]:
        """Everything that can reach ``roots``, plus a parent map:
        ``parent[f]`` is the callee through which f first reached the
        root set (for rendering f -> ... -> root chains)."""
        seen, parent = set(roots), {}
        todo = sorted(roots)             # deterministic BFS order
        while todo:
            nxt: list[str] = []
            for f in todo:
                for g in sorted(self.redges.get(f, ())):
                    if g not in seen:
                        seen.add(g)
                        parent[g] = f
                        nxt.append(g)
            todo = nxt
        return seen, parent

    def chain(self, fid: str, parent: dict[str, str],
              stop: set[str]) -> list[str]:
        """Human-readable qualname chain from ``fid`` down to the first
        node inside ``stop`` (the root/sink set)."""
        out, cur, guard = [], fid, 0
        while cur is not None and guard < 32:
            out.append(self.nodes[cur].label if cur in self.nodes else cur)
            if cur in stop:
                break
            cur = parent.get(cur)
            guard += 1
        return out


class _Collector:
    """Per-unit def/class/import collection + call attribution."""

    def __init__(self, unit: FileUnit) -> None:
        self.unit = unit
        self.relpath = unit.relpath
        self.funcs: list[FuncNode] = []
        self.toplevel: dict[str, str] = {}          # name -> fid
        self.classes: dict[str, dict[str, str]] = {}  # cls -> {meth: fid}
        self.class_bases: dict[str, tuple[str, ...]] = {}
        self.aliases: dict[str, str] = {}           # bound name -> dotted
        self.calls: list[tuple[str, str | None, ast.Call]] = []
        # ^ (caller fid, enclosing class, call node)
        self.nested_edges: list[tuple[str, str]] = []

    def fid(self, qualname: str) -> str:
        return f"{self.relpath}::{qualname}"

    def collect(self) -> None:
        mod = FuncNode(self.fid(MODULE_BODY), self.relpath, MODULE_BODY,
                       MODULE_BODY, None, None)
        self.funcs.append(mod)
        self._imports()
        self._walk_body(self.unit.tree.body, [], None, mod.fid)

    def _imports(self) -> None:
        pkg = module_name(self.relpath).split(".")
        for node in ast.walk(self.unit.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    bound = a.asname or a.name.split(".")[0]
                    target = a.name if a.asname else a.name.split(".")[0]
                    self.aliases[bound] = target
            elif isinstance(node, ast.ImportFrom):
                if node.level:
                    base = pkg[: len(pkg) - node.level]
                    if node.module:
                        base = base + node.module.split(".")
                elif node.module:
                    base = node.module.split(".")
                else:
                    continue
                for a in node.names:
                    if a.name == "*":
                        continue
                    bound = a.asname or a.name
                    self.aliases[bound] = ".".join(base + [a.name])

    def _walk_body(self, body: list[ast.stmt], quals: list[str],
                   cls: str | None, owner: str) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = quals + [stmt.name]
                fn = FuncNode(self.fid(".".join(q)), self.relpath,
                              ".".join(q), stmt.name, cls, stmt, stmt.lineno)
                self.funcs.append(fn)
                if cls is not None and len(quals) == 1:
                    self.classes.setdefault(cls, {})[stmt.name] = fn.fid
                elif not quals:
                    self.toplevel[stmt.name] = fn.fid
                # a nested def is conservatively reachable from its encloser
                if quals:
                    self.nested_edges.append((owner, fn.fid))
                for dec in stmt.decorator_list:
                    self._calls_in(dec, owner, cls)
                self._walk_body(stmt.body, q, cls, fn.fid)
            elif isinstance(stmt, ast.ClassDef):
                if not quals:
                    self.classes.setdefault(stmt.name, {})
                    self.class_bases[stmt.name] = tuple(
                        b for b in (self._base_name(x) for x in stmt.bases)
                        if b)
                for dec in stmt.decorator_list:
                    self._calls_in(dec, owner, cls)
                self._walk_body(stmt.body, quals + [stmt.name],
                                stmt.name if not quals else cls, owner)
            else:
                self._calls_in(stmt, owner, cls)

    @staticmethod
    def _base_name(node: ast.AST) -> str | None:
        chain = dotted(node)
        return chain[-1] if chain else None

    def _calls_in(self, node: ast.AST, owner: str, cls: str | None) -> None:
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                self.calls.append((owner, cls, n))


def build_callgraph(units: list[FileUnit]) -> CallGraph:
    cg = CallGraph()
    collectors = [_Collector(u) for u in units]
    for c in collectors:
        c.collect()

    toplevel: dict[tuple[str, str], str] = {}
    classes: dict[tuple[str, str], dict[str, str]] = {}
    classes_by_name: dict[str, list[tuple[str, str]]] = {}
    bases: dict[tuple[str, str], tuple[str, ...]] = {}
    module_index: dict[str, str] = {}
    for c in collectors:
        module_index[module_name(c.relpath)] = c.relpath
        for name, fid in c.toplevel.items():
            toplevel[(c.relpath, name)] = fid
        for cls, meths in c.classes.items():
            classes[(c.relpath, cls)] = meths
            classes_by_name.setdefault(cls, []).append((c.relpath, cls))
            bases[(c.relpath, cls)] = c.class_bases.get(cls, ())
        for fn in c.funcs:
            cg.nodes[fn.fid] = fn
            cg.unit_of[fn.fid] = c.unit
            if fn.cls is not None:
                cg.methods_by_name.setdefault(fn.name, []).append(fn.fid)

    def class_method(relpath: str, cls: str, name: str,
                     depth: int = 0) -> str | None:
        meths = classes.get((relpath, cls))
        if meths and name in meths:
            return meths[name]
        if depth >= 4:
            return None
        for base in bases.get((relpath, cls), ()):
            for (rp2, cls2) in classes_by_name.get(base, ()):
                hit = class_method(rp2, cls2, name, depth + 1)
                if hit:
                    return hit
        return None

    def ctor(relpath: str, cls_name: str) -> tuple[str, ...]:
        hit = class_method(relpath, cls_name, "__init__")
        return (hit,) if hit else ()

    def resolve(c: _Collector, cls: str | None,
                call: ast.Call) -> tuple[tuple[str, ...], tuple[str, ...]]:
        chain = dotted(call.func)
        if not chain:
            return (), ()
        if len(chain) == 1:
            name = chain[0]
            if (c.relpath, name) in toplevel:
                return (toplevel[(c.relpath, name)],), ()
            if (c.relpath, name) in classes:
                return ctor(c.relpath, name), ()
            if name in c.aliases:
                return _resolve_dotted(c.aliases[name].split("."))
            return (), (name,)
        if chain[0] in ("self", "cls") and cls is not None:
            hit = class_method(c.relpath, cls, chain[-1])
            if hit and len(chain) == 2:
                return (hit,), ()
            return tuple(cg.methods_by_name.get(chain[-1], ())), ()
        if chain[0] in c.aliases:
            full = c.aliases[chain[0]].split(".") + list(chain[1:])
            return _resolve_dotted(full)
        # unknown receiver: union over same-named methods (U401-style)
        return tuple(cg.methods_by_name.get(chain[-1], ())), ()

    def _resolve_dotted(full: list[str]
                        ) -> tuple[tuple[str, ...], tuple[str, ...]]:
        for i in range(len(full) - 1, 0, -1):
            mod = ".".join(full[:i])
            if mod not in module_index:
                continue
            rel2, rest = module_index[mod], full[i:]
            if len(rest) == 1:
                if (rel2, rest[0]) in toplevel:
                    return (toplevel[(rel2, rest[0])],), ()
                if (rel2, rest[0]) in classes:
                    return ctor(rel2, rest[0]), ()
            elif len(rest) == 2 and (rel2, rest[0]) in classes:
                hit = class_method(rel2, rest[0], rest[1])
                if hit:
                    return (hit,), ()
            return (), ()        # known module, unknown member: no edge
        return (), tuple(full)   # fully external: keep chain for sinks

    for c in collectors:
        for owner, cls, call in c.calls:
            targets, external = resolve(c, cls, call)
            site = CallSite(owner, call, targets, external)
            cg.sites.append(site)
            cg.sites_by_caller.setdefault(owner, []).append(site)
            for t in targets:
                cg.edges.setdefault(owner, set()).add(t)
                cg.redges.setdefault(t, set()).add(owner)
        for owner, nested in c.nested_edges:
            cg.edges.setdefault(owner, set()).add(nested)
            cg.redges.setdefault(nested, set()).add(owner)
    return cg
