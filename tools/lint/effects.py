"""A701 — escape/aliasing analysis for live SoA arrays.

The PR 4 ``items()`` bug class: a store method returns (or logs into a
history row) a *view* of a live internal array — a run key column, the
admission counters — and the caller mutates or keeps it across a
``put_batch``, silently corrupting state or recording values that change
after the fact.  We fixed ``items()`` once by hand; A701 checks the
whole class of them.

The pass computes, for every function in sim scope, a **view-source
summary**: the subset of ``{"self"} ∪ params`` whose live storage the
return value may alias.  Summaries propagate bottom-up over the shared
call graph to a fixpoint, so a public method that returns
``self._collapse(sources)`` where ``_collapse`` passes an element of its
argument straight through is caught even though the public method never
touches ``self.<array>`` syntactically.

What counts as *live internal storage*: ``self.X`` where X looks like an
array container — assigned anywhere in the class from a ``np.*`` /
``numpy.*`` call chain, has ``.append()`` called on it, or is assigned a
list literal/comprehension.  Plain scalars, dicts and config attributes
are not storage, so returning ``self.seed`` is fine.

What *launders* a value (stops alias propagation): ``.copy()``,
``np.array(...)``, ``np.asarray`` is NOT blessed (it is a no-copy cast
on purpose), ``copy.deepcopy``, ``np.concatenate`` and friends (they
allocate), arithmetic that allocates (``a + 1``... but ``a + b`` on
tuples concatenates views, so BinOp unions), and fancy (array-valued)
indexing.  Basic slices and constant indices preserve aliasing.

Findings:
* a PUBLIC (no leading underscore, non-dunder) function/method whose
  return value may alias ``self`` storage — reported at the return;
* any ``<...>.history.append(arg)`` whose argument may alias ``self``
  storage — history rows must be frozen at append time.

Private helpers returning views are fine (that is how the SoA code
avoids copies internally); the *public surface* and the audit history
are where aliasing escapes control.
"""
from __future__ import annotations

import ast

from tools.lint.core import FileUnit, Finding, Rule, dotted, get_callgraph, \
    register_rule
from tools.lint.rules import SIM_SCOPE

_LAUNDER_CALLS = {
    ("copy",), ("deepcopy",),
}
_LAUNDER_NP = {"array", "concatenate", "zeros", "ones", "empty", "full",
               "arange", "unique", "sort", "cumsum", "repeat", "tile",
               "where", "searchsorted", "argsort", "bincount", "diff",
               "add", "maximum", "minimum", "stack", "hstack", "vstack",
               "split", "copy", "zeros_like", "ones_like", "empty_like",
               "full_like", "fromiter", "asfortranarray", "ascontiguousarray"}
_LAUNDER_METHODS = {"copy", "tolist", "sum", "mean", "astype", "item",
                    "nonzero", "cumsum", "argsort", "take"}


def _fixture(relpath: str) -> bool:
    return not relpath.startswith("src/repro/")


def _sim(relpath: str) -> bool:
    return _fixture(relpath) or relpath.startswith(SIM_SCOPE)


def _is_np_chain(node: ast.AST) -> bool:
    chain = dotted(node)
    return bool(chain) and chain[0] in ("np", "numpy", "jnp")


def array_attrs(cls: ast.ClassDef) -> set[str]:
    """Attribute names in this class that look like live array storage."""
    out: set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign):
            arrayish = False
            v = node.value
            if isinstance(v, ast.Call) and _is_np_chain(v.func):
                arrayish = True
            elif isinstance(v, (ast.List, ast.ListComp)):
                arrayish = True
            if arrayish:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Attribute) \
                            and isinstance(tgt.value, ast.Name) \
                            and tgt.value.id == "self":
                        out.add(tgt.attr)
        elif isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr == "append" \
                    and isinstance(f.value, ast.Attribute) \
                    and isinstance(f.value.value, ast.Name) \
                    and f.value.value.id == "self":
                out.add(f.value.attr)
    return out


class _FnInfo:
    __slots__ = ("fid", "unit", "fn", "params", "is_method", "arrays",
                 "summary", "aliases", "callsites")

    def __init__(self, fid, unit, fn, params, is_method, arrays):
        self.fid = fid
        self.unit = unit
        self.fn = fn
        self.params = params            # positional param names (sans self)
        self.is_method = is_method
        self.arrays = arrays            # this class's array attr names
        self.summary: frozenset[str] = frozenset()
        self.aliases: dict[str, frozenset[str]] = {}
        self.callsites: dict[int, list[str]] = {}   # id(Call) -> target fids


@register_rule
class ViewEscape(Rule):
    """Public return / history append aliasing live internal arrays."""
    id = "A701"
    title = "view of live internal array escapes without a copy"
    scope = SIM_SCOPE

    def __init__(self) -> None:
        self._by_path: dict[str, list[Finding]] = {}

    # ------------------------------------------------------------ sources
    def _sources(self, node: ast.AST, info: _FnInfo,
                 env: dict[str, frozenset[str]]) -> frozenset[str]:
        """Which of {"self"} ∪ params the value of ``node`` may alias."""
        if isinstance(node, ast.Name):
            if node.id in ("self", "cls") and info.is_method:
                return frozenset(("self",))
            if node.id in env:
                return env[node.id]
            if node.id in info.params:
                return frozenset((node.id,))
            return frozenset()
        if isinstance(node, ast.Attribute):
            chain = dotted(node)
            if chain and chain[0] == "self" and len(chain) == 2 \
                    and chain[1] in info.arrays:
                return frozenset(("self",))
            return frozenset()
        if isinstance(node, ast.Subscript):
            base = self._sources(node.value, info, env)
            if not base:
                return frozenset()
            sl = node.slice
            if isinstance(sl, ast.Slice) or (
                    isinstance(sl, ast.Constant)
                    and isinstance(sl.value, int)):
                return base                     # view-preserving index
            if isinstance(sl, ast.UnaryOp) \
                    and isinstance(sl.operand, ast.Constant):
                return base
            if isinstance(sl, ast.Tuple) and all(
                    isinstance(e, (ast.Slice, ast.Constant))
                    for e in sl.elts):
                return base
            return frozenset()                  # fancy indexing copies
        if isinstance(node, (ast.Tuple, ast.List)):
            out: frozenset[str] = frozenset()
            for e in node.elts:
                out |= self._sources(e, info, env)
            return out
        if isinstance(node, ast.Dict):
            out = frozenset()
            for v in node.values:
                out |= self._sources(v, info, env)
            return out
        if isinstance(node, ast.Starred):
            return self._sources(node.value, info, env)
        if isinstance(node, ast.IfExp):
            return self._sources(node.body, info, env) \
                | self._sources(node.orelse, info, env)
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
            # tuple/list + concatenates views; array + allocates.  Union
            # is the safe over-approximation either way.
            return self._sources(node.left, info, env) \
                | self._sources(node.right, info, env)
        if isinstance(node, (ast.ListComp, ast.GeneratorExp, ast.SetComp)):
            # a comprehension allocates a NEW container whose elements
            # alias the iterated values: [s for s in runs] keeps every
            # view; [s.copy() for s in runs] launders elementwise
            elt_env = dict(env)
            for gen in node.generators:
                gen_src = self._sources(gen.iter, info, elt_env)
                if isinstance(gen.target, ast.Name):
                    elt_env[gen.target.id] = gen_src
                else:
                    # tuple-destructuring targets (for s, d in edges)
                    # extract element FIELDS, overwhelmingly scalars in
                    # this codebase — treated as laundering; whole-row
                    # aliasing uses a bare name target
                    for n in ast.walk(gen.target):
                        if isinstance(n, ast.Name):
                            elt_env[n.id] = frozenset()
            return self._sources(node.elt, info, elt_env)
        if isinstance(node, ast.Call):
            return self._call_sources(node, info, env)
        if isinstance(node, ast.NamedExpr):
            return self._sources(node.value, info, env)
        return frozenset()

    def _call_sources(self, node: ast.Call, info: _FnInfo,
                      env: dict[str, frozenset[str]]) -> frozenset[str]:
        chain = dotted(node.func)
        if chain:
            if chain[0] in ("np", "numpy", "jnp"):
                if chain[-1] == "asarray":
                    # asarray is a no-copy cast — aliasing passes through
                    return (self._sources(node.args[0], info, env)
                            if node.args else frozenset())
                if chain[-1] in _LAUNDER_NP:
                    return frozenset()
            if chain[-1] in ("copy", "deepcopy") and len(chain) <= 2:
                if len(chain) == 2 and chain[0] not in ("copy",):
                    return frozenset()      # x.copy() launders
                return frozenset()          # copy.copy / copy.deepcopy
            if len(chain) >= 2 and chain[-1] in _LAUNDER_METHODS:
                return frozenset()
            if chain in (("tuple",), ("list",)) and len(node.args) == 1:
                # tuple(xs) re-wraps the container but keeps element
                # aliasing; tuple(a.copy() for a in xs) launders through
                # the comprehension rule above.
                return self._sources(node.args[0], info, env)
            if chain[-1] == "append":
                return frozenset()
        # in-program callee: apply its summary to this site's arguments
        targets = info.callsites.get(id(node), ())
        out: frozenset[str] = frozenset()
        for tfid in targets:
            tinfo = self._infos.get(tfid)
            if tinfo is None:
                continue
            summ = tinfo.summary
            if "self" in summ:
                recv = node.func
                if isinstance(recv, ast.Attribute):
                    out |= self._sources(recv.value, info, env)
            for i, p in enumerate(tinfo.params):
                if p in summ and i < len(node.args):
                    out |= self._sources(node.args[i], info, env)
        return out

    # ------------------------------------------------------------ summary
    def _local_env(self, info: _FnInfo) -> dict[str, frozenset[str]]:
        """Forward pass over simple assignments (linear, last-write-wins
        in statement order — adequate for the SoA helper style)."""
        env: dict[str, frozenset[str]] = {}
        for node in ast.walk(info.fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not info.fn:
                pass            # nested defs get their own summaries
        for stmt in self._linear_stmts(info.fn.body):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                env[stmt.targets[0].id] = self._sources(
                    stmt.value, info, env)
            elif isinstance(stmt, ast.Assign) \
                    and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Tuple) \
                    and isinstance(stmt.value, ast.Tuple) \
                    and len(stmt.targets[0].elts) == len(stmt.value.elts):
                for t, v in zip(stmt.targets[0].elts, stmt.value.elts):
                    if isinstance(t, ast.Name):
                        env[t.id] = self._sources(v, info, env)
            elif isinstance(stmt, ast.Assign):
                src = self._sources(stmt.value, info, env)
                for t in stmt.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name) and isinstance(
                                t, (ast.Name, ast.Tuple)):
                            env[n.id] = src
        return env

    def _linear_stmts(self, body):
        for stmt in body:
            yield stmt
            for attr in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, attr, None)
                if sub and not isinstance(
                        stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                    yield from self._linear_stmts(sub)
            for h in getattr(stmt, "handlers", ()):
                yield from self._linear_stmts(h.body)

    def _summarize(self, info: _FnInfo) -> frozenset[str]:
        env = self._local_env(info)
        info.aliases = env
        out: frozenset[str] = frozenset()
        for stmt in self._linear_stmts(info.fn.body):
            if isinstance(stmt, ast.Return) and stmt.value is not None:
                out |= self._sources(stmt.value, info, env)
        return out

    # ------------------------------------------------------------ prepare
    def prepare(self, units: list[FileUnit]) -> None:
        self._by_path = {}
        cg = get_callgraph(units)
        self._infos: dict[str, _FnInfo] = {}
        arrays_by_cls: dict[tuple[str, str], set[str]] = {}
        for u in units:
            for node in ast.walk(u.tree):
                if isinstance(node, ast.ClassDef):
                    arrays_by_cls[(u.relpath, node.name)] = array_attrs(node)
        for fid, fn in cg.nodes.items():
            if fn.node is None or not isinstance(
                    fn.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            args = list(fn.node.args.args)
            is_method = fn.cls is not None and bool(args) \
                and args[0].arg in ("self", "cls")
            params = [a.arg for a in (args[1:] if is_method else args)]
            arrays = arrays_by_cls.get((fn.relpath, fn.cls), set()) \
                if fn.cls else set()
            self._infos[fid] = _FnInfo(fid, cg.unit_of[fid], fn.node,
                                       params, is_method, arrays)
        for site in cg.sites:
            info = self._infos.get(site.caller)
            if info is not None:
                info.callsites[id(site.call)] = [
                    t for t in site.targets if t in self._infos]
        # bottom-up fixpoint (summaries only grow; bounded lattice)
        for _ in range(8):
            changed = False
            for fid in sorted(self._infos):
                info = self._infos[fid]
                new = self._summarize(info)
                if new != info.summary:
                    info.summary = new
                    changed = True
            if not changed:
                break
        # findings
        for fid in sorted(self._infos):
            info = self._infos[fid]
            if not _sim(info.unit.relpath):
                continue
            self._check_public_returns(info)
            self._check_history_appends(info)

    def _check_public_returns(self, info: _FnInfo) -> None:
        name = info.fn.name
        if name.startswith("_"):
            return
        if "self" not in info.summary or not info.is_method:
            return
        env = info.aliases
        for stmt in self._linear_stmts(info.fn.body):
            if isinstance(stmt, ast.Return) \
                    and isinstance(stmt.value, ast.Name) \
                    and stmt.value.id in ("self", "cls"):
                continue    # fluent/identity idiom: the caller already
                            # holds the receiver, nothing new escapes
            if isinstance(stmt, ast.Return) and stmt.value is not None \
                    and "self" in self._sources(stmt.value, info, env):
                self._emit(info, stmt,
                           f"public method {name}() returns a view of "
                           f"live internal array storage — callers can "
                           f"mutate state in place or observe later "
                           f"updates; return copies (x.copy() / "
                           f"np.array(x)) at the public surface")

    def _check_history_appends(self, info: _FnInfo) -> None:
        env = info.aliases
        for node in ast.walk(info.fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if not (isinstance(f, ast.Attribute) and f.attr == "append"):
                continue
            owner = dotted(f.value)
            if not owner or owner[-1] != "history":
                continue
            for arg in node.args:
                if "self" in self._sources(arg, info, env):
                    self._emit(info, node,
                               "history row aliases live internal array "
                               "storage — the recorded value changes "
                               "after later updates; append a copy")
                    break

    def _emit(self, info: _FnInfo, node: ast.AST, msg: str) -> None:
        f = info.unit.finding(self, node, msg)
        self._by_path.setdefault(info.unit.relpath, []).append(f)

    def visit(self, unit: FileUnit) -> list[Finding]:
        return list(self._by_path.get(unit.relpath, ()))
