"""Interprocedural nondeterminism passes.

**T501 — nondeterminism reachability.**  R305 bans ``random``/``time``/
``datetime``/``uuid``/``secrets`` *imports* in the 11 golden-trace-critical
modules; nothing stopped a golden function from calling a helper in a
non-golden module that reads the wall clock.  T501 closes that hole with
a call-graph proof: every function that (transitively) calls a
nondeterminism sink is *tainted*, and every call edge whose caller lives
in a golden module and whose callee is tainted is a finding — reported
at the call site, with the reconstructed path down to the sink.  Direct
sink calls are deliberately NOT re-reported here: those are D101/D102's
(and R305's) per-file job; T501 owns the edges the per-file rules cannot
see.

**T502 — transitive non-stable sort.**  D103 polices ``np.argsort``
without ``kind="stable"`` inside sim-scope files; a sim function calling
into a jax-side helper (models/, kernels/, ...) that sorts unstably
escapes it.  T502 sweeps call sites in sim-scope functions whose callee
chain — through *non-sim* files only, so D103 keeps sole ownership of
its scope — reaches a non-stable ``argsort``.

Sink definitions mirror D101/D102 exactly (unseeded RNG constructors and
global-state RNG calls; whole wall-clock-ish modules), but match the
*alias-expanded* chain, so ``import time as t; t.time()`` is still a
sink.  Fixture convention: paths outside ``src/repro/`` count as golden
AND sim AND non-sim at once — the same full-panel convention the
per-file rules use, which lets a single fixture file exercise an
inherently cross-file property.

**The observability carve-out.**  The obs layer (``src/repro/obs/``) may
read ``time.perf_counter`` to price its own overhead
(``Tracer.self_profile``, registry ``Timer``).  That is a *write-only*
side channel: a golden function calling ``self.tracer.record(...)`` as a
bare statement throws the result away, so no clock value can flow back
into a decision.  T501 therefore refuses to propagate taint across a
call site when (a) every tainted target lives under ``src/repro/obs/``
AND (b) the call's value is discarded (the call is the whole of an
``ast.Expr`` statement).  This is scoped at the *propagation* level, not
a blanket module exemption: an obs value that IS captured
(``x = tracer.record(...)``, ``if registry.timer(...)``) still taints the
caller and is reported — the proof obligation stays "no obs value
reaches a golden decision", checked per edge.
"""
from __future__ import annotations

import ast

from tools.lint.core import FileUnit, Finding, Rule, dotted, get_callgraph, \
    register_rule
from tools.lint.callgraph import CallGraph, CallSite
from tools.lint.rules import GOLDEN_MODULES, SIM_SCOPE, UnseededRandom

_CLOCKISH = ("time", "datetime", "uuid", "secrets")


def _fixture(relpath: str) -> bool:
    return not relpath.startswith("src/repro/")


def _golden(relpath: str) -> bool:
    return _fixture(relpath) or relpath in GOLDEN_MODULES


def _sim(relpath: str) -> bool:
    return _fixture(relpath) or relpath.startswith(SIM_SCOPE)


def _non_sim(relpath: str) -> bool:
    return _fixture(relpath) or not relpath.startswith(SIM_SCOPE)


OBS_SCOPE = "src/repro/obs/"


def _obs(relpath: str) -> bool:
    """Is this file part of the write-only observability layer?  NOT
    fixture-widened: the carve-out must only ever apply to the real obs
    package (a test fixture opts in by using an ``src/repro/obs/``
    pretend path)."""
    return relpath.startswith(OBS_SCOPE)


def _discarded(cg: CallGraph, site: CallSite) -> bool:
    """True when the call's value is thrown away — the call expression is
    the whole of an ``ast.Expr`` statement in its caller's body (module
    body for the synthetic ``<module>`` function)."""
    fn = cg.nodes[site.caller].node
    tree = fn if fn is not None else cg.unit_of[site.caller].tree
    for node in ast.walk(tree):
        if isinstance(node, ast.Expr) and node.value is site.call:
            return True
    return False


def _obs_exempt(cg: CallGraph, site: CallSite, bad: list[str]) -> bool:
    """The observability carve-out (module docstring): a *discarded* call
    whose every tainted target lives in the obs layer cannot feed a clock
    value back into a decision, so taint must not cross this edge."""
    return all(_obs(cg.nodes[t].relpath) for t in bad) \
        and _discarded(cg, site)


def sink_label(site: CallSite) -> str | None:
    """The sink this external call hits, or None.  Mirrors D101/D102 on
    the alias-expanded chain."""
    ch = site.external
    if not ch:
        return None
    if ch[0] in _CLOCKISH:
        return ".".join(ch)
    if ch[0] == "random" and len(ch) >= 2:
        if ch[1] in UnseededRandom._RANDOM_FNS:
            return ".".join(ch)
        if ch[1] == "Random" and not site.call.args and not site.call.keywords:
            return "random.Random"        # unseeded
    if ch[0] == "numpy" and len(ch) >= 2 and ch[1] == "random":
        if ch[-1] == "default_rng" and not site.call.args \
                and not site.call.keywords:
            return "numpy.random.default_rng"   # unseeded
        if len(ch) == 3 and ch[2] in UnseededRandom._NP_GLOBAL_FNS:
            return ".".join(ch)
    return None


def _sink_chain(cg: CallGraph, fid: str, parent: dict[str, str],
                direct: dict[str, str]) -> tuple[list[str], str]:
    """(qualname path from fid to the sinking function, sink name)."""
    names, cur = [], fid
    for _ in range(32):
        names.append(cg.nodes[cur].label)
        if cur in direct:
            return names, direct[cur]
        cur = parent[cur]
    return names, "?"


@register_rule
class TaintReachability(Rule):
    """Golden-module call edge reaching a nondeterminism sink."""
    id = "T501"
    title = "golden-module call transitively reaches a nondeterminism sink"

    def __init__(self) -> None:
        self._by_path: dict[str, list[Finding]] = {}

    def prepare(self, units: list[FileUnit]) -> None:
        self._by_path = {}
        cg = get_callgraph(units)
        direct: dict[str, str] = {}
        for site in cg.sites:
            lbl = sink_label(site)
            if lbl is not None and site.caller not in direct:
                direct[site.caller] = lbl
        if not direct:
            return
        # site-level taint fixpoint rather than cg.reverse_closure:
        # propagation must be able to REFUSE an edge (the obs carve-out
        # needs the call *expression*, which the fid-level reverse graph
        # has already erased).  Nested-def containment edges have no call
        # site, so they propagate unconditionally, as before.
        site_pairs = {(s.caller, t) for s in cg.sites for t in s.targets}
        nested = sorted((o, t) for o, ts in cg.edges.items()
                        for t in ts if (o, t) not in site_pairs)
        tainted, parent = set(direct), {}
        changed = True
        while changed:
            changed = False
            for site in cg.sites:
                if site.caller in tainted:
                    continue
                bad = sorted(t for t in site.targets if t in tainted)
                if not bad or _obs_exempt(cg, site, bad):
                    continue
                tainted.add(site.caller)
                parent[site.caller] = bad[0]
                changed = True
            for o, t in nested:
                if o not in tainted and t in tainted:
                    tainted.add(o)
                    parent[o] = t
                    changed = True
        for site in cg.sites:
            caller = cg.nodes[site.caller]
            if not _golden(caller.relpath):
                continue
            bad = sorted(t for t in site.targets if t in tainted)
            if not bad or _obs_exempt(cg, site, bad):
                continue
            chain, sink = _sink_chain(cg, bad[0], parent, direct)
            unit = cg.unit_of[site.caller]
            self._by_path.setdefault(unit.relpath, []).append(unit.finding(
                self, site.call,
                f"call reaches nondeterminism sink {sink}(...) via "
                f"{' -> '.join(chain)} — golden-trace-critical modules "
                f"must be pure functions of (seed, inputs); thread an "
                f"explicit seed/engine.now through the callee instead"))

    def visit(self, unit: FileUnit) -> list[Finding]:
        return list(self._by_path.get(unit.relpath, ()))


@register_rule
class TransitiveUnstableSort(Rule):
    """Sim-scope call whose callee chain performs a non-stable argsort
    outside D103's per-file scope."""
    id = "T502"
    title = "sim-scope call reaches a non-stable argsort in jax-side code"

    _STABLE_KINDS = {"stable", "mergesort"}

    def __init__(self) -> None:
        self._by_path: dict[str, list[Finding]] = {}

    def _has_unstable_sort(self, fn: ast.AST) -> bool:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = dotted(node.func)
            if not chain or chain[-1] != "argsort":
                continue
            kind = next((kw.value for kw in node.keywords
                         if kw.arg == "kind"), None)
            if kind is None or not (isinstance(kind, ast.Constant)
                                    and kind.value in self._STABLE_KINDS):
                return True
        return False

    def prepare(self, units: list[FileUnit]) -> None:
        self._by_path = {}
        cg = get_callgraph(units)
        roots = {fid for fid, fn in cg.nodes.items()
                 if fn.node is not None and _non_sim(fn.relpath)
                 and self._has_unstable_sort(fn.node)}
        if not roots:
            return
        # close the taint through NON-sim files only: a sim-file
        # intermediary gets its own finding at ITS outbound call, and
        # D103 keeps sole ownership of sorts inside sim files
        tainted, parent = set(roots), {}
        frontier = sorted(roots)
        while frontier:
            nxt: list[str] = []
            for f in frontier:
                for g in sorted(cg.redges.get(f, ())):
                    if g in tainted:
                        continue
                    tainted.add(g)
                    parent[g] = f
                    if _non_sim(cg.nodes[g].relpath):
                        nxt.append(g)
            frontier = nxt
        for site in cg.sites:
            caller = cg.nodes[site.caller]
            if not _sim(caller.relpath):
                continue
            bad = sorted(t for t in site.targets
                         if t in tainted and _non_sim(cg.nodes[t].relpath))
            if not bad:
                continue
            chain = cg.chain(bad[0], parent, roots)
            unit = cg.unit_of[site.caller]
            self._by_path.setdefault(unit.relpath, []).append(unit.finding(
                self, site.call,
                f"call reaches a non-stable argsort via "
                f"{' -> '.join(chain)} — tie order there depends on the "
                f"sort algorithm; ordering-sensitive sim logic must rank "
                f"ties deterministically (kind=\"stable\")"))

    def visit(self, unit: FileUnit) -> list[Finding]:
        return list(self._by_path.get(unit.relpath, ()))
