"""reprolint — AST-based invariant linter for the repro codebase.

Run it as ``python -m tools.lint`` (see ``--help``); the framework is
:mod:`tools.lint.core`, the rule panel :mod:`tools.lint.rules`, and the
grandfathered findings live in ``tools/lint/baseline.json``.
"""
from tools.lint.core import (Finding, LintResult, Rule, all_rules,
                             lint_paths, lint_source, load_baseline,
                             register_rule, split_new, write_baseline)

__all__ = ["Finding", "LintResult", "Rule", "all_rules", "lint_paths",
           "lint_source", "load_baseline", "register_rule", "split_new",
           "write_baseline"]
