"""reprolint — AST-based invariant linter for the repro codebase.

Run it as ``python -m tools.lint`` (see ``--help``); the framework is
:mod:`tools.lint.core`, the per-file rule panel :mod:`tools.lint.rules`,
the interprocedural passes :mod:`tools.lint.taint` /
:mod:`tools.lint.bitwidth` / :mod:`tools.lint.effects` (all sharing the
call graph from :mod:`tools.lint.callgraph`), and the grandfathered
findings live in ``tools/lint/baseline.json``.
"""
from tools.lint.core import (Finding, LintResult, Program, Rule, all_rules,
                             get_callgraph, lint_paths, lint_source,
                             load_baseline, parse_file, register_rule,
                             split_new, write_baseline)

__all__ = ["Finding", "LintResult", "Program", "Rule", "all_rules",
           "get_callgraph", "lint_paths", "lint_source", "load_baseline",
           "parse_file", "register_rule", "split_new", "write_baseline"]
