"""reprolint CLI.

    python -m tools.lint                      # lint src/repro vs baseline
    python -m tools.lint --fail-on-new        # CI mode: exit 1 on any NEW
                                              # (non-grandfathered) finding
    python -m tools.lint --json               # machine-readable report
    python -m tools.lint --write-baseline     # grandfather current findings
    python -m tools.lint --self-check         # run the fixture suite
    python -m tools.lint --list-rules         # the rule panel
    python -m tools.lint --changed-only       # analyze everything, report
                                              # only git-changed files

Default paths: ``src/repro``.  Default baseline:
``tools/lint/baseline.json`` (auto-loaded when it exists; pass
``--baseline ''`` to compare against nothing).  Suppress one finding with
``# reprolint: ignore[RULE]`` on its line; docs/static-analysis.md has the
rule table and the how-to-add-a-rule walkthrough.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys

# allow `python tools/lint/__main__.py` as well as `python -m tools.lint`
sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))

from collections import Counter

from tools.lint.core import (DEFAULT_BASELINE, DEFAULT_PATHS, REPO,
                             all_rules, collect_files, lint_paths,
                             lint_source, load_baseline, split_new,
                             write_baseline)

FIXTURES = os.path.join(REPO, "tools", "lint", "fixtures")
_AS_DIRECTIVE = re.compile(r"^#\s*as:\s*(\S+)\s*$", re.MULTILINE)
_EXPECT = re.compile(r"#\s*expect:\s*([A-Z][0-9]+(?:\s*,\s*[A-Z][0-9]+)*)")


def self_check(verbose: bool = True) -> int:
    """Run the rule panel over its own fixtures: every ``# expect: RULE``
    line must produce exactly that finding, and nothing else may fire.
    Exercises rule logic AND the pretend-path scoping (fixtures declare
    their effective path with a leading ``# as: src/repro/...`` line)."""
    failures: list[str] = []
    names = sorted(f for f in os.listdir(FIXTURES) if f.endswith(".py"))
    if not names:
        print("self-check: no fixtures found", file=sys.stderr)
        return 1
    for name in names:
        fpath = os.path.join(FIXTURES, name)
        with open(fpath, encoding="utf-8") as f:
            src = f.read()
        m = _AS_DIRECTIVE.search(src)
        relpath = m.group(1) if m else f"tools/lint/fixtures/{name}"
        expected: set[tuple[int, str]] = set()
        for i, line in enumerate(src.splitlines(), 1):
            em = _EXPECT.search(line)
            if em:
                for rule in re.split(r"\s*,\s*", em.group(1)):
                    expected.add((i, rule))
        got = {(f.line, f.rule)
               for f in lint_source(src, relpath).findings}
        missing = expected - got
        surprise = got - expected
        status = "ok " if not missing and not surprise else "FAIL"
        if verbose:
            print(f"[{status}] {name} (as {relpath}): "
                  f"{len(expected)} expected, {len(got)} found")
        for line, rule in sorted(missing):
            failures.append(f"{name}:{line}: expected {rule}, not found")
        for line, rule in sorted(surprise):
            failures.append(f"{name}:{line}: unexpected {rule}")
    for msg in failures:
        print(f"self-check FAIL: {msg}", file=sys.stderr)
    print(f"self-check: {len(names)} fixtures, {len(failures)} failures")
    return 1 if failures else 0


def changed_relpaths() -> set[str]:
    """Repo-relative paths touched vs HEAD (staged + unstaged + untracked).
    The *reported* scope for ``--changed-only``; the whole program is
    still parsed and analyzed so interprocedural facts stay exact."""
    out: set[str] = set()
    for cmd in (["git", "diff", "--name-only", "HEAD"],
                ["git", "ls-files", "--others", "--exclude-standard"]):
        proc = subprocess.run(cmd, cwd=REPO, capture_output=True, text=True)
        if proc.returncode != 0:
            raise RuntimeError(
                f"--changed-only needs git: `{' '.join(cmd)}` failed: "
                f"{proc.stderr.strip()}")
        out.update(line.strip().replace(os.sep, "/")
                   for line in proc.stdout.splitlines() if line.strip())
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="reprolint: AST-based invariant linter (determinism, "
                    "float accounting, registry discipline, units)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: src/repro)")
    ap.add_argument("--baseline", default=None, metavar="JSON",
                    help="baseline file of grandfathered findings "
                    "(default: tools/lint/baseline.json when it exists; "
                    "pass '' for none)")
    ap.add_argument("--fail-on-new", action="store_true",
                    help="exit 1 when any finding is not in the baseline")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit a JSON report instead of text")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline file and "
                    "exit 0")
    ap.add_argument("--rules", default=None, metavar="IDS",
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule panel and exit")
    ap.add_argument("--self-check", action="store_true",
                    help="lint the bundled fixtures against their "
                    "annotations and exit")
    ap.add_argument("--changed-only", action="store_true",
                    help="report findings only in files changed vs HEAD "
                    "(git diff + untracked); the whole program is still "
                    "analyzed so interprocedural results are identical")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-finding text output")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            scope = ", ".join(rule.scope) if rule.scope else "src/repro"
            print(f"{rule.id}  [{rule.severity:7s}]  {rule.title}"
                  f"\n       scope: {scope}"
                  + (f"\n       exempt: {', '.join(rule.exempt)}"
                     if rule.exempt else ""))
        return 0
    if args.self_check:
        return self_check(verbose=not args.quiet)

    only = None
    if args.rules:
        only = {r.strip() for r in args.rules.split(",") if r.strip()}
    rules = all_rules(only)
    paths = args.paths or list(DEFAULT_PATHS)
    emit_only = None
    if args.changed_only:
        if args.write_baseline:
            ap.error("--changed-only cannot be combined with "
                     "--write-baseline (baselines must cover the whole "
                     "program)")
        try:
            changed = changed_relpaths()
        except RuntimeError as exc:
            print(f"error: {exc}", file=sys.stderr)
            return 2
        emit_only = set(collect_files(paths)) & changed
    result = lint_paths(paths, rules, emit_only=emit_only)

    baseline_path = args.baseline
    if baseline_path is None and os.path.exists(DEFAULT_BASELINE):
        baseline_path = DEFAULT_BASELINE
    if args.write_baseline:
        out = baseline_path or DEFAULT_BASELINE
        write_baseline(out, result.findings)
        print(f"wrote {len(result.findings)} finding(s) to "
              f"{os.path.relpath(out, REPO)}")
        return 0
    baseline = Counter()
    if baseline_path:
        baseline = load_baseline(baseline_path)
    new, grandfathered = split_new(result.findings, baseline)

    if args.as_json:
        doc = {"version": 1,
               "files": result.files,
               "findings": [dict(f.to_dict(), baselined=False)
                            for f in new]
               + [dict(f.to_dict(), baselined=True)
                  for f in grandfathered],
               "counts": dict(sorted(Counter(
                   f.rule for f in result.findings).items())),
               "new": len(new),
               "baselined": len(grandfathered),
               "suppressed": result.suppressed}
        json.dump(doc, sys.stdout, indent=2)
        print()
    else:
        if not args.quiet:
            for f in new:
                print(f.render())
            for f in grandfathered:
                print(f"{f.render()}  [baselined]")
        scope = (f" ({len(emit_only)} changed reported)"
                 if emit_only is not None else "")
        print(f"reprolint: {result.files} files{scope},"
              f" {len(new)} new finding(s),"
              f" {len(grandfathered)} baselined,"
              f" {result.suppressed} suppressed")
    # exit status keys on NEW findings in both modes: grandfathered
    # findings are deliberate debt (the baseline is committed), so a
    # plain run stays green until someone introduces a fresh violation.
    # --fail-on-new is kept as the explicit CI spelling of the same gate.
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
