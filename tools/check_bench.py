#!/usr/bin/env python
"""Schema check for BENCH_cluster.json (the fleet-driver bench output).

CI runs the fleet bench smoke and then this checker; any drift in the
emitted schema — renamed keys, wrong types, impossible counts — fails the
build instead of silently producing an unplottable artifact.

    python tools/check_bench.py [BENCH_cluster.json]
"""
from __future__ import annotations

import json
import sys

SCHEMA_VERSION = 1

# key -> required type(s); bool is an int subclass, so exclude it where
# a genuine number is meant
RUN_KEYS = {
    "tenants": int,
    "windows": int,
    "tenant_windows": int,
    "admission": str,
    "denied_tenant_windows": int,
    "deferred_tenant_windows": int,
    "preempted_tenant_windows": int,
    "policy_steps": int,
    "peak_cpu": int,
    "peak_mem_mb": (int, float),
    "cluster_cpu_slots": int,
    "cluster_memory_mb": (int, float),
    "seconds": (int, float),
    "tenant_windows_per_s": (int, float),
    "driver": str,
    "seed": int,
}


def check(data) -> list[str]:
    errors: list[str] = []
    if not isinstance(data, dict):
        return ["top level is not an object"]
    if data.get("bench") != "cluster_fleet":
        errors.append(f"bench != 'cluster_fleet': {data.get('bench')!r}")
    if data.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"schema_version != {SCHEMA_VERSION}: "
                      f"{data.get('schema_version')!r}")
    runs = data.get("runs")
    if not isinstance(runs, list) or not runs:
        return errors + ["runs is not a non-empty list"]
    for i, run in enumerate(runs):
        if not isinstance(run, dict):
            errors.append(f"runs[{i}] is not an object")
            continue
        for key, typ in RUN_KEYS.items():
            if key not in run:
                errors.append(f"runs[{i}] missing key {key!r}")
            elif not isinstance(run[key], typ) \
                    or isinstance(run[key], bool):
                want = typ.__name__ if isinstance(typ, type) \
                    else "/".join(t.__name__ for t in typ)
                errors.append(f"runs[{i}][{key!r}] has type "
                              f"{type(run[key]).__name__}, want {want}")
        if errors:
            continue
        # internal consistency: the headline must be derivable
        if run["tenant_windows"] != run["tenants"] * run["windows"]:
            errors.append(f"runs[{i}]: tenant_windows != "
                          "tenants * windows")
        if run["seconds"] <= 0 or run["tenant_windows_per_s"] <= 0:
            errors.append(f"runs[{i}]: non-positive throughput")
        if run["peak_cpu"] > run["cluster_cpu_slots"]:
            errors.append(f"runs[{i}]: peak_cpu exceeds the cluster")
        if run["peak_mem_mb"] > run["cluster_memory_mb"] + 1e-9:
            errors.append(f"runs[{i}]: peak_mem_mb exceeds the cluster")
        for key in ("denied_tenant_windows", "deferred_tenant_windows",
                    "preempted_tenant_windows", "policy_steps"):
            if run[key] < 0:
                errors.append(f"runs[{i}][{key!r}] is negative")
    return errors


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_cluster.json"
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench: cannot read {path}: {e}")
        return 1
    errors = check(data)
    for e in errors:
        print(f"check_bench: {path}: {e}")
    if not errors:
        print(f"check_bench: {path}: ok "
              f"({len(data['runs'])} runs, schema v{SCHEMA_VERSION})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
