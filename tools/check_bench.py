#!/usr/bin/env python
"""Schema + regression check for committed bench artifacts.

Dispatches on the artifact's ``bench`` tag:

* ``cluster_fleet`` — BENCH_cluster.json, the fleet-driver bench
* ``lsm_store``     — BENCH_lsm.json, the legacy-vs-columnar store A/B

CI runs the bench and then this checker; any drift in the emitted
schema — renamed keys, wrong types, impossible counts — fails the build
instead of silently producing an unplottable artifact.

With ``--baseline PATH`` the headline metric is also compared against a
committed reference artifact of the same bench kind, and the check fails
on a regression of more than REGRESSION_TOLERANCE (20%) — the gate that
keeps the columnar store's speedup from silently rotting.

    python tools/check_bench.py [ARTIFACT.json] [--baseline PATH]
"""
from __future__ import annotations

import argparse
import json
import sys

SCHEMA_VERSION = 1
REGRESSION_TOLERANCE = 0.20          # fail if headline drops >20%

# key -> required type(s); bool is an int subclass, so exclude it where
# a genuine number is meant
FLEET_RUN_KEYS = {
    "tenants": int,
    "windows": int,
    "tenant_windows": int,
    "admission": str,
    "denied_tenant_windows": int,
    "deferred_tenant_windows": int,
    "preempted_tenant_windows": int,
    "policy_steps": int,
    "peak_cpu": int,
    "peak_mem_mb": (int, float),
    "cluster_cpu_slots": int,
    "cluster_memory_mb": (int, float),
    "seconds": (int, float),
    "tenant_windows_per_s": (int, float),
    "driver": str,
    "seed": int,
}

LSM_RUN_KEYS = {
    "impl": str,
    "query": str,
    "policy": str,
    "seed": int,
    "repeats": int,
    "seconds": list,
    "seconds_min": (int, float),
    "steps": int,
    "achieved_rate": (int, float),
}


def _check_run_keys(run: dict, i: int, schema: dict) -> list[str]:
    errors = []
    for key, typ in schema.items():
        if key not in run:
            errors.append(f"runs[{i}] missing key {key!r}")
        elif not isinstance(run[key], typ) or isinstance(run[key], bool):
            want = typ.__name__ if isinstance(typ, type) \
                else "/".join(t.__name__ for t in typ)
            errors.append(f"runs[{i}][{key!r}] has type "
                          f"{type(run[key]).__name__}, want {want}")
    return errors


def _check_common(data) -> tuple[list[str], list]:
    if not isinstance(data, dict):
        return ["top level is not an object"], []
    errors = []
    if data.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"schema_version != {SCHEMA_VERSION}: "
                      f"{data.get('schema_version')!r}")
    runs = data.get("runs")
    if not isinstance(runs, list) or not runs:
        return errors + ["runs is not a non-empty list"], []
    return errors, runs


def check_cluster_fleet(data) -> list[str]:
    errors, runs = _check_common(data)
    for i, run in enumerate(runs):
        if not isinstance(run, dict):
            errors.append(f"runs[{i}] is not an object")
            continue
        key_errors = _check_run_keys(run, i, FLEET_RUN_KEYS)
        if key_errors:
            errors += key_errors
            continue
        # internal consistency: the headline must be derivable
        if run["tenant_windows"] != run["tenants"] * run["windows"]:
            errors.append(f"runs[{i}]: tenant_windows != tenants * windows")
        if run["seconds"] <= 0 or run["tenant_windows_per_s"] <= 0:
            errors.append(f"runs[{i}]: non-positive throughput")
        if run["peak_cpu"] > run["cluster_cpu_slots"]:
            errors.append(f"runs[{i}]: peak_cpu exceeds the cluster")
        if run["peak_mem_mb"] > run["cluster_memory_mb"] + 1e-9:
            errors.append(f"runs[{i}]: peak_mem_mb exceeds the cluster")
        for key in ("denied_tenant_windows", "deferred_tenant_windows",
                    "preempted_tenant_windows", "policy_steps"):
            if run[key] < 0:
                errors.append(f"runs[{i}][{key!r}] is negative")
    return errors


def check_lsm_store(data) -> list[str]:
    errors, runs = _check_common(data)
    if not isinstance(data.get("speedup"), (int, float)) \
            or isinstance(data.get("speedup"), bool):
        errors.append(f"speedup is not a number: {data.get('speedup')!r}")
    mins: dict[str, float] = {}
    for i, run in enumerate(runs):
        if not isinstance(run, dict):
            errors.append(f"runs[{i}] is not an object")
            continue
        key_errors = _check_run_keys(run, i, LSM_RUN_KEYS)
        if key_errors:
            errors += key_errors
            continue
        secs = run["seconds"]
        if len(secs) != run["repeats"] or not all(
                isinstance(s, (int, float)) and not isinstance(s, bool)
                and s > 0 for s in secs):
            errors.append(f"runs[{i}]: seconds is not {run['repeats']} "
                          "positive numbers")
            continue
        if abs(run["seconds_min"] - min(secs)) > 1e-9:
            errors.append(f"runs[{i}]: seconds_min != min(seconds)")
        if run["achieved_rate"] <= 0:
            errors.append(f"runs[{i}]: non-positive achieved_rate")
        mins[run["impl"]] = run["seconds_min"]
    if not errors:
        if set(mins) != {"legacy", "columnar"}:
            errors.append(f"impls != {{legacy, columnar}}: {sorted(mins)}")
        else:
            derived = mins["legacy"] / mins["columnar"]
            if abs(derived - data["speedup"]) > 0.01:
                errors.append(f"speedup {data['speedup']} is not "
                              f"legacy_min/columnar_min ({derived:.3f})")
    return errors


CHECKERS = {
    "cluster_fleet": check_cluster_fleet,
    "lsm_store": check_lsm_store,
}

# headline metric per bench kind: (extractor, higher_is_better)
HEADLINES = {
    "cluster_fleet": lambda d: max(r["tenant_windows_per_s"]
                                   for r in d["runs"]),
    "lsm_store": lambda d: d["speedup"],
}


def check(data) -> list[str]:
    if not isinstance(data, dict):
        return ["top level is not an object"]
    kind = data.get("bench")
    checker = CHECKERS.get(kind)
    if checker is None:
        return [f"unknown bench kind {kind!r} "
                f"(want one of {sorted(CHECKERS)})"]
    return checker(data)


def check_baseline(data, base) -> list[str]:
    """Headline regression gate: fail when the current artifact's headline
    metric (both benches: higher is better) drops more than
    REGRESSION_TOLERANCE below the committed baseline's."""
    if data.get("bench") != base.get("bench"):
        return [f"baseline bench kind {base.get('bench')!r} does not match "
                f"artifact {data.get('bench')!r}"]
    extract = HEADLINES[data["bench"]]
    cur, ref = extract(data), extract(base)
    floor = ref * (1.0 - REGRESSION_TOLERANCE)
    if cur < floor:
        return [f"headline regression: {cur:.3f} < {floor:.3f} "
                f"(baseline {ref:.3f} - {REGRESSION_TOLERANCE:.0%})"]
    return []


def _load(path: str):
    with open(path) as f:
        return json.load(f)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifact", nargs="?", default="BENCH_cluster.json")
    ap.add_argument("--baseline", metavar="PATH",
                    # %% — argparse %-interpolates help strings, so a bare
                    # "20%" raises TypeError the moment --help renders
                    help="committed reference artifact; fail on >"
                         f"{REGRESSION_TOLERANCE * 100:.0f}%% headline "
                         f"regression")
    args = ap.parse_args()
    try:
        data = _load(args.artifact)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench: cannot read {args.artifact}: {e}")
        return 1
    errors = check(data)
    if not errors and args.baseline:
        try:
            base = _load(args.baseline)
        except (OSError, json.JSONDecodeError) as e:
            print(f"check_bench: cannot read baseline "
                  f"{args.baseline}: {e}")
            return 1
        base_errors = check(base)
        if base_errors:
            errors += [f"baseline {args.baseline}: {e}"
                       for e in base_errors]
        else:
            errors += check_baseline(data, base)
    for e in errors:
        print(f"check_bench: {args.artifact}: {e}")
    if not errors:
        extra = f", headline {HEADLINES[data['bench']](data):.3f}"
        print(f"check_bench: {args.artifact}: ok ({len(data['runs'])} runs, "
              f"schema v{SCHEMA_VERSION}{extra})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
