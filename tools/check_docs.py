"""Docs command checker — CI's guarantee that documentation stays runnable.

Extracts every command from fenced shell blocks in README.md and docs/*.md
and verifies it still parses against the current tree:

* ``python <script>.py ...`` — the script must exist; if it builds an
  argparse CLI it is run with ``--help`` (arg surface must parse), else it
  is byte-compiled (``py_compile``);
* ``python -m <module> ...`` — the module must resolve in the repo (repo
  root or ``src/``); it is run with ``--help`` and every documented long
  flag must appear in the help output (so ``python -m tools.lint
  --fail-on-new`` breaks this job if the flag is renamed);
* ``python -m pytest ...`` / ``pytest ...`` — pytest must be importable;
* ``pip install ...`` — pyproject.toml must exist (never executed: CI
  installs separately and the checker must not mutate the env);
* heredocs (``python - <<EOF``) and non-command lines are skipped.

Exit status is nonzero if any documented command fails, so a doc edit that
references a renamed script or a dropped flag breaks the docs CI job.

    python tools/check_docs.py [--static] [paths...]

``--static`` skips the subprocess ``--help`` smokes (used by the tier-1
test, which only asserts the documented surface exists).
"""
from __future__ import annotations

import argparse
import os
import py_compile
import re
import shlex
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FENCE = re.compile(r"^```(\S*)\s*$")
SHELL_LANGS = {"", "bash", "sh", "shell", "console"}


def doc_files(paths: list[str] | None = None) -> list[str]:
    if paths:
        return paths
    out = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        out += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                      if f.endswith(".md"))
    return out


def shell_blocks(text: str) -> list[str]:
    """Contents of every shell-language fenced code block."""
    blocks, cur, lang = [], None, None
    for line in text.splitlines():
        m = FENCE.match(line.strip())
        if m:
            if cur is None:
                lang = m.group(1).lower()
                cur = []
            else:
                if lang in SHELL_LANGS:
                    blocks.append("\n".join(cur))
                cur, lang = None, None
            continue
        if cur is not None:
            cur.append(line)
    return blocks


def extract_commands(path: str) -> list[str]:
    """Command lines (env prefixes stripped, ``$ `` prompts removed) that
    invoke python/pip/pytest from one markdown file."""
    cmds = []
    with open(path, encoding="utf-8") as f:
        text = f.read()
    for block in shell_blocks(text):
        # join backslash line continuations so multi-line invocations
        # (the form CI itself uses) are checked as one command
        joined = re.sub(r"\\\s*\n\s*", " ", block)
        for raw in joined.splitlines():
            line = raw.strip()
            if line.startswith("$ "):
                line = line[2:]
            if not line or line.startswith("#"):
                continue
            try:
                toks = shlex.split(line, comments=True)
            except ValueError:
                continue
            while toks and re.match(r"^\w+=", toks[0]):   # env prefixes
                toks = toks[1:]
            if toks and toks[0] in ("python", "python3", "pip", "pytest"):
                cmds.append(" ".join(toks))
    return cmds


def _help_smoke(label: str, argv: list[str], toks: list[str]) -> str | None:
    """Run ``argv + --help`` and verify every documented long flag is part
    of the advertised CLI surface.  Shared by script and ``-m`` checks."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    try:
        r = subprocess.run([*argv, "--help"], env=env,
                           capture_output=True, text=True, timeout=120,
                           cwd=REPO)
    except subprocess.TimeoutExpired:
        return f"`{label} --help` hung (>120 s)"
    if r.returncode != 0:
        return f"`{label} --help` exited {r.returncode}: {r.stderr[-300:]}"
    # every long flag the doc uses must still be part of the CLI surface
    # (catches a renamed/dropped --policy, --fail-on-new, ... without
    # running the full command); tokenized so --grid isn't satisfied by
    # --grid-profiles surviving
    help_flags = set(re.findall(r"--[A-Za-z0-9][-A-Za-z0-9_]*", r.stdout))
    missing = [t for t in toks
               if t.startswith("--") and t != "--help"
               and t.split("=", 1)[0] not in help_flags]
    if missing:
        return (f"`{label} --help` does not mention documented flag(s) "
                f"{', '.join(missing)}")
    return None


def _module_file(mod: str) -> str | None:
    """The source file ``python -m mod`` would execute, searched at the
    repo root (tools.*) and under src/ (repro.*); None when unresolvable."""
    for base in (os.path.join(REPO, *mod.split(".")),
                 os.path.join(REPO, "src", *mod.split("."))):
        if os.path.isdir(base):
            main = os.path.join(base, "__main__.py")
            if os.path.exists(main):
                return main
        elif os.path.exists(base + ".py"):
            return base + ".py"
    return None


def check_command(cmd: str, *, static: bool = False) -> str | None:
    """None if the command parses, else a failure description."""
    toks = shlex.split(cmd)
    prog, rest = toks[0], toks[1:]
    if prog == "pip":
        return None if os.path.exists(os.path.join(REPO, "pyproject.toml")) \
            else "pip install documented but pyproject.toml is missing"
    if prog == "pytest" or rest[:2] == ["-m", "pytest"]:
        try:
            import pytest                                   # noqa: F401
            return None
        except ImportError:
            return "pytest documented but not importable"
    if rest and rest[0] == "-":                             # heredoc stdin
        return None
    if rest[:1] == ["-m"] and len(rest) >= 2:
        mod = rest[1]
        mpath = _module_file(mod)
        if mpath is None:
            return f"documented module does not resolve: {mod}"
        with open(mpath, encoding="utf-8") as f:
            src = f.read()
        # no argparse means --help would EXECUTE the module (and e.g.
        # render_experiments rewrites EXPERIMENTS.md): compile-only, like
        # the script path below
        if "argparse" not in src or static:
            try:
                py_compile.compile(mpath, doraise=True)
                return None
            except py_compile.PyCompileError as e:
                return f"{mod} does not compile: {e}"
        return _help_smoke(f"python -m {mod}",
                           [sys.executable, "-m", mod], toks)
    script = next((t for t in rest if t.endswith(".py")), None)
    if script is None:
        return None                                         # e.g. python -c
    spath = os.path.join(REPO, script)
    if not os.path.exists(spath):
        return f"documented script does not exist: {script}"
    with open(spath, encoding="utf-8") as f:
        src = f.read()
    if "argparse" not in src or static:
        try:
            py_compile.compile(spath, doraise=True)
            return None
        except py_compile.PyCompileError as e:
            return f"{script} does not compile: {e}"
    return _help_smoke(script, [sys.executable, spath], toks)


def main() -> int:
    ap = argparse.ArgumentParser(description="smoke-check documented commands")
    ap.add_argument("paths", nargs="*", help="markdown files (default: "
                    "README.md + docs/*.md)")
    ap.add_argument("--static", action="store_true",
                    help="existence/compile checks only, no subprocesses")
    args = ap.parse_args()
    failures, checked = [], 0
    for path in doc_files(args.paths):
        for cmd in extract_commands(path):
            checked += 1
            err = check_command(cmd, static=args.static)
            status = "ok " if err is None else "FAIL"
            print(f"[{status}] {os.path.relpath(path, REPO)}: {cmd}")
            if err is not None:
                failures.append((path, cmd, err))
    for path, cmd, err in failures:
        print(f"\n{os.path.relpath(path, REPO)}: `{cmd}`\n  {err}",
              file=sys.stderr)
    print(f"\n{checked} documented commands checked, "
          f"{len(failures)} failing")
    return 1 if failures or checked == 0 else 0


if __name__ == "__main__":
    sys.exit(main())
