#!/usr/bin/env python
"""Collection guard: the LSM/kernel suites must never collect zero tests.

A refactor that renames a module, breaks an import, or trips a module-
level skip/parametrize bug can zero out a whole test file while CI stays
green — "passed" because nothing ran.  This gate runs pytest collection
over the suites that lock down the columnar store and fails if any of
them yields no tests (or fewer than its pinned floor).

    python tools/check_collect.py
"""
from __future__ import annotations

import subprocess
import sys
from collections import Counter

# suite -> minimum collected tests.  The differential harness floor is
# the PR acceptance criterion (>=200 random op sequences per store pair);
# the reprolint floor pins the 19-fixture parametrization (per-file
# rules AND the PR 9 interprocedural passes) plus the baseline/CLI
# contract and cross-file pass tests; the packed-key floor pins the
# bit-width/aliasing regression suite; the rest just must not vanish.
SUITES = {
    "tests/test_lsm.py": 1,
    "tests/test_kernels.py": 1,
    "tests/test_lsm_differential.py": 200,
    "tests/test_kernel_parity.py": 1,
    "tests/test_lint.py": 43,
    "tests/test_packed_key_bounds.py": 14,
    "tests/test_obs.py": 22,
}


def main() -> int:
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q",
         *SUITES],
        capture_output=True, text=True)
    if proc.returncode not in (0, 5):
        print(proc.stdout)
        print(proc.stderr, file=sys.stderr)
        print(f"check_collect: pytest collection errored "
              f"(exit {proc.returncode})")
        return 1
    counts: Counter[str] = Counter()
    for line in proc.stdout.splitlines():
        if "::" in line:
            counts[line.split("::", 1)[0]] += 1
    failures = []
    for suite, floor in SUITES.items():
        got = counts.get(suite, 0)
        status = "ok" if got >= floor else f"FAIL (floor {floor})"
        print(f"check_collect: {suite}: {got} tests {status}")
        if got < floor:
            failures.append(suite)
    if failures:
        print(f"check_collect: {len(failures)} suite(s) under-collect")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
