#!/usr/bin/env python
"""Schema check for recorded control-loop traces.

Two formats (``repro.obs.export`` writes both):

* JSONL (default) — a ``{"kind": "repro-trace", "version": 1}`` header
  line followed by one span dict per line.  Spans must carry exactly the
  ``Span.to_dict`` keys, use a known category, keep ``t1 >= t0``, and
  the ``seq`` stream must start at 0 and strictly increase — a trace
  with a gap or a reset means two tracers were interleaved into one
  file.
* ``--chrome`` — Chrome ``trace_event`` JSON object format (what
  Perfetto / chrome://tracing loads): a ``traceEvents`` list of
  complete ("X") and metadata ("M") events plus the ``otherData``
  provenance stamp.

CI records a trace and runs this checker (plus the committed example
under docs/traces/); schema drift fails the build instead of silently
producing a trace Perfetto cannot open.

    python tools/check_trace.py TRACE.jsonl
    python tools/check_trace.py --chrome TRACE.json

Stdlib-only on purpose (check_bench.py convention): the span categories
and header tag are duplicated from ``repro.obs`` so the checker runs
without PYTHONPATH.
"""
from __future__ import annotations

import argparse
import json
import sys

# duplicated from repro.obs.{trace,export} — tests pin them equal
TRACE_KIND = "repro-trace"
TRACE_VERSION = 1
CATS = ("window", "engine", "policy", "admission", "migration", "lsm",
        "preempt")

SPAN_KEYS = {
    "seq": int,
    "name": str,
    "cat": str,
    "t0": (int, float),
    "t1": (int, float),
    "tenant": str,
    "window": (int, type(None)),
    "args": dict,
}


def _typed(val, typ) -> bool:
    return isinstance(val, typ) and not isinstance(val, bool)


def check_span(span, i: int) -> list[str]:
    if not isinstance(span, dict):
        return [f"span[{i}] is not an object"]
    errors = []
    if set(span) != set(SPAN_KEYS):
        errors.append(f"span[{i}] keys {sorted(span)} != "
                      f"{sorted(SPAN_KEYS)}")
        return errors
    for key, typ in SPAN_KEYS.items():
        if not _typed(span[key], typ):
            errors.append(f"span[{i}][{key!r}] has type "
                          f"{type(span[key]).__name__}")
    if errors:
        return errors
    if not span["name"]:
        errors.append(f"span[{i}] has an empty name")
    if span["cat"] not in CATS:
        errors.append(f"span[{i}] cat {span['cat']!r} not in {CATS}")
    if span["t1"] < span["t0"]:
        errors.append(f"span[{i}] t1 < t0 ({span['t1']} < {span['t0']})")
    return errors


def check_jsonl(lines: list[str]) -> list[str]:
    if not lines:
        return ["empty trace"]
    try:
        header = json.loads(lines[0])
    except json.JSONDecodeError as e:
        return [f"header is not JSON: {e}"]
    if not isinstance(header, dict) or header.get("kind") != TRACE_KIND:
        return [f"header kind != {TRACE_KIND!r}"]
    errors = []
    if header.get("version") != TRACE_VERSION:
        errors.append(f"header version != {TRACE_VERSION}: "
                      f"{header.get('version')!r}")
    prev_seq = -1
    for i, line in enumerate(lines[1:]):
        try:
            span = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"span[{i}] is not JSON: {e}")
            continue
        span_errors = check_span(span, i)
        errors += span_errors
        if span_errors:
            continue
        if span["seq"] != prev_seq + 1:
            errors.append(f"span[{i}] seq {span['seq']} != {prev_seq + 1} "
                          "(one tracer per file: seq starts at 0 and "
                          "increments by 1)")
        prev_seq = span["seq"]
    return errors


def check_chrome(data) -> list[str]:
    if not isinstance(data, dict):
        return ["top level is not an object"]
    errors = []
    other = data.get("otherData")
    if not isinstance(other, dict) or other.get("kind") != TRACE_KIND:
        errors.append(f"otherData.kind != {TRACE_KIND!r}")
    elif other.get("version") != TRACE_VERSION:
        errors.append(f"otherData.version != {TRACE_VERSION}")
    events = data.get("traceEvents")
    if not isinstance(events, list) or not events:
        return errors + ["traceEvents is not a non-empty list"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"traceEvents[{i}] is not an object")
            continue
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") not in ("process_name", "thread_name") \
                    or not isinstance(ev.get("args"), dict) \
                    or not isinstance(ev["args"].get("name"), str):
                errors.append(f"traceEvents[{i}] malformed metadata event")
        elif ph == "X":
            if not (isinstance(ev.get("name"), str) and ev["name"]
                    and ev.get("cat") in CATS
                    and _typed(ev.get("ts"), (int, float))
                    and _typed(ev.get("dur"), (int, float))
                    and ev["dur"] > 0
                    and _typed(ev.get("pid"), int)
                    and _typed(ev.get("tid"), int)
                    and isinstance(ev.get("args"), dict)):
                errors.append(f"traceEvents[{i}] malformed complete event")
        else:
            errors.append(f"traceEvents[{i}] unknown ph {ph!r} "
                          "(want X or M)")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="trace file to validate")
    ap.add_argument("--chrome", action="store_true",
                    help="validate Chrome trace_event JSON instead of "
                         "the JSONL span schema")
    args = ap.parse_args()
    try:
        with open(args.trace) as f:
            text = f.read()
    except OSError as e:
        print(f"check_trace: cannot read {args.trace}: {e}")
        return 1
    if args.chrome:
        try:
            data = json.loads(text)
        except json.JSONDecodeError as e:
            print(f"check_trace: {args.trace}: not JSON: {e}")
            return 1
        errors = check_chrome(data)
        n = len(data.get("traceEvents", [])) if isinstance(data, dict) else 0
        what = f"{n} events, trace_event"
    else:
        lines = [ln for ln in text.splitlines() if ln.strip()]
        errors = check_jsonl(lines)
        what = f"{max(len(lines) - 1, 0)} spans, jsonl"
    for e in errors:
        print(f"check_trace: {args.trace}: {e}")
    if not errors:
        print(f"check_trace: {args.trace}: ok ({what})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
