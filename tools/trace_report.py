#!/usr/bin/env python
"""Render a control-loop trace as "why did window N do X".

Two input modes:

* ``--trace PATH`` — render a recorded JSONL trace
  (``nexmark_eval.py --trace`` / ``run.py fleet --trace`` wrote it);
* ``--episode QUERY --policy NAME`` — re-run that Fig. 5 episode with
  tracing enabled (same protocol the golden traces pin: seed and
  max_level come from ``tests/data/golden_autoscale.json`` when the
  episode is a golden one) and render the result.  Needs
  ``PYTHONPATH=src``.

For every decision window the report shows the engine observation, the
trigger verdict, the proposal's :class:`~repro.obs.provenance.Explain`
record — per-operator action plus the exact signal values it was
computed from, against the policy's thresholds — and the admission
verdict.  ``--window N`` narrows to one window, ``--tenant SUB`` to
tenants containing SUB (fleet traces tag spans per tenant).

    PYTHONPATH=src python tools/trace_report.py --episode q8 --policy justin --window 1
    PYTHONPATH=src python tools/trace_report.py --trace fleet.trace.jsonl --tenant a17
"""
from __future__ import annotations

import argparse
import json
import sys


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def _kv(args: dict, skip=()) -> str:
    return "  ".join(f"{k}={_fmt(v)}" for k, v in args.items()
                     if k not in skip and v is not None)


def _render_propose(span: dict, out) -> None:
    args = span["args"]
    head = _kv(args, skip=("config", "thresholds", "operators"))
    print(f"  policy.propose        {head}", file=out)
    if args.get("thresholds"):
        print(f"      thresholds: {_kv(args['thresholds'])}", file=out)
    config = args.get("config") or {}
    for op, rec in (args.get("operators") or {}).items():
        tgt = config.get(op)
        to = f" -> (p={tgt[0]}, level={tgt[1]})" if tgt else ""
        print(f"      {op}: {rec['action']}{to}", file=out)
        sig = rec.get("signals") or {}
        print(f"          {_kv(sig)}", file=out)


def _render_span(span: dict, out) -> None:
    name = span["name"]
    if name == "policy.propose":
        _render_propose(span, out)
        return
    pad = f"  {name:<20s}"
    dur = span["t1"] - span["t0"]
    tspan = f"t={span['t0']:.6g}..{span['t1']:.6g}s" if dur else ""
    line = "  ".join(x for x in (pad.rstrip().ljust(22), tspan,
                                 _kv(span["args"])) if x)
    print(line, file=out)


def render(spans: list[dict], *, window: int | None = None,
           tenant: str | None = None, out=sys.stdout) -> int:
    """Print the report; returns the number of spans rendered."""
    shown = 0
    current = object()
    for s in spans:
        if tenant is not None and tenant not in s["tenant"]:
            continue
        if window is not None and s["window"] != window:
            continue
        key = (s["tenant"], s["window"])
        if key != current:
            current = key
            where = f"window {s['window']}" if s["window"] is not None \
                else "(no window)"
            who = f"  tenant {s['tenant']}" if s["tenant"] else ""
            print(f"== {where}{who} ==", file=out)
        _render_span(s, out)
        shown += 1
    return shown


def _golden_meta() -> dict:
    try:
        with open("tests/data/golden_autoscale.json") as f:
            return json.load(f).get("_meta", {})
    except (OSError, json.JSONDecodeError):
        return {}


def _episode_spans(query: str, policy: str) -> list[dict]:
    """Re-run one Fig. 5 episode with tracing on (golden protocol)."""
    from repro.core.controller import AutoScaler, ControllerConfig
    from repro.core.justin import JustinParams
    from repro.core.policy import make_policy
    from repro.data.nexmark import QUERIES, TARGET_RATES
    from repro.obs import Tracer
    from repro.streaming.engine import StreamEngine
    meta = _golden_meta()
    seed = int(meta.get("seed", 3))
    max_level = int(meta.get("max_level", 2))
    eng = StreamEngine(QUERIES[query](), seed=seed)
    cfg = ControllerConfig(policy=policy,
                           justin=JustinParams(max_level=max_level))
    tracer = Tracer(enabled=True)
    ctl = AutoScaler(eng, TARGET_RATES[query], cfg,
                     policy=make_policy(policy, cfg), tracer=tracer)
    ctl.tenant = f"{query}:{policy}"
    ctl.run()
    return [s.to_dict() for s in tracer.spans]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--trace", metavar="PATH",
                     help="recorded JSONL trace to render")
    src.add_argument("--episode", metavar="QUERY",
                     help="re-run this Nexmark query's Fig. 5 episode "
                          "with tracing enabled (needs PYTHONPATH=src)")
    ap.add_argument("--policy", default="justin",
                    help="policy for --episode (default: justin)")
    ap.add_argument("--window", type=int, default=None,
                    help="only this decision window")
    ap.add_argument("--tenant", default=None,
                    help="only tenants containing this substring")
    args = ap.parse_args()
    if args.trace:
        from pathlib import Path
        lines = [ln for ln in
                 Path(args.trace).read_text().splitlines() if ln.strip()]
        if not lines:
            print(f"trace_report: {args.trace}: empty trace")
            return 1
        header = json.loads(lines[0])
        if header.get("kind") != "repro-trace":
            print(f"trace_report: {args.trace}: not a repro-trace file")
            return 1
        spans = [json.loads(ln) for ln in lines[1:]]
    else:
        spans = _episode_spans(args.episode, args.policy)
    shown = render(spans, window=args.window, tenant=args.tenant)
    if not shown:
        print("trace_report: no spans matched the filter")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
