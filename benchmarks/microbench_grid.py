"""Fig. 4 reproduction: Read/Write/Update microbenchmarks over the
(parallelism x memory) grid — maximum sustainable rate per configuration.
"""
from __future__ import annotations

import argparse
import json

from repro.data.nexmark import BidGen
from repro.streaming.engine import StreamEngine
from repro.streaming.graph import Dataflow
from repro.streaming.operators import KeyedStateOp, SinkOp, SourceOp

TARGETS = {"read": 50_000, "write": 50_000, "update": 30_000}
GRID = [(1, 128), (1, 256), (1, 512), (1, 1024), (1, 2048),
        (2, 256), (2, 512), (2, 1024),
        (4, 128), (4, 256), (4, 512), (4, 1024), (4, 2048),
        (8, 128), (8, 256), (8, 512), (8, 1024)]


def run_point(mode: str, p: int, mem_mb: float, *, seconds: float = 15,
              keyspace: int = 1_000_000, seed: int = 1) -> dict:
    flow = Dataflow("micro")
    op = KeyedStateOp("state_op", mode, keyspace=keyspace)
    flow.chain(SourceOp("source", BidGen(seed=seed)), op, SinkOp("sink"))
    flow.nodes["state_op"].parallelism = p
    eng = StreamEngine(flow, base_mem_mb=mem_mb, seed=seed)
    eng.run(seconds, TARGETS[mode])
    m = eng.collect()
    s = m["state_op"]
    return {"mode": mode, "p": p, "mem_mb": mem_mb,
            "rate": m["sink"]["rate_in"], "target": TARGETS[mode],
            "sustained": m["sink"]["rate_in"] >= 0.98 * TARGETS[mode],
            "theta": s["theta"], "tau_ms": s["tau_ms"],
            "busyness": s["busyness"]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--modes", nargs="*", default=["read", "write", "update"])
    ap.add_argument("--quick", action="store_true",
                    help="subset of the grid + shorter windows")
    ap.add_argument("--out", default="benchmarks/microbench_results.json")
    args = ap.parse_args()
    grid = [(1, 128), (4, 512), (4, 1024), (8, 256), (8, 512)] \
        if args.quick else GRID
    seconds = 8 if args.quick else 15
    rows = []
    for mode in args.modes:
        for p, mem in grid:
            r = run_point(mode, p, mem, seconds=seconds)
            rows.append(r)
            th = r["theta"] if r["theta"] is not None else -1
            print(f"{mode:6s} ({p};{mem:5.0f}) rate={r['rate']:9,.0f} "
                  f"target={r['target']:,} sustained={r['sustained']} "
                  f"theta={th:.2f} tau={r['tau_ms'] or 0:.3f}ms", flush=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
