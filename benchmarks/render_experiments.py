"""Render the result tables into EXPERIMENTS.md from benchmarks/*.json.

  PYTHONPATH=src python -m benchmarks.render_experiments
"""
from __future__ import annotations

import json
import os

B = os.path.dirname(os.path.abspath(__file__))


def _load(name):
    p = os.path.join(B, name)
    return json.load(open(p)) if os.path.exists(p) else None


def roofline_table() -> str:
    recs = _load("roofline_results.json") or []
    out = ["| arch | shape | compute | memory | collective | bound | "
           "useful | roofline |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if "error" in r:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.1f} ms | "
            f"{r['t_memory_s']*1e3:.1f} ms | {r['t_collective_s']*1e3:.1f} ms "
            f"| {r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def nexmark_table() -> str:
    nx = _load("nexmark_results.json")
    if not nx:
        return "(pending)"
    out = ["| query | policy | steps | rate | cpu | mem MB | final config |",
           "|---|---|---|---|---|---|---|"]
    for q, row in nx["queries"].items():
        for pol in ("ds2", "justin"):
            s = row[pol]
            cfg = {k: tuple(v) for k, v in s["config"].items()
                   if k != "source"}
            out.append(f"| {q} | {pol} | {s['steps']} | "
                       f"{s['achieved_rate']:,.0f} | {s['cpu_cores']} | "
                       f"{s['memory_mb']:,.0f} | `{cfg}` |")
        out.append(f"| {q} | **Δ justin** | {row['steps_justin_vs_ds2']} | | "
                   f"**-{row['cpu_saving']:.0%}** | "
                   f"**-{row['mem_saving']:.0%}** | |")
    return "\n".join(out)


def microbench_table() -> str:
    rows = _load("microbench_results.json") or []
    out = ["| mode | (p; mem MB) | rate | sustained | θ | τ ms |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        th = f"{r['theta']:.2f}" if r["theta"] is not None else "—"
        out.append(f"| {r['mode']} | ({r['p']}; {r['mem_mb']:.0f}) | "
                   f"{r['rate']:,.0f} | {'✓' if r['sustained'] else '✗'} | "
                   f"{th} | {r['tau_ms'] or 0:.3f} |")
    return "\n".join(out)


def hillclimb_table() -> str:
    rows = (_load("hillclimb_results.json") or []) \
        + (_load("hillclimb_B.json") or []) + (_load("hillclimb_C.json") or [])
    out = ["| iter | arch × shape | compute | memory | collective | bound | "
           "roofline | hypothesis |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "error" in r:
            out.append(f"| {r['iteration']} | | | | | | FAILED | "
                       f"{r.get('hypothesis','')} |")
            continue
        out.append(
            f"| {r['iteration']} | {r['arch']} × {r['shape']} | "
            f"{r['t_compute_s']*1e3:.1f} ms | {r['t_memory_s']*1e3:.1f} ms | "
            f"{r['t_collective_s']*1e3:.1f} ms | {r['bottleneck']} | "
            f"{r['roofline_fraction']:.3f} | {r['hypothesis'][:90]} |")
    return "\n".join(out)


def main() -> None:
    path = os.path.join(os.path.dirname(B), "EXPERIMENTS.md")
    text = open(path).read()
    for marker, content in [
        ("<!-- ROOFLINE_TABLE -->", roofline_table()),
        ("<!-- NEXMARK_TABLE -->", nexmark_table()),
        ("<!-- MICROBENCH_TABLE -->", microbench_table()),
        ("<!-- PERF_TABLE -->", hillclimb_table()),
    ]:
        if marker in text:
            text = text.replace(marker, content)
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
