"""Beyond-paper benchmark: JustinServe — registry scaling policies
arbitrating LLM-serving replicas (scale-out) vs per-replica
KV/prefix-cache HBM budget (scale-up).

``--policies`` accepts any registered names
(``repro.core.policy.available_policies()``); the default is the paper's
ds2/justin pair, and the replica-saving row is computed whenever both are
present: the hybrid policy should hit the target request rate with fewer
replicas by growing the prefix cache instead of the fleet.
"""
from __future__ import annotations

import argparse
import json

from repro.core.policy import available_policies
from repro.serve.engine import (JustinServeController, ServeCosts,
                                WorkloadSpec)


def evaluate(target_rps: float = 120.0, policies=None,
             verbose: bool = True) -> dict:
    out = {}
    for policy in policies or ("ds2", "justin"):
        ctl = JustinServeController(target_rps, policy=policy)
        res = ctl.autoscale()
        out[policy] = res
        if verbose:
            print(f"serve {policy:9s} steps={res['steps']} "
                  f"replicas={res['replicas']} level={res['level']} "
                  f"busy={res['busyness']:.2f} theta={res['theta']:.2f} "
                  f"hbm_cache={res['hbm_cache_gb']:.1f}GB", flush=True)
    if "ds2" in out and "justin" in out:
        d, j = out["ds2"], out["justin"]
        out["replica_saving"] = 1 - j["replicas"] / d["replicas"]
        if verbose:
            print(f"  -> replica saving {out['replica_saving']:.0%}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--target-rps", type=float, default=120.0)
    ap.add_argument("--policies", nargs="+", default=None,
                    choices=available_policies(),
                    help="registered scaling policies to run "
                         "(default: ds2 justin)")
    ap.add_argument("--out", default="benchmarks/justinserve_results.json")
    args = ap.parse_args()
    res = evaluate(args.target_rps, args.policies)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1, default=float)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
