"""Beyond-paper benchmark: JustinServe — Algorithm 1 arbitrating LLM-serving
replicas (scale-out) vs per-replica KV/prefix-cache HBM budget (scale-up).

Compares against replica-only (DS2-analogue) scaling on a shared-prefix
workload: the hybrid policy should hit the target request rate with fewer
replicas by growing the prefix cache instead of the fleet.
"""
from __future__ import annotations

import argparse
import json

from repro.serve.engine import (JustinServeController, ServeCosts,
                                WorkloadSpec)


def evaluate(target_rps: float = 120.0, verbose: bool = True) -> dict:
    out = {}
    for policy in ("ds2", "justin"):
        ctl = JustinServeController(target_rps, policy=policy)
        res = ctl.autoscale()
        out[policy] = res
        if verbose:
            print(f"serve {policy:6s} steps={res['steps']} "
                  f"replicas={res['replicas']} level={res['level']} "
                  f"busy={res['busyness']:.2f} theta={res['theta']:.2f} "
                  f"hbm_cache={res['hbm_cache_gb']:.1f}GB", flush=True)
    d, j = out["ds2"], out["justin"]
    out["replica_saving"] = 1 - j["replicas"] / d["replicas"]
    if verbose:
        print(f"  -> replica saving {out['replica_saving']:.0%}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--target-rps", type=float, default=120.0)
    ap.add_argument("--out", default="benchmarks/justinserve_results.json")
    args = ap.parse_args()
    res = evaluate(args.target_rps)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1, default=float)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
