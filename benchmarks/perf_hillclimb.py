import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb: hypothesis -> change -> re-lower -> measure, per cell.

Each iteration is a config-knob variant of one of the three chosen
(arch x shape) cells; deltas are measured on the same extrapolated roofline
terms as the baseline table (benchmarks/roofline.py).

  PYTHONPATH=src python benchmarks/perf_hillclimb.py [--cell N]
"""
import argparse
import json

from benchmarks.roofline import analyze_cell
from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.models.sharding import MeshCtx

# (name, arch, shape, config-overrides, hypothesis)
ITERATIONS = [
    # -- cell A: llama3.2-3b x prefill_32k (collective-bound baseline) -----
    ("A0-baseline", "llama3.2-3b", "prefill_32k", {},
     "baseline: GSPMD factorizes 24 heads over the 16-way axis as 8x2 "
     "(heads x head_dim); every score tile becomes a partial sum -> "
     "f32 all-reduce per (layer x q-chunk x kv-chunk)"),
    ("A1-attn-heads", "llama3.2-3b", "prefill_32k",
     {"attn_shard": "heads"},
     "pinning H over model (uneven: ceil(24/16)=2 heads on 8 devices) "
     "removes the head_dim split => score all-reduces vanish; cost: "
     "~33% attention-compute imbalance"),
    ("A2-attn-seq", "llama3.2-3b", "prefill_32k",
     {"attn_shard": "seq"},
     "context-parallel: q positions over model, KV replicated; no head "
     "imbalance, collective = one KV all-gather per layer"),
    ("A3-seq+bf16p", "llama3.2-3b", "prefill_32k",
     {"attn_shard": "seq", "attn_f32_scores": False},
     "bf16 probability tiles halve the dominant HBM operand of p@v"),
    ("A4-tp-only", "llama3.2-3b", "prefill_32k",
     {"fsdp": False},
     "serving layout: TP-only weights (3B f32 / 16 = 800 MB/dev, fits). "
     "FSDP made GSPMD reduce 805 MB/layer activations over the data axis "
     "instead of gathering 18 MB/layer weights"),
    ("A5-tp+bf16p", "llama3.2-3b", "prefill_32k",
     {"fsdp": False, "attn_f32_scores": False},
     "TP-only + bf16 probability tiles"),
    ("A6-pad-heads", "llama3.2-3b", "prefill_32k",
     {"pad_heads_to": 32},
     "group-major head padding 24->32 (semantically neutral, verified): "
     "heads divide the axis so GSPMD never splits head_dim; kills BOTH the "
     "per-chunk score all-reduces and the attention-output partial sums "
     "for +33% attention-only FLOPs"),
    ("A7-pad+bf16p", "llama3.2-3b", "prefill_32k",
     {"pad_heads_to": 32, "attn_f32_scores": False},
     "head padding + bf16 probability tiles"),
    ("A8-pad+tp-only", "llama3.2-3b", "prefill_32k",
     {"pad_heads_to": 32, "fsdp": False},
     "head padding + TP-only serving weights: with the factorization gone, "
     "does removing FSDP weight-gathers now show up?"),

    # -- cell B: llama4-maverick x decode_32k — the most collective-bound
    #    cell (1.98 s/step of ICI!) and the paper-technique analogue: the
    #    KV/expert read path is the serving 'state backend' ----------------
    ("B0-baseline", "llama4-maverick-400b-a17b", "decode_32k", {},
     "baseline: EPxFSDP expert weights are all-gathered over dp EVERY "
     "decode step (~99 GB/dev of ICI for ~KBs of tokens)"),
    ("B1-moe-2d", "llama4-maverick-400b-a17b", "decode_32k",
     {"moe_shard": "2d"},
     "move tokens, not weights: experts fully sharded (E over model x F "
     "over dp); all-gather the 128-token batch (1.3 MB) + one psum "
     "replaces the 99 GB weight gather"),
    ("B2-moe-2d+heads", "llama4-maverick-400b-a17b", "decode_32k",
     {"moe_shard": "2d", "attn_shard": "heads"},
     "plus pinned attention heads (40 over 16 otherwise factorizes 8x2 "
     "with score partial-sums)"),

    # -- cell C: llama4-maverick-400b x train_4k (largest model; MoE) ------
    ("C0-baseline", "llama4-maverick-400b-a17b", "train_4k", {},
     "baseline: EPxFSDP experts, remat=full, bf16 opt"),
    ("C1-attn-heads", "llama4-maverick-400b-a17b", "train_4k",
     {"attn_shard": "heads"},
     "40 heads over 16: GSPMD factorizes 8x2 like cell A; pin heads"),
    ("C2-remat-dots", "llama4-maverick-400b-a17b", "train_4k",
     {"attn_shard": "heads", "remat": "dots"},
     "keep matmul outputs, recompute elementwise only: compute term down "
     "~25% for extra activation memory"),
    ("C3-bf16p", "llama4-maverick-400b-a17b", "train_4k",
     {"attn_shard": "heads", "remat": "dots", "attn_f32_scores": False},
     "bf16 probability tiles in attention"),
    ("C4-pad+dots", "llama4-maverick-400b-a17b", "train_4k",
     {"pad_heads_to": 48, "remat": "dots"},
     "group-major head padding 40->48 (removes the 8x2 head_dim "
     "factorization at the weight level) + dots remat"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="prefix filter, e.g. A")
    ap.add_argument("--out", default="benchmarks/hillclimb_results.json")
    args = ap.parse_args()

    from repro.obs import MetricsRegistry
    reg = MetricsRegistry()   # one clock path (same registry the
    mesh = make_production_mesh(multi_pod=False)   # bench suite times on)
    mctx = MeshCtx(mesh)
    rows = []
    for name, arch, shape, overrides, hypothesis in ITERATIONS:
        if args.only and not name.startswith(args.only):
            continue
        cfg = get_config(arch).replace(**overrides)
        try:
            with reg.timer(f"hillclimb.{name}") as tm:
                rec = analyze_cell(arch, shape, mctx, cfg_override=cfg)
            rec.update(iteration=name, overrides=overrides,
                       hypothesis=hypothesis, wall_s=round(tm.s, 4))
            rows.append(rec)
            print(f"{name:16s} comp={rec['t_compute_s']*1e3:9.2f}ms "
                  f"mem={rec['t_memory_s']*1e3:9.2f}ms "
                  f"coll={rec['t_collective_s']*1e3:9.2f}ms "
                  f"bound={rec['bottleneck']:10s} "
                  f"roofline={rec['roofline_fraction']:.3f}", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{name} FAILED: {type(e).__name__}: {e}", flush=True)
            rows.append({"iteration": name, "error": str(e),
                         "overrides": overrides})
    existing = []
    if os.path.exists(args.out):
        existing = json.load(open(args.out))
    with open(args.out, "w") as f:
        json.dump(existing + rows, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
