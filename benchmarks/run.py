"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Runs the QUICK variants so the
whole suite finishes in minutes; the full grids live in microbench_grid.py /
nexmark_eval.py / roofline.py (see EXPERIMENTS.md for full-run outputs).

All wall-clock measurement goes through ONE registry
(``repro.obs.MetricsRegistry.timer``) instead of ad-hoc ``time.time()``
pairs, so the CSV rows, the BENCH_*.json artifacts and any recorded
traces report from the same clock path; ``fleet`` additionally accepts
``--trace PATH`` to dump the fleet episode's span trace as JSONL.
"""
from __future__ import annotations

import sys

_REG = None


def _registry():
    """The suite-wide metrics registry (lazy: ``repro`` imports stay
    inside bench functions so ``py_compile`` needs no PYTHONPATH)."""
    global _REG
    if _REG is None:
        from repro.obs import MetricsRegistry
        _REG = MetricsRegistry()
    return _REG


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_fig4_microbench() -> None:
    """Paper Fig. 4: memory/parallelism grid (quick subset)."""
    from benchmarks.microbench_grid import run_point
    for mode, p, mem in [("read", 1, 128), ("read", 4, 1024),
                         ("read", 8, 512), ("write", 4, 512),
                         ("update", 8, 512)]:
        with _registry().timer(f"fig4_{mode}_p{p}_m{mem}") as tm:
            r = run_point(mode, p, mem, seconds=6)
        _row(f"fig4_{mode}_p{p}_m{mem}", tm.us,
             f"rate={r['rate']:.0f};sustained={r['sustained']};"
             f"theta={r['theta'] if r['theta'] is not None else ''}")


def bench_fig5_nexmark() -> None:
    """Paper Fig. 5 / §5.1: Justin vs DS2 (q11 + q1, quick)."""
    from benchmarks.nexmark_eval import evaluate
    with _registry().timer("fig5_nexmark") as tm:
        res = evaluate(["q1", "q11"], max_level=2, verbose=False)
    for q, row in res["queries"].items():
        _row(f"fig5_{q}", tm.us / len(res["queries"]),
             f"cpu_saving={row['cpu_saving']:.2f};"
             f"mem_saving={row['mem_saving']:.2f};"
             f"steps={row['steps_justin_vs_ds2']}")


def bench_episode_autoscale() -> None:
    """Single-episode autoscaling wall-clock — the engine fast-path
    headline number (one full AutoScaler episode per policy, q11)."""
    from repro.core.controller import AutoScaler, ControllerConfig
    from repro.core.justin import JustinParams
    from repro.data.nexmark import QUERIES, TARGET_RATES
    from repro.streaming.engine import StreamEngine
    for policy in ("ds2", "justin"):
        with _registry().timer(f"episode_q11_{policy}") as tm:
            flow = QUERIES["q11"]()
            eng = StreamEngine(flow, seed=3)
            ctl = AutoScaler(eng, TARGET_RATES["q11"], ControllerConfig(
                policy=policy, justin=JustinParams(max_level=2)))
            ctl.run()
            s = ctl.summary()
        _row(f"episode_q11_{policy}", tm.us,
             f"steps={s['steps']};rate={s['achieved_rate']:.0f};"
             f"cpu={s['cpu_cores']};mem={s['memory_mb']:.0f}")


def bench_scenarios() -> None:
    """Dynamic-workload scenarios through the controller — one episode per
    registered policy family (model-based justin, reactive threshold,
    fixed static) plus justin under a spike."""
    from repro.scenarios import run_scenario
    for policy, prof in (("justin", "ramp"), ("justin", "spike"),
                         ("threshold", "ramp"), ("static", "ramp")):
        with _registry().timer(f"scenario_q5_{prof}_{policy}") as tm:
            r = run_scenario(policy, "q5", prof, windows=6)
        _row(f"scenario_q5_{prof}_{policy}", tm.us,
             f"steps={r.steps};recovered={r.recovered()};"
             f"cpu={r.final.cpu_cores}")


def bench_colocation() -> None:
    """Shared-cluster co-location: the neighbor a ds2 tenant blocks is
    admitted when the tenant runs justin (see examples/colocation_demo.py)."""
    from repro.core.controller import ControllerConfig
    from repro.core.justin import JustinParams
    from repro.scenarios import Cluster, ColocatedSpec, run_colocated
    cfg = ControllerConfig(decision_window_s=60.0, stabilization_s=30.0,
                           justin=JustinParams(max_level=2))
    for a_policy in ("ds2", "justin"):
        with _registry().timer(f"colocate_A_{a_policy}") as tm:
            res = run_colocated(
                [ColocatedSpec(a_policy, "q1", name="A"),
                 ColocatedSpec("ds2", "q1", name="B")],
                Cluster(cpu_slots=16, memory_mb=7000.0), windows=5, cfg=cfg)
        b = res.tenant("B")
        _row(f"colocate_A_{a_policy}", tm.us,
             f"B_denied={len(b.denials)};B_recovered={b.slo().recovered};"
             f"peak_mem={max(m for _, m in res.usage):.0f}")
    # preemptive admission: a static tenant pinned at storage level 2
    # starves the high-priority DS2 tenant under priority; preemption
    # reclaims its levels and the request is admitted
    for adm in ("priority", "preemption"):
        with _registry().timer(f"colocate_preempt_{adm}") as tm:
            res = run_colocated(
                [ColocatedSpec("ds2", "q1", name="H"),
                 ColocatedSpec("static", "q11", name="V", target=5_000,
                               config={"user_sessions": (6, 2)})],
                Cluster(cpu_slots=16, memory_mb=8500.0), windows=5, cfg=cfg,
                admission=adm)
        h, v = res.tenant("H"), res.tenant("V")
        _row(f"colocate_preempt_{adm}", tm.us,
             f"H_denied={len(h.denials)};V_preempted={len(v.preemptions)};"
             f"H_recovered={h.slo().recovered}")
    # shared-TM packing: three small tenants on one slot-capped fleet pay
    # two TMs' base memory instead of three private fleets'
    from repro.core.placement import default_tm_spec
    with _registry().timer("colocate_shared_tm") as tm:
        cluster = Cluster(cpu_slots=6, memory_mb=20000.0,
                          tm_spec=default_tm_spec())
        res = run_colocated([("ds2", "q1")] * 3, cluster, windows=2, cfg=cfg)
    shared = cluster.placement().memory_mb
    private = sum(t.scaler.resources()[1] for t in res.tenants)
    _row("colocate_shared_tm", tm.us,
         f"shared_mb={shared:.0f};private_mb={private:.0f};"
         f"saving={1 - shared / private:.2f}")


def bench_fleet() -> None:
    """Thousand-tenant fleet driver: vectorized ``run_colocated`` over a
    sampled population, headline = simulated tenant-windows per second.
    Writes ``BENCH_cluster.json`` (schema checked by tools/check_bench.py)
    with the registry snapshot (timers + fleet audit totals) under
    ``obs``.

    Scale: ``run.py fleet [tenants windows] [--trace PATH]`` (default
    1000 x 100); ``--trace`` records the preemption episode's span trace
    as JSONL (schema checked by tools/check_trace.py).  When the whole
    suite runs (no selector) the quick 128 x 20 variant keeps the total
    under a minute."""
    import json
    import os

    from repro.scenarios import fleet_stats, run_fleet
    argv = sys.argv[1:]
    trace_path = None
    if "--trace" in argv:
        i = argv.index("--trace")
        trace_path = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    if argv and argv[0] == "fleet":
        tenants = int(argv[1]) if len(argv) > 1 else 1000
        windows = int(argv[2]) if len(argv) > 2 else 100
    else:
        tenants, windows = 128, 20
    reg = _registry()
    tracer = None
    runs = []
    for admission in ("fair_share", "preemption"):
        if trace_path is not None and admission == "preemption":
            from repro.obs import Tracer
            tracer = Tracer(enabled=True)
        with reg.timer(f"fleet_{admission}_{tenants}x{windows}") as tm:
            res = run_fleet(tenants, windows, admission=admission, seed=0,
                            tracer=tracer if admission == "preemption"
                            else None)
        st = fleet_stats(res, tm.s)
        st["driver"] = "vectorized"
        st["seed"] = 0
        runs.append(st)
        reg.absorb_fleet(res, prefix=f"fleet.{admission}")
        _row(f"fleet_{admission}_{tenants}x{windows}",
             st["seconds"] * 1e6,
             f"tw_per_s={st['tenant_windows_per_s']:.0f};"
             f"denied={st['denied_tenant_windows']};"
             f"deferred={st['deferred_tenant_windows']};"
             f"preempted={st['preempted_tenant_windows']}")
    if tracer is not None:
        from repro.obs import write_jsonl
        write_jsonl(tracer.spans, trace_path,
                    meta={"bench": "cluster_fleet", "tenants": tenants,
                          "windows": windows, "admission": "preemption",
                          "seed": 0})
        print(f"wrote {trace_path} ({len(tracer.spans)} spans)", flush=True)
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_cluster.json")
    with open(path, "w") as f:
        json.dump({"bench": "cluster_fleet", "schema_version": 1,
                   "runs": runs, "obs": reg.snapshot()}, f, indent=2)
        f.write("\n")


def bench_lsm_store() -> None:
    """Columnar vs legacy state backend on the PR 1 headline episode
    (q8, justin policy, seed 3) — an in-process A/B: ``set_store_impl``
    swaps every TaskRunner's store class, and the engine routes the
    legacy store through the frozen pre-columnar partition installer so
    it runs in its historical configuration.  min-of-N wall clock per
    impl; writes ``BENCH_lsm.json`` (schema + regression gate in
    tools/check_bench.py).

    Scale: ``run.py lsm [repeats]`` (default 3); the suite-wide run (no
    selector) uses the same default — one episode is seconds, not
    minutes."""
    import json
    import os
    import subprocess

    argv = sys.argv[1:]
    repeats = int(argv[1]) if argv and argv[0] == "lsm" and len(argv) > 1 \
        else 3
    query, seed = "q8", 3

    snippet = """
import json
from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.obs import MetricsRegistry
from repro.state import lsm
from repro.streaming.engine import StreamEngine
lsm.set_store_impl({impl!r})
flow = QUERIES[{query!r}]()
eng = StreamEngine(flow, seed={seed})
ctl = AutoScaler(eng, TARGET_RATES[{query!r}], ControllerConfig(
    policy="justin", justin=JustinParams(max_level=2)))
reg = MetricsRegistry()
with reg.timer("episode") as tm:
    ctl.run()
s = ctl.summary()
print(json.dumps({{"seconds": tm.s, "steps": s["steps"],
                   "achieved_rate": s["achieved_rate"]}}))
"""
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   p for p in (src, os.environ.get("PYTHONPATH")) if p))

    def episode(impl: str) -> tuple[float, dict]:
        out = subprocess.run(
            [sys.executable, "-c",
             snippet.format(impl=impl, query=query, seed=seed)],
            capture_output=True, text=True, check=True, env=env)
        r = json.loads(out.stdout.strip().splitlines()[-1])
        return r["seconds"], r

    secs: dict[str, list] = {"legacy": [], "columnar": []}
    summs: dict[str, dict] = {}
    # one fresh process per episode (allocator state from a previous
    # episode measurably slows later ones), interleaved so drifting host
    # load hits both sides of the A/B equally; min-of-N then discards
    # the loaded episodes
    for _ in range(repeats):
        for impl in ("legacy", "columnar"):
            dt, summs[impl] = episode(impl)
            secs[impl].append(round(dt, 3))
    runs = []
    for impl in ("legacy", "columnar"):
        summ = summs[impl]
        runs.append({
            "impl": impl, "query": query, "policy": "justin",
            "seed": seed, "repeats": repeats, "seconds": secs[impl],
            "seconds_min": min(secs[impl]), "steps": int(summ["steps"]),
            "achieved_rate": float(summ["achieved_rate"]),
        })
        _row(f"lsm_{impl}_{query}", min(secs[impl]) * 1e6,
             f"min_of={repeats};steps={summ['steps']};"
             f"rate={summ['achieved_rate']:.0f}")
    by = {r["impl"]: r["seconds_min"] for r in runs}
    speedup = by["legacy"] / by["columnar"]
    _row(f"lsm_speedup_{query}", 0.0, f"speedup={speedup:.2f}")
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_lsm.json")
    with open(path, "w") as f:
        json.dump({"bench": "lsm_store", "schema_version": 1,
                   "speedup": round(speedup, 3), "runs": runs}, f, indent=2)
        f.write("\n")


def bench_justinserve() -> None:
    """Beyond-paper: hybrid LLM-serving elasticity."""
    from benchmarks.justinserve_bench import evaluate
    with _registry().timer("justinserve") as tm:
        res = evaluate(verbose=False)
    _row("justinserve", tm.us,
         f"replica_saving={res['replica_saving']:.2f};"
         f"justin_replicas={res['justin']['replicas']};"
         f"ds2_replicas={res['ds2']['replicas']}")


def bench_kernels() -> None:
    """Pallas kernels vs pure-jnp oracles (interpret mode, correctness +
    per-call wall time on this CPU host)."""
    import numpy as np
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    reg = _registry()

    from repro.kernels.sorted_probe.ops import probe
    table = jnp.asarray(np.unique(rng.integers(0, 1 << 20, 4096))
                        .astype(np.int32))
    queries = jnp.asarray(rng.integers(0, 1 << 20, 1024).astype(np.int32))
    p1, f1 = probe(table, queries)
    with reg.timer("kernel_sorted_probe") as tm:
        p1, f1 = probe(table, queries)
    p2, f2 = probe(table, queries, impl="ref")
    _row("kernel_sorted_probe", tm.us,
         f"match={bool((p1 == p2).all() and (f1 == f2).all())}")

    from repro.kernels.window_agg.ops import aggregate
    seg = jnp.asarray(rng.integers(0, 512, 2048), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(2048, 4)), jnp.float32)
    s1, c1 = aggregate(seg, vals, 512)
    with reg.timer("kernel_window_agg") as tm:
        s1, c1 = aggregate(seg, vals, 512)
    s2, c2 = aggregate(seg, vals, 512, impl="ref")
    _row("kernel_window_agg", tm.us,
         f"allclose={bool(jnp.allclose(s1, s2, atol=1e-3))}")

    from repro.kernels.flash_attn.ops import attention
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    o1 = attention(q, k, v)
    with reg.timer("kernel_flash_attn") as tm:
        o1 = attention(q, k, v)
    o2 = attention(q, k, v, impl="ref")
    _row("kernel_flash_attn", tm.us,
         f"maxerr={float(jnp.max(jnp.abs(o1 - o2))):.2e}")

    from repro.kernels.decode_attn.ops import decode
    qd = jnp.asarray(rng.normal(size=(2, 4, 64)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(2, 2, 512, 64)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(2, 2, 512, 64)), jnp.float32)
    o1 = decode(qd, kc, vc, 512)
    with reg.timer("kernel_decode_attn") as tm:
        o1 = decode(qd, kc, vc, 512)
    o2 = decode(qd, kc, vc, 512, impl="ref")
    _row("kernel_decode_attn", tm.us,
         f"maxerr={float(jnp.max(jnp.abs(o1 - o2))):.2e}")


def bench_train_smoke() -> None:
    """End-to-end reduced training step timing per arch family."""
    from repro.launch.train import train
    for arch in ("llama3.2-3b", "mamba2-130m", "mixtral-8x7b"):
        with _registry().timer(f"train_{arch}") as tm:
            r = train(arch, steps=4, verbose=False)
        _row(f"train_{arch}", tm.us / 4,
             f"final_loss={r['final_loss']:.3f}")


def main() -> None:
    print("name,us_per_call,derived")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for name, fn in list(globals().items()):
        if name.startswith("bench_") and (only is None or only in name):
            fn()


if __name__ == "__main__":
    main()
