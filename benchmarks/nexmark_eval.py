"""Fig. 5 reproduction: Justin vs DS2 elastic scaling on Nexmark.

For each query: steps to converge, achieved rate vs target, final CPU cores
and memory MB, plus the per-window history (capacity/CPU/mem over time —
the Fig. 5 curves) dumped to JSON.

``--policy`` selects which registered scaling policies to run (default:
ds2 + justin, the paper's pair; any name from
``repro.core.policy.available_policies()`` works — e.g. ``--policy
threshold`` runs the Dhalion-style reactive baseline alone).  The
ds2-vs-justin savings row is computed whenever both are in the set.

``max_level=2`` reproduces the paper's observed trajectories (operators cap
at one scale-up, final configs (p, 316 MB)); the Algorithm-1-literal
``max_level=3`` ablation is also recorded.  See EXPERIMENTS.md §Nexmark.

``--grid`` switches to the policy × profile × query evaluation grid
(``repro.scenarios.grid``): every combination's steps-to-converge,
SLO-violation count, catch-up time and CPU/MB resource-time integrals,
written as JSON and printed as markdown tables.  ``--grid-policies``
restricts the policy set (default: every registered policy).

``--grid --admission <mode>`` additionally runs, per query, the
ds2/justin pair co-located on one shared-TM cluster under that admission
mode (``preemption`` lets the high-priority tenant's denied requests
force the neighbor's storage levels down) and adds the co-location
savings table: per-tenant denials, preemptions, private vs amortized
memory integrals, and the shared-fleet saving.  ``--cluster-slots`` /
``--cluster-mb`` override the auto-sized budget.

``--reconfig-cost {instant,savepoint,handoff}`` makes reconfiguration a
priced, observable event (``repro.migration``): every enacted C^t pauses
the job for its planned downtime — full snapshot/restore under
``savepoint``, moved-MB-only under ``handoff`` — and histories/grids
carry downtime windows + moved-MB integrals.  ``--migration-budget-mb``
(with ``--grid --admission``) caps the state MB co-location admissions
may move per window.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.core.policy import available_policies, make_policy
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.streaming.engine import StreamEngine

DEFAULT_POLICIES = ("ds2", "justin")


def evaluate(queries=None, *, max_level: int = 2, seed: int = 3,
             verbose: bool = True, profile: str | None = None,
             windows: int = 8, policies=None,
             reconfig_cost: str = "instant", tracer=None) -> dict:
    """One episode per (query, policy).  ``profile=None`` reproduces the
    paper's fixed-target protocol; a named profile ("ramp", "spike",
    "diurnal", "sinusoid", "step") runs the same comparison under a dynamic
    workload via the scenario subsystem.  ``policies`` may be any subset of
    the registry (default: the paper's ds2/justin pair).
    ``reconfig_cost`` prices every reconfiguration (``repro.migration``):
    ``savepoint`` pauses for the whole state footprint, ``handoff`` only
    for the MB that moves; the default ``instant`` keeps reconfiguration
    free (the golden-trace protocol)."""
    queries = queries or list(QUERIES)
    policies = list(policies or DEFAULT_POLICIES)
    out: dict = {"max_level": max_level, "profile": profile,
                 "policies": policies, "reconfig_cost": reconfig_cost,
                 "queries": {}}
    for qname in queries:
        row = {}
        for policy in policies:
            t0 = time.time()
            if profile is not None:
                from repro.scenarios import run_scenario
                res = run_scenario(policy, qname, profile, windows=windows,
                                   seed=seed, max_level=max_level,
                                   reconfig_cost=reconfig_cost,
                                   tracer=tracer,
                                   tenant=f"{qname}:{policy}"
                                   if tracer is not None else "")
                hist = res.history
                s = res.summary()
            else:
                flow = QUERIES[qname]()
                eng = StreamEngine(flow, seed=seed)
                cfg = ControllerConfig(
                    policy=policy, justin=JustinParams(max_level=max_level))
                migration = None
                if reconfig_cost != "instant":
                    from repro.migration import MigrationRuntime
                    migration = MigrationRuntime(reconfig_cost)
                ctl = AutoScaler(eng, TARGET_RATES[qname], cfg,
                                 policy=make_policy(policy, cfg),
                                 migration=migration, tracer=tracer)
                if tracer is not None:
                    ctl.tenant = f"{qname}:{policy}"
                hist = ctl.run()
                s = ctl.summary()
            s["wall_s"] = round(time.time() - t0, 1)
            s["history"] = [dataclasses.asdict(h) for h in hist]
            row[policy] = s
            if verbose:
                down = sum(h["reconfig_downtime"] for h in s["history"])
                extra = f" downtime={down:,.0f}s" \
                    if reconfig_cost != "instant" else ""
                print(f"{qname:4s} {policy:9s} steps={s['steps']} "
                      f"rate={s['achieved_rate']:,.0f}/{s['target']:,} "
                      f"cpu={s['cpu_cores']} mem={s['memory_mb']:,.0f}MB "
                      f"({s['wall_s']}s){extra}", flush=True)
        if "ds2" in row and "justin" in row:
            d, j = row["ds2"], row["justin"]
            row["cpu_saving"] = 1 - j["cpu_cores"] / d["cpu_cores"]
            row["mem_saving"] = 1 - j["memory_mb"] / d["memory_mb"]
            row["steps_justin_vs_ds2"] = (j["steps"], d["steps"])
            if verbose:
                print(f"  -> CPU saving {row['cpu_saving']:.0%}  "
                      f"MEM saving {row['mem_saving']:.0%}  "
                      f"steps {j['steps']} vs {d['steps']}", flush=True)
        out["queries"][qname] = row
    return out


def main() -> None:
    policy_names = available_policies()
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", nargs="*", default=None)
    ap.add_argument("--policy", nargs="+", default=None,
                    choices=policy_names, dest="policies",
                    help="registered policies to evaluate (default: ds2 "
                         f"justin; registry: {', '.join(policy_names)})")
    ap.add_argument("--max-level", type=int, default=2)
    ap.add_argument("--profile", default=None,
                    choices=["constant", "ramp", "spike", "diurnal",
                             "sinusoid", "step"],
                    help="run under a dynamic rate profile instead of the "
                         "paper's fixed target")
    ap.add_argument("--windows", type=int, default=8)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--grid", action="store_true",
                    help="run the {policies} x {profiles} x {queries} "
                         "evaluation grid (SLO violations, catch-up time, "
                         "resource integrals) instead of the Fig. 5 episode")
    ap.add_argument("--grid-profiles", nargs="+", default=None,
                    choices=["constant", "ramp", "spike", "diurnal",
                             "sinusoid", "step"],
                    help="profile subset for --grid (default: all six)")
    ap.add_argument("--grid-policies", nargs="+", default=None,
                    choices=policy_names,
                    help="policy subset for --grid (default: every "
                         "registered policy)")
    ap.add_argument("--admission", default=None,
                    choices=["priority", "fair_share", "first_come",
                             "preemption"],
                    help="with --grid: also run the per-query ds2/justin "
                         "co-location on a shared-TM cluster under this "
                         "admission mode (savings table gains amortized-"
                         "memory + preemption columns)")
    ap.add_argument("--cluster-slots", type=int, default=0,
                    help="co-location cluster CPU slots (0 = auto-size "
                         "from the pair's initial placements)")
    ap.add_argument("--cluster-mb", type=float, default=0.0,
                    help="co-location cluster memory MB (0 = auto-size)")
    ap.add_argument("--reconfig-cost", default="instant",
                    choices=["instant", "savepoint", "handoff"],
                    help="price every reconfiguration as paused downtime: "
                         "savepoint = full snapshot/restore (downtime ∝ "
                         "total state MB), handoff = incremental LSM "
                         "transfer (downtime ∝ moved MB); instant keeps "
                         "reconfiguration free (the golden-trace default)")
    ap.add_argument("--migration-budget-mb", type=float, default=None,
                    help="with --grid --admission: cap the state MB the "
                         "co-location arbiter lets admissions move per "
                         "window (over-budget requests are deferred and "
                         "retried)")
    ap.add_argument("--driver", default="vectorized",
                    choices=["vectorized", "scalar"],
                    help="with --grid --admission: co-location fleet "
                         "driver (scalar = the reference oracle loop; "
                         "both are decision-identical)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="Fig. 5 episode mode only: record a deterministic "
                         "span trace of every control-loop phase "
                         "(repro.obs) and write it as JSONL to PATH; "
                         "decisions are byte-identical with tracing on or "
                         "off")
    ap.add_argument("--trace-perfetto", default=None, metavar="PATH",
                    help="like --trace but written in Chrome trace_event "
                         "JSON — load PATH in Perfetto / chrome://tracing "
                         "(both flags may be combined: one tracer, two "
                         "exports)")
    ap.add_argument("--out", default=None,
                    help="output JSON (default: benchmarks/"
                         "nexmark_results.json, or nexmark_grid.json with "
                         "--grid — the two schemas differ)")
    args = ap.parse_args()
    if args.grid and (args.trace or args.trace_perfetto):
        ap.error("--trace/--trace-perfetto apply to the Fig. 5 episode, "
                 "not --grid")
    if args.grid and args.profile is not None:
        ap.error("--profile applies to the Fig. 5 episode; with --grid "
                 "use --grid-profiles to restrict the profile set")
    if args.grid and args.policies is not None:
        ap.error("--policy applies to the Fig. 5 episode; with --grid "
                 "use --grid-policies to restrict the policy set")
    for flag, val in (("--grid-profiles", args.grid_profiles),
                      ("--grid-policies", args.grid_policies),
                      ("--admission", args.admission)):
        if val is not None and not args.grid:
            ap.error(f"{flag} requires --grid")
    if (args.cluster_slots or args.cluster_mb) \
            and not (args.grid and args.admission):
        ap.error("--cluster-slots/--cluster-mb apply to the co-location "
                 "section: they require --grid --admission")
    if args.migration_budget_mb is not None \
            and not (args.grid and args.admission):
        ap.error("--migration-budget-mb applies to the co-location "
                 "arbiter: it requires --grid --admission")
    if args.out is None:
        args.out = "benchmarks/nexmark_grid.json" if args.grid \
            else "benchmarks/nexmark_results.json"
    if args.grid:
        from repro.scenarios.grid import grid_markdown, run_grid
        # default to the fast queries; pass --queries for the pressured ones
        queries = args.queries or ["q1", "q5"]
        res = run_grid(queries, args.grid_profiles, args.grid_policies,
                       windows=args.windows, seed=args.seed,
                       max_level=args.max_level, admission=args.admission,
                       windows_colocated=args.windows,
                       cluster_slots=args.cluster_slots,
                       cluster_mb=args.cluster_mb,
                       reconfig_cost=args.reconfig_cost,
                       migration_budget_mb=args.migration_budget_mb,
                       driver=args.driver)
        print(grid_markdown(res))
    else:
        tracer = None
        if args.trace or args.trace_perfetto:
            from repro.obs import Tracer
            tracer = Tracer(enabled=True)
        res = evaluate(args.queries, max_level=args.max_level,
                       profile=args.profile, windows=args.windows,
                       seed=args.seed, policies=args.policies,
                       reconfig_cost=args.reconfig_cost, tracer=tracer)
        if tracer is not None:
            from repro.obs import write_chrome, write_jsonl
            meta = {"seed": args.seed, "max_level": args.max_level,
                    "profile": args.profile,
                    "queries": args.queries or sorted(QUERIES),
                    "policies": list(args.policies or DEFAULT_POLICIES)}
            if args.trace:
                write_jsonl(tracer.spans, args.trace, meta=meta)
                print(f"wrote {args.trace} ({len(tracer.spans)} spans)")
            if args.trace_perfetto:
                write_chrome(tracer.spans, args.trace_perfetto, meta=meta)
                print(f"wrote {args.trace_perfetto} "
                      f"({len(tracer.spans)} spans, trace_event)")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1, default=float)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
