"""Fig. 5 reproduction: Justin vs DS2 elastic scaling on Nexmark.

For each query: steps to converge, achieved rate vs target, final CPU cores
and memory MB, plus the per-window history (capacity/CPU/mem over time —
the Fig. 5 curves) dumped to JSON.

``max_level=2`` reproduces the paper's observed trajectories (operators cap
at one scale-up, final configs (p, 316 MB)); the Algorithm-1-literal
``max_level=3`` ablation is also recorded.  See EXPERIMENTS.md §Nexmark.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.streaming.engine import StreamEngine


def evaluate(queries=None, *, max_level: int = 2, seed: int = 3,
             verbose: bool = True, profile: str | None = None,
             windows: int = 8) -> dict:
    """Justin vs DS2 per query.  ``profile=None`` reproduces the paper's
    fixed-target protocol; a named profile ("ramp", "spike", "diurnal",
    "sinusoid", "step") runs the same comparison under a dynamic workload
    via the scenario subsystem."""
    queries = queries or list(QUERIES)
    out: dict = {"max_level": max_level, "profile": profile, "queries": {}}
    for qname in queries:
        row = {}
        for policy in ("ds2", "justin"):
            t0 = time.time()
            if profile is not None:
                from repro.scenarios import run_scenario
                res = run_scenario(policy, qname, profile, windows=windows,
                                   seed=seed, max_level=max_level)
                hist = res.history
                s = res.summary()
            else:
                flow = QUERIES[qname]()
                eng = StreamEngine(flow, seed=seed)
                ctl = AutoScaler(eng, TARGET_RATES[qname], ControllerConfig(
                    policy=policy, justin=JustinParams(max_level=max_level)))
                hist = ctl.run()
                s = ctl.summary()
            s["wall_s"] = round(time.time() - t0, 1)
            s["history"] = [dataclasses.asdict(h) for h in hist]
            row[policy] = s
            if verbose:
                print(f"{qname:4s} {policy:6s} steps={s['steps']} "
                      f"rate={s['achieved_rate']:,.0f}/{s['target']:,} "
                      f"cpu={s['cpu_cores']} mem={s['memory_mb']:,.0f}MB "
                      f"({s['wall_s']}s)", flush=True)
        d, j = row["ds2"], row["justin"]
        row["cpu_saving"] = 1 - j["cpu_cores"] / d["cpu_cores"]
        row["mem_saving"] = 1 - j["memory_mb"] / d["memory_mb"]
        row["steps_justin_vs_ds2"] = (j["steps"], d["steps"])
        if verbose:
            print(f"  -> CPU saving {row['cpu_saving']:.0%}  "
                  f"MEM saving {row['mem_saving']:.0%}  "
                  f"steps {j['steps']} vs {d['steps']}", flush=True)
        out["queries"][qname] = row
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", nargs="*", default=None)
    ap.add_argument("--max-level", type=int, default=2)
    ap.add_argument("--profile", default=None,
                    choices=["constant", "ramp", "spike", "diurnal",
                             "sinusoid", "step"],
                    help="run under a dynamic rate profile instead of the "
                         "paper's fixed target")
    ap.add_argument("--windows", type=int, default=8)
    ap.add_argument("--out", default="benchmarks/nexmark_results.json")
    args = ap.parse_args()
    res = evaluate(args.queries, max_level=args.max_level,
                   profile=args.profile, windows=args.windows)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1, default=float)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
