import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ must precede any jax import (the roofline lowers on the production mesh).

"""Roofline analysis per (arch x shape) on the single-pod mesh.

Methodology (EXPERIMENTS.md §Roofline):
  * XLA's cost_analysis counts a ``scan`` body ONCE regardless of trip count
    (verified experimentally), so per-cell FLOPs/bytes/collective-bytes are
    obtained by lowering two reduced-depth variants (L1, L2 layers at FULL
    width/batch) and extrapolating linearly to the real depth:
        f(L) = f(L1) + (L - L1) / (L2 - L1) * (f(L2) - f(L1))
    Every L-dependent cost is linear in L (scan trips + stacked-leaf ops),
    so the extrapolation is exact up to constant folding noise.
  * memory figures (peak bytes/device) come from the full-depth compile —
    the same artifact the dry-run validates.
  * Roofline terms (TPU v5e): compute = FLOPs/dev / 197e12,
    memory = bytes/dev / 819e9, collective = coll-bytes/dev / 50e9.

Outputs benchmarks/roofline_results.json + a markdown table on stdout.
"""
import argparse
import json
import math

import jax

from repro.configs import applicable_shapes, get_config, get_shape, list_archs
from repro.launch.dryrun import collective_bytes_from_text, lower_cell
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models.config import ArchConfig, ShapeConfig
from repro.models.sharding import MeshCtx


def depth_variants(cfg: ArchConfig) -> tuple[ArchConfig, ArchConfig, float]:
    """Two reduced-depth FULL-WIDTH *unrolled* configs + the extrapolation
    multiplier ((L_full - L1)/(L2 - L1) applied to the delta).  Unrolling
    (scan_layers=False) makes per-layer costs explicit in the HLO, since XLA
    counts a scan body once regardless of trip count."""
    cfg = cfg.replace(scan_layers=False)
    # depth pairs start at >=2 layers: the 1-layer compile can specialize
    # one-time reshards differently, which would poison the delta
    if cfg.family == "hybrid":
        plen = len(cfg.block_pattern)
        tail = cfg.num_layers % plen
        l1, l2 = 2 * plen + tail, 3 * plen + tail
        steps_full = cfg.num_layers // plen
        return (cfg.replace(num_layers=l1), cfg.replace(num_layers=l2),
                float(steps_full - 2))
    if cfg.family == "encdec":
        return (cfg.replace(num_layers=2, enc_layers=2),
                cfg.replace(num_layers=4, enc_layers=4),
                float((cfg.num_layers - 2) / 2))
    if cfg.num_experts and cfg.moe_period > 1:
        p = cfg.moe_period
        return (cfg.replace(num_layers=2 * p), cfg.replace(num_layers=4 * p),
                float((cfg.num_layers // p - 2) / 2))
    return (cfg.replace(num_layers=2), cfg.replace(num_layers=4),
            float((cfg.num_layers - 2) / 2))


def cost_of(cfg, shape, mctx) -> dict:
    lowered, _ = lower_cell(cfg, shape, mctx)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_text(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(coll.values())),
        "coll_by_op": coll,
        "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)),
    }


def model_flops_per_device(cfg: ArchConfig, shape: ShapeConfig,
                           n_dev: int) -> float:
    """Analytic useful FLOPs (6ND train / 2ND inference + attention term)."""
    # active params ~ sum of per-layer matmul params actually used per token
    d, hd = cfg.d_model, cfg.head_dim
    per_layer = 0
    if cfg.num_heads:
        per_layer += d * (cfg.num_heads + 2 * cfg.num_kv_heads) * hd \
            + cfg.num_heads * hd * d
    if cfg.family == "ssm":
        d_in = cfg.d_inner
        per_layer += d * (2 * d_in + 2 * cfg.ssm_state + cfg.ssm_nheads) \
            + d_in * d
    elif cfg.family == "hybrid":
        plen = len(cfg.block_pattern)
        rec_frac = cfg.block_pattern.count("rec") / plen
        r = cfg.lru_width
        rec = 2 * d * r + 2 * r * r + r * d + 3 * d * cfg.d_ff
        att = (d * (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
               + cfg.num_heads * hd * d + 3 * d * cfg.d_ff)
        per_layer = rec_frac * rec + (1 - rec_frac) * att
    elif cfg.num_experts:
        dense_ffn = 3 * d * cfg.d_ff
        moe_ffn = cfg.top_k * 3 * d * cfg.d_ff \
            + (3 * d * cfg.d_ff if cfg.shared_expert else 0)
        frac_moe = 1.0 / cfg.moe_period
        per_layer += (1 - frac_moe) * dense_ffn + frac_moe * moe_ffn
    else:
        per_layer += 3 * d * cfg.d_ff
    n_layers = cfg.num_layers + (cfg.enc_layers or 0)
    n_active = per_layer * n_layers + d * cfg.padded_vocab  # + unembed
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    flops = mult * n_active * tokens
    # attention context term: fwd = 4*H*hd*tokens*ctx_avg (qk + pv matmuls);
    # causal averages ctx/2; train multiplies by 3 (fwd + bwd)
    if cfg.num_heads:
        ctx = shape.seq_len
        if cfg.window:
            ctx = min(ctx, cfg.window)
        if cfg.chunk_attn:
            ctx = min(ctx, cfg.chunk_attn)
        ctx_avg = ctx if shape.kind == "decode" else ctx / 2
        att = 4 * cfg.num_heads * hd * tokens * ctx_avg * cfg.num_layers
        if cfg.family == "encdec":   # enc self (bidir, enc_seq) + cross
            att = 4 * cfg.num_heads * hd * tokens * ctx_avg * cfg.num_layers
            att += 4 * cfg.num_heads * hd * tokens * cfg.enc_seq \
                * cfg.num_layers
            enc_tokens = shape.global_batch * cfg.enc_seq
            att += 4 * cfg.num_heads * hd * enc_tokens * cfg.enc_seq \
                * cfg.enc_layers
        if shape.kind == "train":
            att *= 3
        flops += att
    return flops / n_dev


def model_bytes_per_device(cfg: ArchConfig, shape: ShapeConfig,
                           n_dev: int) -> float:
    """Ideal HBM traffic: weights once + cache once + activations floor."""
    from repro.models.model import get_model
    import numpy as np
    model = get_model(cfg)
    shapes = model.param_shapes(cfg)
    pbytes = sum(int(np.prod(s)) for s in jax.tree.leaves(
        shapes, is_leaf=lambda x: isinstance(x, tuple))) \
        * (2 if cfg.param_dtype == "bfloat16" else 4)
    total = float(pbytes)
    if shape.kind == "train":
        total *= 4.0          # params + grads + m + v round trip
    if shape.kind == "decode":
        cshapes = model.cache_shapes(cfg, shape.global_batch, shape.seq_len)
        total += 2.0 * sum(int(np.prod(s)) for s in jax.tree.leaves(
            cshapes, is_leaf=lambda x: isinstance(x, tuple))) * 2
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    total += tokens * cfg.d_model * 2 * 4      # activation floor
    return total / n_dev


def analyze_cell(arch: str, shape_name: str, mctx,
                 cfg_override: ArchConfig | None = None) -> dict:
    cfg = cfg_override or get_config(arch)
    shape = get_shape(shape_name)
    c1_cfg, c2_cfg, mult = depth_variants(cfg)
    with mctx.mesh:
        c1 = cost_of(c1_cfg, shape, mctx)
        c2 = cost_of(c2_cfg, shape, mctx)
        cf = cost_of(cfg, shape, mctx)
    n_dev = mctx.mesh.devices.size
    rec: dict = {"arch": arch, "shape": shape_name, "n_devices": n_dev}
    for key in ("flops", "bytes", "coll"):
        # clamp: SPMD occasionally specializes the shallow pair differently;
        # a negative per-layer delta is compile noise, not a real saving
        rec[key] = c1[key] + mult * max(c2[key] - c1[key], 0.0)
    rec["peak_bytes"] = cf["peak_bytes"]
    rec["coll_by_op"] = {k: c1["coll_by_op"].get(k, 0.0)
                         + mult * (c2["coll_by_op"].get(k, 0.0)
                                   - c1["coll_by_op"].get(k, 0.0))
                         for k in set(c1["coll_by_op"]) | set(c2["coll_by_op"])}
    rec["t_compute_s"] = rec["flops"] / PEAK_FLOPS_BF16
    rec["t_memory_s"] = rec["bytes"] / HBM_BW
    rec["t_collective_s"] = rec["coll"] / ICI_BW
    terms = {"compute": rec["t_compute_s"], "memory": rec["t_memory_s"],
             "collective": rec["t_collective_s"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    base_cfg, base_shape = get_config(arch), get_shape(shape_name)
    rec["model_flops"] = model_flops_per_device(base_cfg, base_shape, n_dev)
    rec["model_bytes"] = model_bytes_per_device(base_cfg, base_shape, n_dev)
    rec["useful_ratio"] = rec["model_flops"] / max(rec["flops"], 1.0)
    # ideal step time given the algorithm's intrinsic flops/bytes; the
    # roofline fraction is ideal/bound — 1.0 means the compiled program
    # does no work beyond the algorithm's floor on the binding resource.
    t_ideal = max(rec["model_flops"] / PEAK_FLOPS_BF16,
                  rec["model_bytes"] / HBM_BW)
    bound = max(max(terms.values()), 1e-12)
    rec["t_ideal_s"] = t_ideal
    rec["roofline_fraction"] = min(1.0, t_ideal / bound)
    return rec


SUGGEST = {
    "compute": "reduce recompute (remat policy) / pad-waste in attention "
               "head sharding",
    "memory": "fuse/relayout to cut HBM traffic; larger attention blocks; "
              "bf16 intermediates",
    "collective": "reshard to cut all-gather volume (FSDP axis choice), "
                  "overlap collectives with compute, int8 gradient sync",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="benchmarks/roofline_results.json")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)
    mctx = MeshCtx(mesh)
    cells = []
    if args.arch:
        shapes = [args.shape] if args.shape else applicable_shapes(
            get_config(args.arch))
        cells = [(args.arch, s) for s in shapes]
    else:
        for arch in list_archs():
            for s in applicable_shapes(get_config(arch)):
                cells.append((arch, s))

    records = []
    for arch, s in cells:
        try:
            rec = analyze_cell(arch, s, mctx)
            records.append(rec)
            print(f"{arch:26s} {s:12s} comp={rec['t_compute_s']*1e3:8.2f}ms "
                  f"mem={rec['t_memory_s']*1e3:8.2f}ms "
                  f"coll={rec['t_collective_s']*1e3:8.2f}ms "
                  f"bound={rec['bottleneck']:10s} "
                  f"useful={rec['useful_ratio']:.2f} "
                  f"roofline={rec['roofline_fraction']:.2f}", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{arch} {s} FAILED: {type(e).__name__}: {e}", flush=True)
            records.append({"arch": arch, "shape": s, "error": str(e)})
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
