"""Scenario subsystem: profile shapes, fault schedules, and end-to-end
controller behaviour under dynamic workloads (the Daedalus/Phoebe-style
evaluations), including the paper's Fig. 5 memory headline in miniature.
"""
import numpy as np
import pytest

from repro.core.controller import ControllerConfig
from repro.core.justin import JustinParams
from repro.data.nexmark import MEMORY_PRESSURED
from repro.scenarios import (Constant, Diurnal, FaultSchedule, KillTask,
                             Ramp, SetStraggler, Sinusoid, Spike, Step,
                             make_profile, parse_fault, run_scenario)
from repro.streaming.engine import StreamEngine
from repro.streaming.graph import Dataflow
from repro.streaming.operators import MapOp, SinkOp, SourceOp
from repro.data.nexmark import BidGen


# ------------------------------------------------------------- profiles
def test_profile_shapes():
    assert Constant(100.0)(0) == 100.0 and Constant(100.0)(1e9) == 100.0

    r = Ramp(start=10, end=110, duration_s=100, t0=50)
    assert r(0) == 10 and r(50) == 10
    assert r(100) == pytest.approx(60)
    assert r(150) == 110 and r(1000) == 110

    s = Spike(base=10, peak=90, t0=20, duration_s=10)
    assert s(19.9) == 10 and s(20) == 90 and s(29.9) == 90 and s(30) == 10

    d = Diurnal(low=10, high=110, period_s=100)
    assert d(0) == pytest.approx(10)          # midnight trough
    assert d(50) == pytest.approx(110)        # midday peak
    assert d(100) == pytest.approx(10)

    w = Sinusoid(mean=50, amplitude=20, period_s=40)
    assert w(0) == pytest.approx(50)
    assert w(10) == pytest.approx(70)
    assert w(30) == pytest.approx(30)

    st = Step(times=(0, 10, 20), rates=(5, 50, 25))
    assert st(0) == 5 and st(9.9) == 5 and st(10) == 50 and st(25) == 25
    with pytest.raises(ValueError):
        Step(times=(10, 0), rates=(1, 2))

    for name in ("constant", "ramp", "spike", "diurnal", "sinusoid", "step"):
        p = make_profile(name, 1000.0, 100.0)
        assert 0.0 <= p(0.0) <= 1000.0
    with pytest.raises(ValueError):
        make_profile("nope", 1.0, 1.0)


def test_profiles_never_negative():
    w = Sinusoid(mean=10, amplitude=100, period_s=40)
    assert w(30) == 0.0                       # clamped


# ---------------------------------------------------------------- faults
def test_fault_schedule_fires_once_in_order():
    f = Dataflow("t")
    f.chain(SourceOp("source", BidGen(seed=1)),
            MapOp("m", lambda b: b), SinkOp("sink"))
    f.nodes["m"].parallelism = 2
    eng = StreamEngine(f, seed=0)
    sched = FaultSchedule([SetStraggler(5.0, "m", 0, 8.0, duration_s=10.0),
                           KillTask(12.0, "m", 1)])
    assert len(sched.pending) == 3            # straggler + recovery + kill
    assert sched.apply_due(eng, 4.9) == []
    fired = sched.apply_due(eng, 5.0)
    assert len(fired) == 1
    assert eng.tasks["m"][0].slowdown == 8.0
    fired = sched.apply_due(eng, 20.0)        # recovery (t=15) + kill (t=12)
    assert len(fired) == 2
    assert eng.tasks["m"][0].slowdown == 1.0  # recovered
    assert sched.apply_due(eng, 1e9) == []    # nothing left / no re-fire


def test_parse_fault():
    k = parse_fault("kill:30:window_join:2")
    assert isinstance(k, KillTask) and k.t == 30 and k.idx == 2
    s = parse_fault("straggle:10:op:0:20:5")
    assert isinstance(s, SetStraggler) and s.factor == 20 and s.duration_s == 5
    with pytest.raises(ValueError):
        parse_fault("explode:1:op")


# ----------------------------------------------------- end-to-end scenarios
def quick_cfg(policy):
    """Half-length decision windows: same controller logic, ~2x faster —
    keeps the scenario suite inside the tier-1 budget."""
    return ControllerConfig(policy=policy, decision_window_s=60.0,
                            stabilization_s=30.0,
                            justin=JustinParams(max_level=2))


def test_ramp_scenario_reconfigures_and_recovers():
    """Rising load forces at least one scale-out; the final window meets
    its (moving) target."""
    res = run_scenario("justin", "q5", "ramp", windows=6,
                       cfg=quick_cfg("justin"))
    assert res.steps >= 1
    assert res.recovered()
    # the enacted parallelism actually grew with the load
    p0 = dict(res.history[0].config)["hot_auctions"][0]
    p1 = dict(res.final.config)["hot_auctions"][0]
    assert p1 > p0


def test_spike_scenario_reconfigures_and_recovers():
    res = run_scenario("ds2", "q5", "spike", windows=6,
                       cfg=quick_cfg("ds2"))
    assert res.steps >= 1
    assert res.recovered()
    # targets in the history reflect the spike shape (base != peak windows)
    targets = {h.target for h in res.history}
    assert len(targets) >= 2


def test_scenario_with_straggler_still_recovers():
    res = run_scenario(
        "justin", "q5", "ramp", windows=6, cfg=quick_cfg("justin"),
        faults=[SetStraggler(12.0, "hot_auctions", 0, 15.0,
                             duration_s=12.0)])
    assert len(res.faults_fired) == 2         # injection + recovery
    assert res.recovered()


def test_scenario_with_kill_task_keeps_flowing():
    res = run_scenario("justin", "q5", "constant", windows=4,
                       cfg=quick_cfg("justin"),
                       faults=[KillTask(10.0, "hot_auctions", 0)])
    assert len(res.faults_fired) == 1
    assert res.final.achieved_rate > 0
    assert res.recovered()


def test_diurnal_scenario_tracks_load():
    res = run_scenario("justin", "q5", "diurnal", windows=8,
                       cfg=quick_cfg("justin"))
    assert res.recovered()
    assert len({round(h.target) for h in res.history}) >= 3


@pytest.mark.slow
def test_justin_memory_at_most_ds2_on_pressured_q8():
    """Fig. 5's headline in miniature: on the memory-pressured q8 scenario
    Justin converges with no more memory than DS2 (and fewer cores)."""
    assert "q8" in MEMORY_PRESSURED
    ds2 = run_scenario("ds2", "q8", "constant", windows=8)
    justin = run_scenario("justin", "q8", "constant", windows=8)
    assert ds2.recovered() and justin.recovered()
    assert justin.final.memory_mb <= ds2.final.memory_mb
    assert justin.final.cpu_cores <= ds2.final.cpu_cores


def test_justin_memory_at_most_ds2_on_pressured_q11_ramp():
    """Same comparison under a dynamic ramp on the other pressured query."""
    assert "q11" in MEMORY_PRESSURED
    ds2 = run_scenario("ds2", "q11", "ramp", windows=6,
                       cfg=quick_cfg("ds2"))
    justin = run_scenario("justin", "q11", "ramp", windows=6,
                          cfg=quick_cfg("justin"))
    assert ds2.recovered() and justin.recovered()
    assert justin.final.memory_mb <= ds2.final.memory_mb
