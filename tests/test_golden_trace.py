"""Golden-trace regression: the engine fast path (coalesced processing,
vectorized CLOCK, incremental memtable view, deduped probes) must reproduce
the SEED engine's autoscaling decisions byte-for-byte.

``tests/data/golden_autoscale.json`` was captured from the pre-fast-path
engine on fixed-seed Nexmark episodes.  These tests re-run the episodes and
compare every enacted configuration C^t, the trigger sequence, and the step
counts — if an engine change shifts any policy decision, they fail.
"""
import json
import pathlib

import pytest

from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.core.policy import make_policy
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.streaming.engine import StreamEngine

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "data" / "golden_autoscale.json")
    .read_text())


def run_episode(qname: str, policy: str) -> dict:
    meta = GOLDEN["_meta"]
    flow = QUERIES[qname]()
    eng = StreamEngine(flow, seed=meta["seed"])
    cfg = ControllerConfig(
        policy=policy, justin=JustinParams(max_level=meta["max_level"]))
    # construct the policy explicitly through the registry: the traces pin
    # that registry-built ds2/justin make byte-identical decisions
    ctl = AutoScaler(eng, TARGET_RATES[qname], cfg,
                     policy=make_policy(policy, cfg))
    hist = ctl.run()
    return {
        "steps": ctl.steps,
        "windows": len(hist),
        "configs": [sorted((op, list(pc)) for op, pc in h.config.items())
                    for h in hist],
        "triggered": [h.triggered for h in hist],
        "cpu_cores": hist[-1].cpu_cores,
        "memory_mb": hist[-1].memory_mb,
        "final_rate_ok": hist[-1].achieved_rate
        >= 0.97 * TARGET_RATES[qname],
    }


def assert_matches_golden(key: str) -> None:
    got = run_episode(*key.split("_"))
    want = GOLDEN[key]
    # dict-compare field by field for actionable failure messages
    assert got["steps"] == want["steps"], (got["steps"], want["steps"])
    assert got["triggered"] == want["triggered"]
    got_cfg = got["configs"]
    want_cfg = [[(op, list(pc)) for op, pc in w] for w in want["configs"]]
    got_cfg = [[(op, list(pc)) for op, pc in w] for w in got_cfg]
    assert got_cfg == want_cfg
    assert got["cpu_cores"] == want["cpu_cores"]
    assert got["memory_mb"] == want["memory_mb"]
    assert got["final_rate_ok"] and want["final_rate_ok"]


def test_golden_q8_justin():
    """The ISSUE's headline trace: fixed-seed q8, Justin decisions
    (scale-out, cancel-out + scale-up) byte-identical to the seed."""
    assert_matches_golden("q8_justin")


def test_golden_q11_justin():
    assert_matches_golden("q11_justin")


def test_golden_q11_ds2():
    assert_matches_golden("q11_ds2")


@pytest.mark.slow
def test_golden_q8_ds2():
    assert_matches_golden("q8_ds2")


def test_golden_q8_justin_exhibits_hybrid_decisions():
    """The pinned q8 Justin trace must actually contain the Algorithm-1
    decision kinds the paper describes: a DS2 scale-out step and a
    cancel-out + memory scale-up (parallelism held, level raised)."""
    cfgs = [dict((op, tuple(pc)) for op, pc in w)
            for w in GOLDEN["q8_justin"]["configs"]]
    wj = [c["window_join"] for c in cfgs]
    scale_outs = any(b[0] > a[0] for a, b in zip(wj, wj[1:]))
    scale_ups = any(b[0] == a[0] and (b[1] or 0) > (a[1] or 0)
                    for a, b in zip(wj, wj[1:]))
    assert scale_outs and scale_ups, wj
