"""reprolint: the rule panel against its fixtures, baseline round-trip,
and the CLI contract (exit codes, JSON schema, self-check).

The fixture files under ``tools/lint/fixtures/`` are the ground truth:
each declares a pretend path (``# as: src/repro/...``) and annotates
every expected finding with ``# expect: RULE`` on its line.  The test
suite re-runs them through :func:`lint_source` (the same entry point the
CLI self-check uses) so a rule regression fails here *and* in CI's
``--self-check`` step.
"""
import json
import pathlib
import re
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))           # tools/ is not an installed package

from tools.lint.core import (all_rules, lint_source, load_baseline,  # noqa: E402
                             split_new, write_baseline)

FIXTURES = REPO / "tools" / "lint" / "fixtures"
_AS = re.compile(r"^#\s*as:\s*(\S+)\s*$", re.MULTILINE)
_EXPECT = re.compile(r"#\s*expect:\s*([A-Z][0-9]+(?:\s*,\s*[A-Z][0-9]+)*)")


def fixture_cases():
    for p in sorted(FIXTURES.glob("*.py")):
        src = p.read_text()
        m = _AS.search(src)
        relpath = m.group(1) if m else f"tools/lint/fixtures/{p.name}"
        expected = set()
        for i, line in enumerate(src.splitlines(), 1):
            em = _EXPECT.search(line)
            if em:
                for rule in re.split(r"\s*,\s*", em.group(1)):
                    expected.add((i, rule))
        yield pytest.param(src, relpath, expected, id=p.stem)


def run_cli(*args, cwd=REPO):
    return subprocess.run([sys.executable, "-m", "tools.lint", *args],
                          cwd=cwd, capture_output=True, text=True)


# --------------------------------------------------------------- rule panel

@pytest.mark.parametrize("src,relpath,expected", fixture_cases())
def test_fixture_findings_exact(src, relpath, expected):
    """Every annotated line fires exactly its rule; nothing else fires."""
    got = {(f.line, f.rule) for f in lint_source(src, relpath).findings}
    assert got == expected


def test_every_rule_has_a_known_bad_fixture():
    """The fixture suite exercises the WHOLE panel — a new rule without a
    fixture fails here before it ships unverified."""
    covered = set()
    for _src, _rel, expected in (p.values for p in fixture_cases()):
        covered |= {rule for _line, rule in expected}
    assert covered == {r.id for r in all_rules()}


def test_suppression_counted_not_hidden():
    src = ("import numpy as np\n"
           "def f(xs):\n"
           "    return np.argsort(xs)  # reprolint: ignore[D103]\n")
    res = lint_source(src, "src/repro/core/x.py")
    assert res.findings == [] and res.suppressed == 1
    # a suppression for a DIFFERENT rule does not silence this one
    src2 = src.replace("[D103]", "[F201]")
    res2 = lint_source(src2, "src/repro/core/x.py")
    assert [f.rule for f in res2.findings] == ["D103"]


def test_scope_pretend_paths():
    """The same source fires in sim scope and stays quiet outside it."""
    src = "import numpy as np\norder = np.argsort([3, 1, 2])\n"
    assert [f.rule for f in
            lint_source(src, "src/repro/core/x.py").findings] == ["D103"]
    assert lint_source(src, "src/repro/models/x.py").findings == []


def test_frozen_legacy_store_is_grandfathered_not_clean():
    """state/legacy.py is the A/B differential baseline and must never be
    edited — its real D103 finding lives in the committed baseline, not
    in a fix."""
    baseline = load_baseline(str(REPO / "tools" / "lint" / "baseline.json"))
    assert any(k.startswith("D103:src/repro/state/legacy.py:")
               for k in baseline)


# ----------------------------------------------------------------- baseline

def test_baseline_round_trip(tmp_path):
    src = ("import numpy as np\n"
           "def f(xs):\n"
           "    return np.argsort(xs)\n")
    findings = lint_source(src, "src/repro/core/x.py").findings
    assert findings
    bl = tmp_path / "baseline.json"
    write_baseline(str(bl), findings)
    new, old = split_new(findings, load_baseline(str(bl)))
    assert new == [] and old == findings


def test_baseline_is_line_shift_resilient():
    """Keys are rule:path:stripped-line — inserting unrelated lines above
    a grandfathered finding must not make it 'new'."""
    src = "import numpy as np\ndef f(xs):\n    return np.argsort(xs)\n"
    shifted = "import numpy as np\n\n\n\ndef f(xs):\n    return np.argsort(xs)\n"
    a = lint_source(src, "src/repro/core/x.py").findings
    b = lint_source(shifted, "src/repro/core/x.py").findings
    assert a[0].line != b[0].line and a[0].key == b[0].key


def test_baseline_multiset_budget():
    """Two identical violations with one baselined: exactly one is new."""
    from collections import Counter
    src = ("import numpy as np\n"
           "def f(xs):\n"
           "    return np.argsort(xs)\n"
           "def g(xs):\n"
           "    return np.argsort(xs)\n")
    findings = lint_source(src, "src/repro/core/x.py").findings
    assert len(findings) == 2 and findings[0].key == findings[1].key
    new, old = split_new(findings, Counter({findings[0].key: 1}))
    assert len(new) == 1 and len(old) == 1


# ---------------------------------------------------------------------- CLI

def test_cli_clean_on_pr_head():
    """The committed baseline grandfathers everything that remains: the
    acceptance gate `python -m tools.lint --fail-on-new` exits 0."""
    r = run_cli("--fail-on-new", "--quiet")
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_fails_with_location_on_injected_regression(tmp_path):
    bad = tmp_path / "regression.py"
    bad.write_text("import numpy as np\n"
                   "def f(xs):\n"
                   "    return np.argsort(xs)\n")
    r = run_cli(str(bad), "--fail-on-new")
    assert r.returncode == 1
    assert re.search(r"regression\.py:3:\d+: D103", r.stdout)


def test_cli_json_schema(tmp_path):
    bad = tmp_path / "regression.py"
    bad.write_text("import numpy as np\n"
                   "def f(xs):\n"
                   "    return np.argsort(xs)\n")
    r = run_cli(str(bad), "--json")
    doc = json.loads(r.stdout)
    assert doc["version"] == 1
    assert doc["files"] == 1 and doc["new"] == 1 and doc["baselined"] == 0
    assert isinstance(doc["suppressed"], int)
    assert doc["counts"] == {"D103": 1}
    (f,) = doc["findings"]
    assert set(f) == {"rule", "severity", "path", "line", "col", "message",
                      "key", "baselined"}
    assert f["rule"] == "D103" and f["line"] == 3 and f["baselined"] is False
    assert f["key"].startswith("D103:") and f["severity"] == "error"


def test_cli_self_check_passes():
    r = run_cli("--self-check", "--quiet")
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_list_rules_covers_panel():
    r = run_cli("--list-rules")
    assert r.returncode == 0
    for rule in all_rules():
        assert rule.id in r.stdout


def test_cli_unknown_rule_id_is_an_error():
    r = run_cli("--rules", "Z999")
    assert r.returncode != 0


# ------------------------------------------- interprocedural passes (PR 9)
# The whole-program passes (callgraph / T501 / T502 / B601 / A701) get the
# same fixture coverage as the per-file rules above; these tests pin the
# CROSS-FILE behaviour a single-file fixture cannot express.

from tools.lint.callgraph import build_callgraph  # noqa: E402
from tools.lint.core import (lint_units, parse_file,  # noqa: E402
                             parse_source)

UTIL = ("import time\n"
        "def now():\n"
        "    return time.time()\n")
GOLDEN_CALLER = ("from repro.core.zz_util import now\n"
                 "def stamp(batch):\n"
                 "    return now()\n")


def _units():
    return [parse_source(UTIL, "src/repro/core/zz_util.py"),
            parse_source(GOLDEN_CALLER, "src/repro/streaming/events.py")]


def test_callgraph_resolves_cross_module_calls_and_sinks():
    units = _units()
    cg = build_callgraph(units)
    f_now = "src/repro/core/zz_util.py::now"
    f_stamp = "src/repro/streaming/events.py::stamp"
    assert f_now in cg.edges[f_stamp]
    # alias expansion: ``time.time()`` surfaces as an external chain
    ext = {s.external for s in cg.sites_by_caller[f_now] if s.external}
    assert ("time", "time") in ext
    # reverse closure from the sink-bearing callee reaches the caller
    seen, parent = cg.reverse_closure({f_now})
    assert f_stamp in seen and parent[f_stamp] == f_now


def test_taint_pass_flags_cross_file_wall_clock_in_golden_module():
    findings = lint_units(_units(), all_rules({"T501"})).findings
    assert [(f.path, f.rule) for f in findings] == \
        [("src/repro/streaming/events.py", "T501")]
    assert "time.time" in findings[0].message


# ------------------------------------------- T501 obs carve-out (PR 10)
# The observability layer may read perf_counter for self-profiling; a
# golden module calling into it as a DISCARDED statement must stay clean,
# while a captured obs value — or the same shape outside src/repro/obs/ —
# is still a finding.  See tools/lint/taint.py module docstring.

OBS_TRACE = ("import time\n"
             "def zz_span(name):\n"
             "    time.perf_counter()\n")


def test_taint_obs_scope_discarded_call_is_exempt():
    # both a direct discarded call AND an indirect one through a local
    # helper: the carve-out works at propagation level, so the helper
    # itself never becomes tainted
    units = [parse_source(OBS_TRACE, "src/repro/obs/zz_trace.py"),
             parse_source(
                 "from repro.obs.zz_trace import zz_span\n"
                 "def _note():\n"
                 "    zz_span('w')\n"
                 "def stamp(batch):\n"
                 "    _note()\n"
                 "    zz_span('x')\n"
                 "    return len(batch)\n",
                 "src/repro/streaming/events.py")]
    assert lint_units(units, all_rules({"T501"})).findings == []


def test_taint_obs_scope_captured_value_still_flagged():
    units = [parse_source(OBS_TRACE, "src/repro/obs/zz_trace.py"),
             parse_source(
                 "from repro.obs.zz_trace import zz_span\n"
                 "def stamp(batch):\n"
                 "    return zz_span('x')\n",
                 "src/repro/streaming/events.py")]
    findings = lint_units(units, all_rules({"T501"})).findings
    assert [(f.path, f.rule) for f in findings] == \
        [("src/repro/streaming/events.py", "T501")]
    assert "time.perf_counter" in findings[0].message


def test_taint_obs_scope_is_path_scoped_not_shape_scoped():
    # the same write-only shape OUTSIDE src/repro/obs/ gets no carve-out:
    # a discarded call can still have arbitrary side effects, only the
    # audited obs package is trusted to be write-only
    units = [parse_source(OBS_TRACE, "src/repro/core/zz_trace.py"),
             parse_source(
                 "from repro.core.zz_trace import zz_span\n"
                 "def stamp(batch):\n"
                 "    zz_span('x')\n"
                 "    return len(batch)\n",
                 "src/repro/streaming/events.py")]
    findings = lint_units(units, all_rules({"T501"})).findings
    assert [(f.path, f.rule) for f in findings] == \
        [("src/repro/streaming/events.py", "T501")]


def test_emit_only_restricts_reporting_not_analysis():
    # the --changed-only contract: the whole program is still analyzed
    # (the cross-file taint fact comes from core/zz_util), but findings are
    # only REPORTED for the changed files.
    rules = all_rules({"T501", "D102"})
    golden_only = lint_units(_units(), rules,
                             emit_only={"src/repro/streaming/events.py"})
    assert [f.rule for f in golden_only.findings] == ["T501"]
    util_only = lint_units(_units(), rules,
                           emit_only={"src/repro/core/zz_util.py"})
    assert [f.rule for f in util_only.findings] == ["D102"]
    assert lint_units(_units(), rules, emit_only=set()).findings == []


def test_cli_changed_only_smoke():
    r = run_cli("--changed-only", "--quiet")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "changed reported" in r.stdout


def test_cli_changed_only_rejects_write_baseline():
    r = run_cli("--changed-only", "--write-baseline")
    assert r.returncode == 2


def test_parse_cache_reuses_units_across_runs():
    path = "src/repro/streaming/events.py"
    assert parse_file(path) is parse_file(path)


def test_bitwidth_symbolic_modulus_proves_low_field():
    guarded = ("import numpy as np\n"
               "_S = np.int64(45)\n"
               "def pack(srcs, keys):\n"
               "    n = len(srcs)\n"
               "    assert n < (1 << 18)\n"
               "    keys = keys % (np.int64(1) << _S)\n"
               "    return (np.arange(n) << _S) + keys\n")
    assert lint_source(guarded, "src/repro/state/zz.py").findings == []
    unguarded = guarded.replace("    assert n < (1 << 18)\n", "")
    assert [f.rule for f in
            lint_source(unguarded, "src/repro/state/zz.py").findings] \
        == ["B601"]


def test_escape_pass_tracks_aliasing_through_private_helper():
    src = ("import numpy as np\n"
           "class Box:\n"
           "    def __init__(self):\n"
           "        self._a = np.zeros(4)\n"
           "    def _live(self):\n"
           "        return self._a\n"
           "    def view(self):\n"
           "        return self._live()\n"
           "    def safe(self):\n"
           "        return self._live().copy()\n")
    findings = lint_source(src, "src/repro/state/zz.py").findings
    assert [(f.rule, f.line) for f in findings] == [("A701", 8)]
