"""Differential store-testing harness: columnar vs legacy vs dict model.

Random op sequences (put_batch / get_batch / items / resize / snapshot /
bulk_load) drive the columnar ``LSMStore`` and the frozen pre-columnar
``LegacyLSMStore`` in lockstep, asserting identical *observable* state
after every op:

* get_batch values + found masks (and both must match a python-dict
  model with newest-write-wins semantics);
* the full metrics snapshot — every θ/τ input the policies read;
* bit-identical CLOCK cache arrays (keys/vals/ref/hand);
* entry_count (the migration payload measure) and items();
* resize-spill and snapshot/restore semantics pinned in PR 1/PR 4.

This is the gate that makes ripping out store internals safe: any
store-internal change must pass this harness BEFORE a golden-trace regen
is even considered (see docs/golden-traces.md).

Sequences are generated from pinned numpy seeds so the suite needs no
optional dependencies; when ``hypothesis`` is installed an extra
property-driven case searches the same op space adversarially.
"""
import numpy as np
import pytest

from repro.state.legacy import LegacyLSMStore
from repro.state.lsm import LSMStore

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

N_SEQUENCES = 220                    # acceptance floor is 200 per pair
KEYSPACE = 4_000
CACHE_ATTRS = ("cache_keys", "cache_vals", "cache_ref", "cache_hand")


def _gen_sequence(seed: int):
    """One pinned-seed op sequence: (memory_mb, use_filter, [op...])."""
    r = np.random.default_rng(seed)
    memory_mb = float(r.choice([0.25, 0.5, 2.0]))   # tiny => flush/compact
    use_filter = seed % 5 == 0                      # annihilation coverage
    ops = []
    for _ in range(int(r.integers(6, 14))):
        kind = r.choice(["put", "put", "put", "get", "get", "items",
                         "resize", "snapshot", "bulk"])
        if kind == "put":
            n = int(r.integers(1, 1_200))
            ops.append(("put",
                        r.integers(0, KEYSPACE, n).astype(np.int64),
                        r.integers(0, 1 << 30, (n, 2)).astype(np.int32)))
        elif kind == "get":
            n = int(r.integers(1, 600))
            # duplicate-laden probes exercise the θ/τ duplicate accounting
            q = r.integers(0, KEYSPACE + 500, n).astype(np.int64)
            if n > 10 and r.random() < 0.5:
                q[n // 2:] = q[: n - n // 2]
            ops.append(("get", q))
        elif kind == "resize":
            ops.append(("resize", float(r.choice([0.25, 0.5, 2.0, 8.0]))))
        elif kind == "bulk":
            n = int(r.integers(1, 800))
            ops.append(("bulk",
                        r.integers(0, KEYSPACE, n).astype(np.int64),
                        r.integers(0, 1 << 30, (n, 2)).astype(np.int32)))
        else:
            ops.append((kind,))
    ops.append(("items",))
    return memory_mb, use_filter, ops


def _assert_state_equal(a: LSMStore, b: LegacyLSMStore, tag: str) -> None:
    assert a.metrics.snapshot() == b.metrics.snapshot(), tag
    assert a.entry_count == b.entry_count, tag
    assert a.memtable_cap == b.memtable_cap, tag
    assert (a.cache_sets, a.cache_ways) == (b.cache_sets, b.cache_ways), tag
    for attr in CACHE_ATTRS:
        np.testing.assert_array_equal(getattr(a, attr), getattr(b, attr),
                                      err_msg=tag)


def _run_sequence(seed: int) -> None:
    memory_mb, use_filter, ops = _gen_sequence(seed)
    col = LSMStore(memory_mb, value_words=2)
    leg = LegacyLSMStore(memory_mb, value_words=2)
    model: dict[int, tuple] = {}
    # bulk_load is a pre-population fast path: it installs its run BELOW
    # the live memtable and never touches the cache.  Two consequences the
    # dict model must mirror when sequences interleave bulk after puts:
    #  * a key put in the current memtable epoch shadows a later bulk_load
    #    of it forever (the flush stacks the memtable run on top), so the
    #    model keeps the put value — epoch membership is replayed from the
    #    store's flush cadence (raw write count vs memtable_cap);
    #  * a key with a cached copy keeps serving the stale cached value
    #    until CLOCK evicts it, after which the bulk value surfaces — the
    #    value is eviction-order-dependent, so such keys are "tainted" and
    #    exempt from value (not presence) checks until a put or resize
    #    makes the model authoritative again.
    epoch_puts: set[int] = set()
    tainted: set[int] = set()
    mem_count = 0
    if use_filter:
        keep = lambda keys: keys % 3 != 0           # annihilate a third
        col.compact_filter = keep
        leg.compact_filter = keep

    for step, op in enumerate(ops):
        tag = f"seed={seed} step={step} op={op[0]}"
        if op[0] == "put":
            _, keys, vals = op
            col.put_batch(keys, vals)
            leg.put_batch(keys, vals)
            for k, v in zip(keys.tolist(), vals.tolist()):
                model[k] = tuple(v)
            tainted.difference_update(keys.tolist())
            off, cap = 0, col.memtable_cap
            while off < len(keys):
                take = min(cap - mem_count, len(keys) - off)
                epoch_puts.update(keys[off:off + take].tolist())
                mem_count += take
                off += take
                if mem_count >= cap:                 # flush boundary
                    epoch_puts.clear()
                    mem_count = 0
        elif op[0] == "get":
            _, q = op
            gc, fc = col.get_batch(q)
            gl, fl = leg.get_batch(q)
            np.testing.assert_array_equal(fc, fl, err_msg=tag)
            np.testing.assert_array_equal(gc, gl, err_msg=tag)
            if not use_filter:          # the dict model has no annihilation
                for i, k in enumerate(q.tolist()):
                    assert bool(fc[i]) == (k in model), tag
                    if fc[i] and k not in tainted:
                        assert tuple(gc[i].tolist()) == model[k], tag
        elif op[0] == "resize":
            col.resize(op[1])
            leg.resize(op[1])
            epoch_puts.clear()           # resize spills the memtable
            tainted.clear()              # ...and rebuilds an empty cache
            mem_count = 0
        elif op[0] == "bulk":
            _, keys, vals = op
            col.bulk_load(keys, vals)
            leg.bulk_load(keys, vals)
            cached = set(col.cache_keys[col.cache_keys >= 0].tolist())
            for k, v in zip(keys.tolist(), vals.tolist()):
                if k in epoch_puts:      # memtable puts shadow bulk runs
                    continue
                model[k] = tuple(v)      # the levels-resident truth
                if k in cached:          # stale cached copy may serve first
                    tainted.add(k)
        elif op[0] == "items":
            kc, vc = col.items()
            kl, vl = leg.items()
            np.testing.assert_array_equal(kc, kl, err_msg=tag)
            np.testing.assert_array_equal(vc, vl, err_msg=tag)
            if not use_filter:
                assert set(kc.tolist()) == set(model), tag
        elif op[0] == "snapshot":
            sc = col.snapshot()
            sl = leg.snapshot()
            np.testing.assert_array_equal(sc["keys"], sl["keys"],
                                          err_msg=tag)
            np.testing.assert_array_equal(sc["vals"], sl["vals"],
                                          err_msg=tag)
            assert sc["memory_mb"] == sl["memory_mb"], tag
            # weights are columnar-only; each occurrence counted once
            assert int(sc["weights"].sum()) >= len(sc["keys"]), tag
            rc = LSMStore.restore(sc)
            rl = LegacyLSMStore.restore(sl)
            np.testing.assert_array_equal(rc.items()[0], rl.items()[0],
                                          err_msg=tag)
            np.testing.assert_array_equal(rc.items()[1], rl.items()[1],
                                          err_msg=tag)
        _assert_state_equal(col, leg, tag)


@pytest.mark.parametrize("seed", range(N_SEQUENCES))
def test_columnar_matches_legacy_and_model(seed):
    _run_sequence(seed)


def test_sequence_space_covers_all_ops():
    """The pinned seeds must actually exercise every op kind and both
    filter modes — guards against the generator silently degenerating."""
    kinds = set()
    filters = set()
    for seed in range(N_SEQUENCES):
        _, use_filter, ops = _gen_sequence(seed)
        filters.add(use_filter)
        kinds.update(op[0] for op in ops)
    assert kinds == {"put", "get", "items", "resize", "snapshot", "bulk"}
    assert filters == {True, False}


def test_get_batch_uhint_identical():
    """A hinted probe (put decomposition shifted by a constant, the join
    operator's reuse pattern) must be bit-identical to the unhinted call —
    values, found masks, metric charges, and cache arrays."""
    rng = np.random.default_rng(42)
    a = LSMStore(0.5, value_words=2)
    b = LSMStore(0.5, value_words=2)
    for step in range(8):
        n = int(rng.integers(1, 900))
        keys = rng.integers(0, 3_000, n).astype(np.int64)
        vals = rng.integers(0, 1 << 30, (n, 2)).astype(np.int32)
        da = a.put_batch(keys, vals)
        b.put_batch(keys, vals)
        q = keys + 7                    # monotone shift of the same batch
        ga, fa = a.get_batch(q, uhint=(da[0] + 7, da[1]))
        gb, fb = b.get_batch(q)
        np.testing.assert_array_equal(fa, fb, err_msg=str(step))
        np.testing.assert_array_equal(ga, gb, err_msg=str(step))
        assert a.metrics.snapshot() == b.metrics.snapshot(), step
        for attr in CACHE_ATTRS:
            np.testing.assert_array_equal(getattr(a, attr), getattr(b, attr),
                                          err_msg=str(step))


def test_weight_semantics_columnar():
    """Z-set bookkeeping the legacy store can't express: weights count
    write occurrences, survive snapshot/restore, and annihilated weight
    is tracked when the compaction filter drops keys."""
    s = LSMStore(0.25, value_words=2)
    keys = np.array([7, 7, 7, 9], np.int64)
    vals = np.arange(8, dtype=np.int32).reshape(4, 2)
    s.put_batch(keys, vals)
    snap = s.snapshot()
    w = dict(zip(snap["keys"].tolist(), snap["weights"].tolist()))
    assert w == {7: 3, 9: 1}
    r = LSMStore.restore(snap)
    assert r.total_weight() == 4
    s2 = LSMStore(0.25, value_words=2)
    s2.compact_filter = lambda k: k % 2 != 0
    s2.put_batch(np.array([2, 2, 3], np.int64),
                 np.ones((3, 2), np.int32))
    s2._flush()
    assert s2.annihilated == 2                  # both writes of key 2
    assert s2.items()[0].tolist() == [3]


if HAS_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(10_000, 10_000_000))
    def test_columnar_matches_legacy_hypothesis(seed):
        """Adversarial search over the same sequence space (extra seeds)."""
        _run_sequence(seed)
