"""2d MoE sharding (§Perf cell B): exactness vs the EP path on a real
multi-device mesh.  Runs in a subprocess so the 8-device XLA flag does not
leak into the rest of the suite."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # heavy jax compiles; run with -m slow

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_config
from repro.models import get_model, MeshCtx

mesh = jax.make_mesh((2, 4), ("data", "model"))
mctx = MeshCtx(mesh)
cfg = get_config('llama4-maverick-400b-a17b').reduced().replace(
    num_experts=8, d_model=64, d_ff=128)
m = get_model(cfg)
params = m.init(cfg, jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
with mesh:
    l1 = float(m.loss(params, {'tokens': toks}, cfg, mctx))
cfg2 = cfg.replace(moe_shard="2d")
m2 = get_model(cfg2)
with mesh:
    l2 = float(m2.loss(params, {'tokens': toks}, cfg2, mctx))
assert abs(l1 - l2) < 1e-3, (l1, l2)
print("OK", l1, l2)
"""


def test_moe_2d_matches_expert_mode():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_moe_2d_single_device_fallback():
    """On one device the 2d mode must fall back and still be correct."""
    import jax
    from repro.configs import get_config
    from repro.models import get_model, cpu_mesh_ctx
    cfg = get_config('mixtral-8x7b').reduced().replace(moe_shard="2d")
    mctx = cpu_mesh_ctx()
    m = get_model(cfg)
    params = m.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
    loss = float(m.loss(params, {'tokens': toks}, cfg, mctx))
    assert 4.0 < loss < 7.0
