"""Reconfiguration runtime: migration plans, downtime pricing, paused
windows, and the migration-budgeted arbiter.

The property-style tests pin the planner's two contracts from the PR
issue: every key range assigned exactly once (per-operator tiling of the
hash keyspace, no gaps/overlaps), and plan MB reconciling exactly with
``placement.repack``'s ``MigrationCost``.  The scenario tests pin the
cost mechanisms' observable separation; the golden-compat test pins that
``instant`` changes nothing.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.core.placement import (bin_pack, default_tm_spec,
                                  placement_requests, repack, shared_pack)
from repro.core.policy import make_policy
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.migration import (KEYSPACE, CostModel, MigrationRuntime,
                             engine_store_stats, plan_migration)
from repro.streaming.engine import StreamEngine


# ------------------------------------------------------------ plan invariants
def random_config(rng) -> dict:
    ops = [f"op{i}" for i in range(rng.integers(1, 5))]
    return {op: (int(rng.integers(1, 9)),
                 int(rng.integers(0, 3)) if rng.random() < 0.7 else None)
            for op in ops}


def assert_tiles_keyspace(plan) -> None:
    """Every (tenant, op)'s key ranges tile [0, KEYSPACE) exactly once."""
    for (tenant, op), hs in plan.by_op().items():
        ranges = sorted(h.key_range for h in hs)
        assert ranges[0][0] == 0, (tenant, op)
        assert ranges[-1][1] == KEYSPACE, (tenant, op)
        for a, b in zip(ranges, ranges[1:]):
            assert a[1] == b[0], (tenant, op, a, b)   # no gap, no overlap
        # exactly one handoff per task
        assert len({h.task for h in hs}) == len(hs)


def test_plan_assigns_every_key_range_exactly_once():
    rng = np.random.default_rng(7)
    spec = default_tm_spec()
    for _ in range(25):
        old_cfg, new_cfg = random_config(rng), random_config(rng)
        # overlap the op sets so surviving/new/dropped tasks all occur
        new_cfg.update({op: pc for op, pc in random_config(rng).items()
                        if op in old_cfg})
        old = bin_pack(placement_requests(old_cfg), spec)
        new = bin_pack(placement_requests(new_cfg), spec)
        plan = plan_migration(old, new)
        assert_tiles_keyspace(plan)
        # plan covers exactly the new placement's tasks
        assert len(plan.handoffs) == sum(p for p, _ in new_cfg.values())


def test_plan_mb_reconciles_with_repack_migration_cost():
    """The plan's move subset must reproduce ``repack``'s MigrationCost
    bit-for-bit: same task count, same (grant) MB."""
    rng = np.random.default_rng(11)
    spec = default_tm_spec()
    for _ in range(25):
        tenants = {f"t{i}": placement_requests(random_config(rng),
                                               tenant=f"t{i}")
                   for i in range(int(rng.integers(1, 4)))}
        prev = shared_pack(tenants, spec)
        # one tenant re-shapes
        victim = sorted(tenants)[0]
        tenants2 = dict(tenants)
        tenants2[victim] = placement_requests(random_config(rng),
                                              tenant=victim)
        new, cost = repack(tenants2, spec, prev)
        plan = plan_migration(prev, new)
        got = plan.migration_cost()
        assert got.tasks_moved == cost.tasks_moved
        assert got.state_mb == pytest.approx(cost.state_mb)
        assert_tiles_keyspace(plan)


def test_measured_payload_rides_stats_not_grants():
    """With store_stats provided, payloads are measured: a stateless task
    (no store entry) carries 0 MB even though its DS2-style grant is
    nonzero; without stats the grants are the fallback."""
    spec = default_tm_spec()
    old = bin_pack(placement_requests({"m": (2, 0)}), spec)
    new = bin_pack(placement_requests({"m": (4, 0)}), spec)
    grant = plan_migration(old, new)
    measured = plan_migration(old, new, store_stats={})
    assert grant.transfer_mb > 0          # repartition priced at grants
    assert measured.transfer_mb == 0      # ...but nothing measured moves


# ------------------------------------------------------------- cost mechanics
def _mini_plan(moved_mb: float, stay_mb: float):
    spec = default_tm_spec()
    old = bin_pack(placement_requests({"a": (2, 0)}), spec)
    new = bin_pack(placement_requests({"a": (2, 0)}), spec)
    plan = plan_migration(old, new,
                          store_stats={("", "a", 0): moved_mb,
                                       ("", "a", 1): stay_mb})
    return plan


def test_cost_model_prices_mechanisms():
    plan = _mini_plan(100.0, 300.0)       # nothing moves: same placement
    assert CostModel("instant").price(plan).free
    sp = CostModel("savepoint", savepoint_mb_per_s=100.0,
                   restart_s=10.0).price(plan)
    # savepoint pays for ALL state even though nothing moved
    assert sp.downtime_s == pytest.approx(10.0 + 400.0 / 100.0)
    assert sp.moved_mb == pytest.approx(400.0)
    ho = CostModel("handoff", barrier_s=2.0).price(plan)
    assert ho.downtime_s == pytest.approx(2.0)   # only the barrier
    assert ho.moved_mb == 0.0
    with pytest.raises(ValueError):
        CostModel("teleport")


def test_handoff_prices_memory_only_below_parallelism_change():
    """Acceptance pin: under ``handoff``, a memory-only reconfiguration
    (state backend resized in place, no task relocated) is strictly
    cheaper than a parallelism change (whole-operator re-shuffle)."""
    eng = StreamEngine(QUERIES["q11"](), seed=3)
    cfg = ControllerConfig(justin=JustinParams(max_level=2))
    ctl = AutoScaler(eng, TARGET_RATES["q11"], cfg,
                     migration=MigrationRuntime("handoff"))
    cur = ctl.flow.config()
    p, lvl = cur["user_sessions"]
    mem_only = dict(cur, user_sessions=(p, (lvl or 0) + 1))
    par_change = dict(cur, user_sessions=(p * 2, lvl))
    rt = ctl.migration
    mem_cost = rt.model.price(rt.plan(ctl, cur, mem_only))
    par_cost = rt.model.price(rt.plan(ctl, cur, par_change))
    # warm q11 holds real session state, so the re-shuffle is priced > 0
    assert par_cost.moved_mb > 0
    assert mem_cost.moved_mb < par_cost.moved_mb
    assert mem_cost.downtime_s < par_cost.downtime_s


# ------------------------------------------------------------- paused windows
def test_run_paused_accrues_backlog_without_processing():
    eng = StreamEngine(QUERIES["q1"](), seed=0, warm=False)
    eng.run_paused(5.0, 10_000)
    assert eng.now == 5.0
    backlog = sum(t.queued_events for ts in eng.tasks.values() for t in ts)
    assert backlog == 5 * 10_000          # all arrivals queued...
    assert all(eng.stats[n].processed == 0 for n in eng.topo
               if n not in eng.flow.sources())     # ...nothing processed
    eng.run(5.0, 10_000)                  # resumed: the backlog drains
    drained = sum(eng.stats[n].processed for n in eng.topo
                  if n not in eng.flow.sources())
    assert drained > 0


def test_instant_runtime_is_a_strict_noop_on_golden_trace():
    """Acceptance pin: the default ``instant`` mechanism leaves the
    golden q11-justin episode untouched — decisions, step count, final
    placement all byte-identical to the pinned trace."""
    golden = json.loads(
        (pathlib.Path(__file__).parent / "data" / "golden_autoscale.json")
        .read_text())
    meta = golden["_meta"]
    eng = StreamEngine(QUERIES["q11"](), seed=meta["seed"])
    cfg = ControllerConfig(policy="justin",
                           justin=JustinParams(max_level=meta["max_level"]))
    ctl = AutoScaler(eng, TARGET_RATES["q11"], cfg,
                     policy=make_policy("justin", cfg),
                     migration=MigrationRuntime("instant"))
    hist = ctl.run()
    want = golden["q11_justin"]
    assert ctl.steps == want["steps"]
    assert [h.triggered for h in hist] == want["triggered"]
    got_cfg = [sorted((op, list(pc)) for op, pc in h.config.items())
               for h in hist]
    want_cfg = [[(op, list(pc)) for op, pc in w] for w in want["configs"]]
    assert got_cfg == want_cfg
    assert hist[-1].memory_mb == want["memory_mb"]
    # and the runtime priced every reconfiguration at zero downtime
    assert ctl.migration.events and all(
        e.cost.free for e in ctl.migration.events)
    assert all(h.reconfig_downtime == 0.0 for h in hist)


# --------------------------------------------------- pinned q8 separation
def test_q8_savepoint_threshold_pays_more_downtime_than_justin():
    """Acceptance pin: under ``savepoint`` on q8, threshold's doubling
    ratchet triggers on its own catch-up backlog and pays a fourth (and
    biggest) downtime window, while justin converges in three — fewer
    reconfiguration steps win once each step has a price."""
    cm = CostModel(mechanism="savepoint", savepoint_mb_per_s=6.0)
    results = {}
    for pol in ("justin", "threshold"):
        cfg = ControllerConfig(policy=pol,
                               justin=JustinParams(max_level=2))
        ctl = AutoScaler(StreamEngine(QUERIES["q8"](), seed=3),
                         TARGET_RATES["q8"], cfg,
                         policy=make_policy(pol, cfg),
                         migration=MigrationRuntime(cm))
        hist = ctl.run(max_windows=6)
        down = [h.reconfig_downtime for h in hist]
        results[pol] = {
            "steps": ctl.steps,
            "downtime_windows": sum(1 for d in down if d > 0),
            "downtime_s": sum(down),
            "recovered": hist[-1].achieved_rate
            >= 0.97 * TARGET_RATES["q8"],
        }
    j, t = results["justin"], results["threshold"]
    assert j["recovered"] and t["recovered"]
    assert j["steps"] == 3 and t["steps"] == 4
    assert t["downtime_windows"] > j["downtime_windows"]
    assert t["downtime_s"] > j["downtime_s"]


# ----------------------------------------------------- budgeted admission
def test_migration_budget_defers_reconfigs_that_move_too_much():
    """A per-window migration budget turns an over-budget admission into
    a deferral through the ordinary denial/retry path: tenant A's q1
    scale-out would shove tenant B's tasks onto another TM (tasks moved x
    state MB above the budget), so it is deferred every window; without a
    budget the identical request is admitted."""
    from repro.scenarios import Cluster, ColocatedSpec, run_colocated

    def pair(budget):
        cluster = Cluster(cpu_slots=24, memory_mb=30_000.0,
                          tm_spec=default_tm_spec())
        return run_colocated(
            [ColocatedSpec("ds2", "q1", name="A"),
             ColocatedSpec("static", "q1", name="B")],
            cluster, windows=3,
            cfg=ControllerConfig(decision_window_s=60.0,
                                 stabilization_s=30.0,
                                 justin=JustinParams(max_level=2)),
            migration_budget_mb=budget)

    free = pair(None)
    a_free = free.tenant("A")
    assert a_free.deferrals == [] and a_free.scaler.steps > 0

    capped = pair(100.0)          # below one displaced 158 MB task
    a_capped = capped.tenant("A")
    assert a_capped.deferrals, "scale-out should exceed the budget"
    assert set(a_capped.deferrals) <= set(a_capped.denials)
    # deferred reconfigs never enacted: strictly less churn than unbudgeted
    assert a_capped.scaler.steps < a_free.scaler.steps
    # the deferral is visible in the summary schema
    s = capped.summary()
    assert s["tenants"]["A"]["deferred_windows"] == a_capped.deferrals


def test_summary_emits_zeroed_migration_block_on_private_clusters():
    """Satellite: the ``migration`` block is part of the summary schema in
    BOTH cluster modes — zeroed totals on private fleets (which never
    repack) instead of a missing key."""
    from repro.scenarios import Cluster, ColocatedSpec, run_colocated
    res = run_colocated(
        [ColocatedSpec("static", "q1", name="A")],
        Cluster(cpu_slots=16, memory_mb=9_000.0), windows=1,
        cfg=ControllerConfig(decision_window_s=60.0, stabilization_s=30.0,
                             justin=JustinParams(max_level=2)))
    s = res.summary()
    assert s["migration"] == {"tasks_moved": 0, "state_mb": 0.0}


def test_engine_store_stats_measures_live_state():
    eng = StreamEngine(QUERIES["q11"](), seed=3)
    stats = engine_store_stats(eng, tenant="t")
    assert stats and all(k[0] == "t" for k in stats)
    assert all(mb >= 0 for mb in stats.values())
    p = eng.flow.nodes["user_sessions"].parallelism
    assert sum(1 for k in stats if k[1] == "user_sessions") == p
    assert sum(stats.values()) > 0        # warm q11 really holds state
