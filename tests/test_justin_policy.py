"""Algorithm 1 unit + property tests (policy invariants)."""
import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional [test] extra
from hypothesis import given, settings, strategies as st

from repro.core.justin import (JustinParams, JustinState, OperatorDecision,
                               commit, justin_policy)

P = JustinParams()


def mk_metrics(stateful=True, p=2, lvl=0, theta=0.5, tau=0.2, busy=0.9):
    return {"op": {"stateful": stateful, "parallelism": p,
                   "memory_level": lvl, "theta": theta, "tau_ms": tau,
                   "busyness": busy, "rate_in": 1e4, "rate_out": 1e4,
                   "selectivity": 1.0, "backlog": 10, "blocked": True,
                   "busy_s": 1.0, "processed": 1000,
                   "rate_processed": 1e4}}


def test_stateless_gets_bottom():
    """Lines 3-4: stateless operators lose their managed memory."""
    m = mk_metrics(stateful=False)
    out = justin_policy(None, m, {"op": 5}, JustinState(), P)
    assert out["op"].memory_level is None
    assert out["op"].parallelism == 5


def test_pressure_cancels_scale_out():
    """Lines 16-19: θ below Δθ => cancel DS2's scale-out, scale up."""
    m = mk_metrics(theta=0.5)
    out = justin_policy(None, m, {"op": 6}, JustinState(), P)
    assert out["op"].parallelism == 2          # canceled
    assert out["op"].memory_level == 1         # scaled up
    assert out["op"].scaled_up


def test_no_pressure_applies_ds2():
    m = mk_metrics(theta=0.95, tau=0.1)
    out = justin_policy(None, m, {"op": 6}, JustinState(), P)
    assert out["op"].parallelism == 6
    assert out["op"].memory_level == 0
    assert not out["op"].scaled_up


def test_tau_threshold_triggers_scale_up():
    m = mk_metrics(theta=0.95, tau=2.0)        # latency over Δτ=1ms
    out = justin_policy(None, m, {"op": 6}, JustinState(), P)
    assert out["op"].parallelism == 2
    assert out["op"].memory_level == 1


def test_improvement_continues_scale_up():
    """Lines 7-12: prior scale-up improved => scale up again."""
    state = JustinState()
    m0 = mk_metrics(theta=0.40)
    c0 = {"op": OperatorDecision(2, 1, True)}
    commit(state, c0, m0)
    m1 = mk_metrics(theta=0.60, lvl=1)         # improved well over hysteresis
    out = justin_policy(None, m1, {"op": 6}, state, P)
    assert out["op"].parallelism == 2
    assert out["op"].memory_level == 2
    assert out["op"].scaled_up


def test_no_improvement_rolls_back():
    """Lines 13-14: prior scale-up did not improve => roll memory back and
    let DS2's parallelism apply."""
    state = JustinState()
    m0 = mk_metrics(theta=0.50)
    commit(state, {"op": OperatorDecision(2, 1, True)}, m0)
    m1 = mk_metrics(theta=0.50, lvl=1)         # no improvement
    out = justin_policy(None, m1, {"op": 6}, state, P)
    assert out["op"].parallelism == 6
    assert out["op"].memory_level == 0
    assert not out["op"].scaled_up


def test_max_level_caps_scale_up():
    m = mk_metrics(theta=0.5, lvl=P.max_level - 1)
    state = JustinState()
    commit(state, {"op": OperatorDecision(2, P.max_level - 1, False)}, m)
    out = justin_policy(None, m, {"op": 6}, state, P)
    assert out["op"].parallelism == 6          # can't scale up: apply DS2
    assert out["op"].memory_level == P.max_level - 1


def test_capacity_sufficient_no_change():
    """Line 6: operators DS2 does not rescale keep their configuration."""
    m = mk_metrics(theta=0.1)                  # pressured but p unchanged
    state = JustinState()
    commit(state, {"op": OperatorDecision(2, 0, False)}, m)
    out = justin_policy(None, m, {"op": 2}, state, P)
    assert out["op"].parallelism == 2
    assert out["op"].memory_level == 0


@settings(max_examples=200, deadline=None)
@given(theta=st.one_of(st.none(), st.floats(0, 1)),
       tau=st.one_of(st.none(), st.floats(0, 5)),
       prev_theta=st.one_of(st.none(), st.floats(0, 1)),
       prev_tau=st.one_of(st.none(), st.floats(0, 5)),
       lvl=st.integers(0, 3), prev_up=st.booleans(),
       ds2_p=st.integers(1, 64), p=st.integers(1, 64),
       stateful=st.booleans())
def test_property_policy_invariants(theta, tau, prev_theta, prev_tau, lvl,
                                    prev_up, ds2_p, p, stateful):
    """For ANY metric values: memory level stays within [0, maxLevel-1] or ⊥;
    parallelism is DS2's or the previous one; vertical flag implies a level
    increase; stateless ops always get ⊥."""
    state = JustinState()
    m_prev = mk_metrics(stateful, p, lvl, prev_theta, prev_tau)
    commit(state, {"op": OperatorDecision(p, lvl, prev_up)}, m_prev)
    m = mk_metrics(stateful, p, lvl, theta, tau)
    out = justin_policy(None, m, {"op": ds2_p}, state, P)
    d = out["op"]
    if not stateful:
        assert d.memory_level is None
        assert d.parallelism == ds2_p
        return
    assert 0 <= d.memory_level < max(P.max_level, lvl + 1)
    assert d.parallelism in (ds2_p, p)
    if d.scaled_up:
        assert d.memory_level == lvl + 1
        assert d.parallelism == p              # scale-up cancels scale-out
