"""End-to-end behaviour tests for the paper's system: the full pipeline
(generator -> engine -> metrics -> DS2 -> Justin -> placement -> engine)
plus grad-compression and distribution plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.streaming.engine import StreamEngine
from repro.train.grad_compress import (compress_with_feedback,
                                       dequantize_int8, init_error_buf,
                                       quantize_int8)

pytestmark = pytest.mark.slow  # heavy jax compiles; run with -m slow


def test_full_autoscale_cycle_q3():
    """q3 converges for both policies and Justin never uses more CPU."""
    out = {}
    for policy in ("ds2", "justin"):
        flow = QUERIES["q3"]()
        eng = StreamEngine(flow, seed=5)
        ctl = AutoScaler(eng, 120_000, ControllerConfig(
            policy=policy, justin=JustinParams(max_level=2)))
        ctl.run()
        out[policy] = ctl.summary()
        assert out[policy]["achieved_rate"] >= 0.97 * 120_000
    assert out["justin"]["cpu_cores"] <= out["ds2"]["cpu_cores"]
    assert out["justin"]["memory_mb"] < out["ds2"]["memory_mb"]


def test_history_records_fig5_series():
    flow = QUERIES["q11"]()
    eng = StreamEngine(flow, seed=3)
    ctl = AutoScaler(eng, TARGET_RATES["q11"],
                     ControllerConfig(policy="justin"))
    hist = ctl.run()
    assert len(hist) >= 2
    for row in hist:
        assert row.cpu_cores > 0
        assert row.memory_mb > 0
        assert row.achieved_rate >= 0


def test_quantize_roundtrip_bounded_error(rng):
    x = jnp.asarray(rng.normal(size=(256, 64)) * 3, jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 1.01


def test_error_feedback_reduces_bias(rng):
    """Accumulated compressed-grad sum approaches the true sum."""
    g = jnp.asarray(rng.normal(size=(128,)), jnp.float32) * 1e-3
    grads = {"w": g}
    err = init_error_buf({"w": g})
    acc = jnp.zeros_like(g)
    for _ in range(50):
        qs, ss, err = compress_with_feedback(grads, err)
        acc = acc + dequantize_int8(qs["w"], ss["w"])
    rel = float(jnp.linalg.norm(acc - 50 * g) / jnp.linalg.norm(50 * g))
    assert rel < 0.05


def test_reduced_arch_matrix_one_step():
    """Every assigned arch trains one step end-to-end via the driver."""
    from repro.launch.train import train
    from repro.configs import list_archs
    for arch in list_archs():
        r = train(arch, steps=1, verbose=False)
        assert np.isfinite(r["final_loss"]), arch
