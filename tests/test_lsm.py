"""LSM state backend: correctness vs a dict oracle + invariants."""
import numpy as np
import pytest

# hypothesis is an optional [test] extra: only the property test needs it
# (with a pinned-seed fallback below).  A module-level importorskip here
# used to silently skip the WHOLE file — tools/check_collect.py now guards
# against that regressing.
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.state.lsm import LSMStore, LatencyModel


def make_store(mb=8.0, **kw):
    return LSMStore(mb, value_words=2, **kw)


def test_put_get_roundtrip(rng):
    s = make_store()
    keys = rng.choice(10_000, 500, replace=False).astype(np.int64)
    vals = rng.integers(0, 1 << 30, (500, 2)).astype(np.int32)
    s.put_batch(keys, vals)
    got, found = s.get_batch(keys)
    assert found.all()
    np.testing.assert_array_equal(got, vals)


def test_absent_keys_not_found(rng):
    s = make_store()
    s.put_batch(np.arange(100, dtype=np.int64),
                np.ones((100, 2), np.int32))
    got, found = s.get_batch(np.arange(200, 300).astype(np.int64))
    assert not found.any()


def test_overwrite_last_wins(rng):
    s = make_store()
    keys = np.arange(50, dtype=np.int64)
    s.put_batch(keys, np.full((50, 2), 1, np.int32))
    s.put_batch(keys, np.full((50, 2), 2, np.int32))
    got, found = s.get_batch(keys)
    assert found.all()
    assert (got == 2).all()


def test_flush_and_compaction_preserve_data(rng):
    s = LSMStore(0.5, value_words=2)           # tiny memtable: many flushes
    oracle = {}
    for _ in range(10):
        keys = rng.integers(0, 5_000, 1_000).astype(np.int64)
        vals = rng.integers(0, 1 << 30, (1_000, 2)).astype(np.int32)
        # dedupe within batch the same way the store does (last wins)
        s.put_batch(keys, vals)
        for k, v in zip(keys, vals):
            oracle[int(k)] = v
    assert s.metrics.flushes > 0
    probe = np.array(sorted(oracle), np.int64)
    got, found = s.get_batch(probe)
    assert found.all()
    expect = np.stack([oracle[int(k)] for k in probe])
    np.testing.assert_array_equal(got, expect)


def test_resize_preserves_data(rng):
    s = make_store(4.0)
    keys = np.arange(2_000, dtype=np.int64)
    vals = rng.integers(0, 100, (2_000, 2)).astype(np.int32)
    s.put_batch(keys, vals)
    s.resize(16.0)
    got, found = s.get_batch(keys)
    assert found.all()
    np.testing.assert_array_equal(got, vals)
    assert s.memory_mb == 16.0


def test_snapshot_restore(rng):
    s = make_store()
    keys = rng.choice(100_000, 3_000, replace=False).astype(np.int64)
    vals = rng.integers(0, 1 << 30, (3_000, 2)).astype(np.int32)
    s.put_batch(keys, vals)
    snap = s.snapshot()
    s2 = LSMStore.restore(snap)
    got, found = s2.get_batch(keys)
    assert found.all()
    np.testing.assert_array_equal(got, vals)


def test_memory_layout_paper_rules():
    """§3: memtable <= 64 MB and at least half the budget goes to cache."""
    s128 = LSMStore(128)
    assert s128.memtable_cap == 32 * 1024 * 1024 // 1000   # 32 MB memtable
    s256 = LSMStore(256)
    assert s256.memtable_cap == 64 * 1024 * 1024 // 1000   # 64 MB memtable
    s1024 = LSMStore(1024)
    assert s1024.memtable_cap == s256.memtable_cap          # capped at 64 MB


def test_compact_filter_drops_entries(rng):
    s = LSMStore(0.5, value_words=2)
    s.compact_filter = lambda keys: keys >= 500
    s.put_batch(np.arange(1_000, dtype=np.int64),
                np.ones((1_000, 2), np.int32))
    for _ in range(5):                          # force flush+compaction
        s.put_batch(np.arange(1_000, 2_000, dtype=np.int64),
                    np.ones((1_000, 2), np.int32))
    s._flush()
    keys, _ = s.items()
    assert (keys >= 500).all()


def test_cache_hit_rate_increases_with_memory(rng):
    """Takeaway 2: bigger cache => higher read hit rate (uniform reads)."""
    rates = []
    for mb in (2, 8, 32):
        s = LSMStore(mb, value_words=2)
        keys = np.arange(20_000, dtype=np.int64)
        vals = np.zeros((20_000, 2), np.int32)
        s.put_batch(keys, vals)
        s.prewarm_cache(keys, vals)
        for _ in range(5):
            s.get_batch(rng.integers(0, 20_000, 2_000).astype(np.int64))
        rates.append(s.metrics.cache_hit_rate)
    assert rates[0] < rates[1] < rates[2] or rates[2] > 0.95


def test_write_latency_insensitive_to_cache(rng):
    """Takeaway 3: cache size does not affect write cost."""
    taus = []
    for mb in (128, 1024):
        s = LSMStore(mb)
        keys = rng.integers(0, 1 << 20, 20_000).astype(np.int64)
        vals = np.zeros((20_000, 4), np.int32)
        s.put_batch(keys, vals)
        taus.append(s.metrics.access_latency_total_ms / 20_000)
    assert abs(taus[0] - taus[1]) / max(taus[0], taus[1]) < 0.5


def _check_store_matches_dict(ops):
    """Property body: LSM == python dict under any put sequence (last wins)."""
    s = LSMStore(0.25, value_words=1)           # tiny: exercises flush paths
    oracle = {}
    keys = np.array([k for k, _ in ops], np.int64)
    vals = np.array([[v] for _, v in ops], np.int32)
    for i in range(0, len(ops), 37):            # uneven batches
        s.put_batch(keys[i:i + 37], vals[i:i + 37])
        for k, v in zip(keys[i:i + 37], vals[i:i + 37]):
            oracle[int(k)] = int(v[0])
    probe = np.array(sorted(oracle), np.int64)
    got, found = s.get_batch(probe)
    assert found.all()
    assert [int(x) for x in got[:, 0]] == [oracle[int(k)] for k in probe]


if HAS_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 999), st.integers(0, 2**20)),
                    min_size=1, max_size=300))
    def test_property_store_matches_dict(ops):
        _check_store_matches_dict(ops)
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_property_store_matches_dict(seed):
        r = np.random.default_rng(seed)
        m = int(r.integers(1, 300))
        ops = list(zip(r.integers(0, 1000, m).tolist(),
                       r.integers(0, 1 << 20, m).tolist()))
        _check_store_matches_dict(ops)

