"""The pluggable scaling-policy API: registry construction, the protocol
hooks, the two new built-ins (static, threshold), a grid smoke over them,
and the memory-pressured q8/q11 pair co-located under threshold vs justin.

(The four golden traces in test_golden_trace.py pin that registry-built
ds2/justin stay decision-identical; this file covers the API surface.)
"""
import pytest

from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.core.policy import (DS2Policy, JustinPolicy, Proposal,
                               ScalingPolicy, available_policies,
                               make_policy, register_policy)
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.scenarios import Cluster, ColocatedSpec, run_colocated
from repro.scenarios.grid import run_grid
from repro.streaming.engine import StreamEngine


# ------------------------------------------------------------------ registry
def test_builtin_policies_registered():
    names = available_policies()
    assert {"ds2", "justin", "static", "threshold"} <= set(names)
    assert names == sorted(names)


def test_unknown_policy_error_lists_available():
    with pytest.raises(ValueError) as e:
        make_policy("dhalion-2", ControllerConfig())
    msg = str(e.value)
    assert "dhalion-2" in msg
    for name in available_policies():
        assert name in msg


def test_make_policy_constructs_fresh_instances():
    cfg = ControllerConfig(policy="justin")
    a, b = make_policy("justin", cfg), make_policy("justin", cfg)
    assert isinstance(a, JustinPolicy) and isinstance(b, JustinPolicy)
    assert a is not b and a.state is not b.state      # per-episode history
    assert a.name == "justin"
    assert isinstance(make_policy("ds2", cfg), DS2Policy)


def test_register_policy_roundtrip_and_type_check():
    @register_policy("test-noop")
    class NoopPolicy(ScalingPolicy):
        def propose(self, flow, metrics, target, cfg):
            return Proposal({op: (m["parallelism"], m["memory_level"])
                             for op, m in metrics.items()})
    try:
        assert "test-noop" in available_policies()
        made = make_policy("test-noop", ControllerConfig())
        assert isinstance(made, NoopPolicy) and made.name == "test-noop"
        with pytest.raises(TypeError):
            register_policy("test-bad")(object)
    finally:
        from repro.core.policy import _REGISTRY
        _REGISTRY.pop("test-noop", None)


# ----------------------------------------------------- protocol semantics
def _q1_scaler(policy: str) -> AutoScaler:
    cfg = ControllerConfig(policy=policy,
                           justin=JustinParams(max_level=2))
    eng = StreamEngine(QUERIES["q1"](), seed=3, warm=False)
    return AutoScaler(eng, TARGET_RATES["q1"], cfg)


def test_no_string_dispatch_left_in_controller():
    """The controller must consult only the policy object — the literal
    ``cfg.policy ==`` branches are gone."""
    import inspect
    import repro.core.controller as controller
    src = inspect.getsource(controller)
    assert "cfg.policy ==" not in src and 'policy == "' not in src


def test_resources_config_is_the_policy_memory_model():
    config = {"source": (1, None), "op": (4, 2), "sink": (1, None)}
    cfg = ControllerConfig()
    ds2 = make_policy("ds2", cfg)
    assert ds2.resources_config(config) == {
        "source": (1, 0), "op": (4, 0), "sink": (1, 0)}
    justin = make_policy("justin", cfg)
    assert justin.resources_config(config) == config     # per-level grants
    threshold = make_policy("threshold", cfg)
    assert threshold.resources_config(config) == ds2.resources_config(config)


def test_static_policy_never_reconfigures():
    s = _q1_scaler("static")
    hist = s.run(max_windows=4)
    assert s.steps == 0
    assert all(not h.triggered for h in hist)
    cfgs = {tuple(sorted(h.config.items())) for h in hist}
    assert len(cfgs) == 1                                # allocation fixed


def test_threshold_policy_scales_out_uniform_memory():
    s = _q1_scaler("threshold")
    hist = s.run(max_windows=6)
    assert s.steps >= 1
    p0 = dict(hist[0].config)["currency_map"][0]
    p1 = dict(hist[-1].config)["currency_map"][0]
    assert p1 > p0                                       # reactive scale-out
    # memory stays the uniform per-slot package: never a raised level
    for h in hist:
        for op, (p, lvl) in h.config.items():
            assert lvl in (None, 0), (op, lvl)


def test_summary_on_empty_history_is_zero_window():
    s = _q1_scaler("justin")
    out = s.summary()                                    # nothing ran yet
    assert out["windows"] == 0 and out["steps"] == 0
    assert out["achieved_rate"] == 0.0
    assert out["cpu_cores"] > 0 and out["memory_mb"] > 0  # initial placement
    assert out["policy"] == "justin"
    assert out["config"] == s.flow.config()


# ------------------------------------------------------------- grid smoke
def test_grid_smoke_includes_new_policies():
    grid = run_grid(["q1"], ["constant"], ("static", "threshold"),
                    windows=3, max_level=0, verbose=False)
    assert len(grid["cells"]) == 2
    by_pol = {c["policy"]: c for c in grid["cells"]}
    assert by_pol["static"]["steps"] == 0
    assert by_pol["threshold"]["steps"] >= 1
    # static is the floor: the elastic policy never violates MORE
    assert by_pol["threshold"]["slo"]["violations"] \
        <= by_pol["static"]["slo"]["violations"]


# ------------------------------------- co-location: q8/q11 pressured pair
def test_colocated_pressured_pair_threshold_vs_justin():
    """The memory-pressured q8/q11 pair on one shared cluster, once under
    justin and once under threshold.  The budget is sized to justin's
    hybrid footprint: justin's proposals are all admitted, while the
    threshold scaler's doubling ratchet keeps requesting packages the
    budget cannot hold (denied, retried every following window)."""
    cfg = ControllerConfig(decision_window_s=60.0, stabilization_s=30.0,
                           justin=JustinParams(max_level=2))
    out = {}
    for pol in ("justin", "threshold"):
        cluster = Cluster(cpu_slots=24, memory_mb=17000.0)
        out[pol] = run_colocated(
            [ColocatedSpec(pol, "q8", name="A8"),
             ColocatedSpec(pol, "q11", name="B11")],
            cluster, windows=5, cfg=cfg)
        for cpu, mem in out[pol].usage:                  # never overdrawn
            assert cpu <= cluster.cpu_slots
            assert mem <= cluster.memory_mb + 1e-9
    j, t = out["justin"], out["threshold"]
    # justin's hybrid footprint fits the budget end to end
    assert j.tenant("A8").denials == [] and j.tenant("B11").denials == []
    assert j.tenant("A8").slo().recovered
    assert j.tenant("B11").slo().recovered
    # threshold's uniform doubling hits the ceiling and is re-denied at
    # consecutive window boundaries
    t_denials = t.tenant("A8").denials + t.tenant("B11").denials
    assert len(t_denials) >= 2
