"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions, decode-vs-prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.tree_util import DictKey, tree_map_with_path

from repro.configs import get_config, list_archs
from repro.models import cpu_mesh_ctx, get_model
from repro.models.transformer import VIT_STUB_DIM
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

pytestmark = pytest.mark.slow  # heavy jax compiles; run with -m slow

ARCHS = list_archs()


def make_batch(cfg, b=2, s=64, key=1):
    batch = {"tokens": jax.random.randint(jax.random.key(key), (b, s), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, :s - cfg.img_tokens]
        batch["img_emb"] = jax.random.normal(
            jax.random.key(2), (b, cfg.img_tokens, VIT_STUB_DIM))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (b, cfg.enc_seq, VIT_STUB_DIM))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_loss(arch):
    cfg = get_config(arch).reduced()
    mctx = cpu_mesh_ctx()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.key(0))
    loss = model.loss(params, make_batch(cfg), cfg, mctx)
    assert loss.shape == ()
    assert jnp.isfinite(loss)
    assert 4.0 < float(loss) < 7.0              # ~ln(vocab) at init


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One optimizer step decreases nothing NaN and keeps shapes."""
    cfg = get_config(arch).reduced()
    mctx = cpu_mesh_ctx()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.key(0))
    opt = init_opt_state(params, AdamWConfig())
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, cfg, mctx))(params)
    new_params, new_opt, metrics = adamw_update(params, grads, opt,
                                                AdamWConfig())
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.shape == b.shape
        assert jnp.all(jnp.isfinite(b))
    assert jnp.isfinite(metrics["grad_norm"])
    assert float(metrics["grad_norm"]) > 0


def _pad_kv(caches):
    def f(path, x):
        keys = [p.key for p in path if isinstance(p, DictKey)]
        if keys and keys[-1] in ("k", "v"):
            pad = [(0, 0)] * x.ndim
            pad[-2] = (0, 1)
            return jnp.pad(x, pad)
        return x
    return tree_map_with_path(f, caches)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    """serve_step(token t) == prefill(tokens[:t+1]) last logits."""
    cfg = get_config(arch).reduced()
    mctx = cpu_mesh_ctx()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.key(0))
    b, s = 2, 48
    toks = jax.random.randint(jax.random.key(1), (b, s + 1), 0,
                              cfg.vocab_size)

    def mk(t):
        batch = {"tokens": t}
        if cfg.family == "vlm":
            batch["img_emb"] = jax.random.normal(
                jax.random.key(2), (b, cfg.img_tokens, VIT_STUB_DIM))
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.key(2), (b, cfg.enc_seq, VIT_STUB_DIM))
        return batch

    _, caches = model.prefill(params, mk(toks[:, :s]), cfg, mctx)
    ref, _ = model.prefill(params, mk(toks[:, :s + 1]), cfg, mctx)
    got, _ = model.decode(params, _pad_kv(caches), toks[:, s:s + 1],
                          jnp.int32(s), cfg, mctx)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(got - ref))) / scale < 0.03


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-130m",
                                  "recurrentgemma-2b"])
def test_unrolled_matches_scan(arch):
    """scan_layers=False (roofline path) computes the same function."""
    cfg = get_config(arch).reduced()
    mctx = cpu_mesh_ctx()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    l1 = model.loss(params, batch, cfg, mctx)
    cfg2 = cfg.replace(scan_layers=False)
    l2 = get_model(cfg2).loss(params, batch, cfg2, mctx)
    assert abs(float(l1) - float(l2)) < 2e-2


def test_swa_limits_attention_window():
    """A token beyond the window must not influence logits (SWA arch)."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    assert cfg.window == 32
    mctx = cpu_mesh_ctx()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 64), 0, cfg.vocab_size)
    logits1, _ = model.prefill(params, {"tokens": toks}, cfg, mctx)
    toks2 = toks.at[:, 0].set((toks[:, 0] + 7) % cfg.vocab_size)
    logits2, _ = model.prefill(params, {"tokens": toks2}, cfg, mctx)
    # position 0 is 63 tokens away from the last one: outside window=32
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2),
                               atol=1e-4)


def test_full_configs_match_assignment():
    """The full (not reduced) configs carry the assigned dimensions."""
    spec = {
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
    }
    for arch, (nl, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
                cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size) == \
            (nl, d, h, kv, ff, v), arch


def test_moe_param_count_llama4():
    """llama4 config lands near 400B total / ~17B active."""
    import numpy as np
    from repro.models.model import abstract_params
    cfg = get_config("llama4-maverick-400b-a17b")
    shapes = abstract_params(cfg)
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    assert 3.5e11 < total < 4.6e11, f"total params {total:.3e}"
