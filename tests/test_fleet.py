"""Fleet-scale driver: population sampling + vectorized/scalar identity.

The vectorized fleet driver is only trustworthy if it is *decision-
identical* to the scalar oracle — same denials, deferrals, preemptions,
first-pending ages, usage curves and histories — on fleets it did not see
during development.  The property sweep here samples random populations
(heavy-tailed rates, mixed policies/queries, flash crowds, faults, under-
provisioned stateful tenants) and runs every admission mode with and
without a migration budget under both drivers.
"""
import math

import pytest
from test_cluster import assert_drivers_identical

from repro.core.placement import default_tm_spec
from repro.scenarios import (Cluster, ColocatedSpec, PopulationSpec,
                             fleet_cfg, fleet_stats, run_colocated,
                             run_fleet, sample_population, size_cluster)
from repro.scenarios.faults import FaultSchedule
from repro.scenarios.profiles import Diurnal, Profile, Ramp, Spike
from repro.scenarios.runner import scenario_horizon_s


# ------------------------------------------------------------ population
def test_population_is_deterministic():
    cfg = fleet_cfg()
    horizon = scenario_horizon_s(cfg, 10)
    a = sample_population(PopulationSpec(tenants=40, seed=7), horizon)
    b = sample_population(PopulationSpec(tenants=40, seed=7), horizon)
    assert a == b
    c = sample_population(PopulationSpec(tenants=40, seed=8), horizon)
    assert a != c


def test_population_shape():
    spec = PopulationSpec(tenants=200, seed=1)
    cfg = fleet_cfg()
    pop = sample_population(spec, scenario_horizon_s(cfg, 20))
    assert len(pop) == 200
    names = [s.name for s in pop]
    assert len(set(names)) == 200
    # every configured query/policy actually occurs at these counts
    assert {s.query for s in pop} == {q for q, _ in spec.query_mix}
    assert {s.policy for s in pop} == {p for p, _ in spec.policy_mix}
    # heavy tail: rates spread well over an order of magnitude, capped
    rates = [s.target for s in pop]
    assert max(rates) <= spec.rate_cap
    assert max(rates) / min(rates) > 10
    # the profile mix includes flash-crowd spikes AND staggered diurnals
    spikes = [s.profile for s in pop if isinstance(s.profile, Spike)]
    diurnals = [s.profile for s in pop if isinstance(s.profile, Diurnal)]
    assert spikes and diurnals
    assert any(isinstance(s.profile, Ramp) for s in pop)
    # staggered: diurnal phases are NOT aligned
    assert len({d.phase_s for d in diurnals}) > 1
    # flash crowd is correlated: spike onsets cluster around mid-horizon
    horizon = scenario_horizon_s(cfg, 20)
    for sp in spikes:
        assert abs(sp.t0 - spec.flash_at_frac * horizon) \
            <= spec.flash_spread_frac * horizon + 1e-9
    # faults are plain lists (re-runnable), never pre-built schedules
    faulted = [s for s in pop if s.faults is not None]
    assert faulted
    assert all(isinstance(s.faults, list) for s in faulted)
    # under-provisioned stateful tenants exist: they scale through
    # admission, which is where the fleet's arbitration traffic comes from
    assert any(s.config and any(v == (1, 0) for v in s.config.values())
               for s in pop)


def test_size_cluster_holds_initial_placements():
    cfg = fleet_cfg()
    pop = sample_population(PopulationSpec(tenants=24, seed=3),
                            scenario_horizon_s(cfg, 4))
    cluster = size_cluster(pop, cfg)
    # windows=0 runs setup (initial reservations) only: must not raise
    res = run_colocated(pop, cluster, windows=0, cfg=cfg)
    assert len(res.tenants) == 24
    assert res.cluster.cpu_in_use <= cluster.cpu_slots
    assert res.cluster.mem_in_use <= cluster.memory_mb + 1e-9


# ----------------------------------------- property sweep: driver identity
def _fleet_case(seed, admission, budget, tm_spec=None, tenants=12,
                windows=4):
    cfg = fleet_cfg()
    pop = sample_population(PopulationSpec(tenants=tenants, seed=seed),
                            scenario_horizon_s(cfg, windows))
    runs = {}
    for driver in ("vectorized", "scalar"):
        cluster = size_cluster(pop, cfg, tm_spec=tm_spec)
        runs[driver] = run_colocated(pop, cluster, windows=windows,
                                     cfg=cfg, admission=admission,
                                     driver=driver,
                                     migration_budget_mb=budget)
    return runs


@pytest.mark.parametrize("admission", ["priority", "fair_share",
                                       "first_come", "preemption"])
@pytest.mark.parametrize("budget", [None, 1500.0])
def test_drivers_identical_on_random_fleets(admission, budget):
    """Satellite pin: for random populations, every admission mode, with
    and without a migration budget, the vectorized driver and the scalar
    oracle make byte-identical decisions."""
    for seed in (11, 23):
        runs = _fleet_case(seed, admission, budget)
        assert_drivers_identical(runs["vectorized"], runs["scalar"])


def test_drivers_identical_on_shared_tm_fleet():
    """Shared-TaskManager clusters exercise the attribution/repack paths
    (nonzero give-back quotes, amortized_mb rows) — identity must hold
    there too."""
    runs = _fleet_case(31, "preemption", 2000.0,
                       tm_spec=default_tm_spec(158.0))
    assert_drivers_identical(runs["vectorized"], runs["scalar"])


# ------------------------------------------------------------ fleet smoke
def test_run_fleet_smoke():
    res = run_fleet(32, 6, admission="fair_share", seed=0)
    assert len(res.tenants) == 32
    assert len(res.usage) == 6
    for cpu, mem in res.usage:
        assert cpu <= res.cluster.cpu_slots
        assert mem <= res.cluster.memory_mb + 1e-9
    st = fleet_stats(res, 1.0)
    assert st["tenants"] == 32 and st["windows"] == 6
    assert st["tenant_windows"] == 192
    assert st["tenant_windows_per_s"] == pytest.approx(192.0)
    assert {"denied_tenant_windows", "deferred_tenant_windows",
            "preempted_tenant_windows", "policy_steps", "peak_cpu",
            "peak_mem_mb", "cluster_cpu_slots",
            "cluster_memory_mb"} <= set(st)
    # the vectorized result keeps its SoA arrays for fleet_stats
    assert res.fleet is not None
    assert res.fleet.denied.shape == (6, 32)


def test_fleet_contends_at_default_sizing():
    """The point of ``size_cluster``'s bounded headroom: a default-sized
    fleet must actually exercise admission (growth > headroom), else the
    bench measures an uncontended cluster."""
    res = run_fleet(128, 20, admission="preemption", seed=0)
    st = fleet_stats(res)
    assert st["denied_tenant_windows"] > 0
    assert st["preempted_tenant_windows"] > 0


# ------------------------------------------------- trace-summary identity
def test_drivers_emit_identical_trace_summaries():
    """Observability satellite: with a live tracer attached, the
    vectorized driver's per-tenant span aggregates (count and sim-time
    per tenant|cat|name) are identical to the scalar oracle's — tracing
    covers the fast path with the same fidelity as the slow one."""
    from repro.obs import Tracer
    cfg = fleet_cfg()
    pop = sample_population(PopulationSpec(tenants=12, seed=23),
                            scenario_horizon_s(cfg, 4))
    summaries = {}
    for driver in ("vectorized", "scalar"):
        cluster = size_cluster(pop, cfg)
        tr = Tracer(enabled=True)
        run_colocated(pop, cluster, windows=4, cfg=cfg,
                      admission="preemption", migration_budget_mb=1500.0,
                      driver=driver, tracer=tr)
        assert tr.spans, driver
        summaries[driver] = tr.summary()
    assert summaries["vectorized"] == summaries["scalar"]
    # the fleet trace actually covers every tenant and the control phases
    tenants = {k.split("|")[0] for k in summaries["scalar"]}
    assert len(tenants) == 12
    cats = {k.split("|")[1] for k in summaries["scalar"]}
    assert {"engine", "policy", "lsm"} <= cats
