"""Cluster budget model + co-located admission arbitration.

Unit tests drive :class:`Cluster` directly (budget never overdrawn, atomic
deny, release frees capacity, arbitration orders); the end-to-end tests run
two real episodes on one shared cluster and pin the PR's headline: a
neighbor's scale-up that DS2's packaged allocation blocks is admitted when
the first tenant runs Justin instead — because Justin's stateless operators
hold no managed memory and its give-backs free shared capacity.
"""
import pytest

from repro.core.controller import ControllerConfig
from repro.core.justin import JustinParams
from repro.scenarios import Cluster, ColocatedSpec, run_colocated
from repro.scenarios.cluster import _arbitration_order


# ----------------------------------------------------------------- unit
def test_reserve_within_budget():
    c = Cluster(cpu_slots=8, memory_mb=1000.0)
    assert c.reserve("a", 4, 600.0)
    assert c.reserve("b", 4, 400.0)
    assert c.available() == (0, 0.0)


def test_deny_leaves_state_untouched():
    c = Cluster(cpu_slots=8, memory_mb=1000.0)
    assert c.reserve("a", 4, 600.0)
    assert not c.reserve("b", 4, 500.0)       # memory would overdraw
    assert not c.reserve("b", 5, 100.0)       # cpu would overdraw
    assert c.used_cpu == {"a": 4} and c.used_mem == {"a": 600.0}
    assert c.available() == (4, 400.0)


def test_reserve_replaces_own_footprint_not_adds():
    c = Cluster(cpu_slots=8, memory_mb=1000.0)
    assert c.reserve("a", 6, 900.0)
    # growing within own replacement headroom is fine even though the
    # naive sum (6+8, 900+1000) would not be
    assert c.reserve("a", 8, 1000.0)
    # shrink releases capacity for the neighbor
    assert c.reserve("a", 2, 200.0)
    assert c.reserve("b", 6, 800.0)


def test_release():
    c = Cluster(cpu_slots=4, memory_mb=100.0)
    assert c.reserve("a", 4, 100.0)
    assert not c.reserve("b", 1, 10.0)
    c.release("a")
    assert c.reserve("b", 1, 10.0)
    assert c.available() == (3, 90.0)


class _T:
    def __init__(self, name, first_pending=None):
        self.name = name
        self.first_pending = first_pending


def test_arbitration_orders():
    c = Cluster(cpu_slots=10, memory_mb=1000.0)
    c.reserve("big", 8, 200.0)
    c.reserve("small", 1, 100.0)
    ts = [_T("big"), _T("small", first_pending=2), _T("new")]
    assert [t.name for t in _arbitration_order(ts, c, "priority")] \
        == ["big", "small", "new"]
    # fair share: ascending budget share (new=0, small=.1, big=.8)
    assert [t.name for t in _arbitration_order(ts, c, "fair_share")] \
        == ["new", "small", "big"]
    # first come: oldest unserved request first, others keep spec order
    ts2 = [_T("big", first_pending=3), _T("small", first_pending=1),
           _T("new")]
    assert [t.name for t in _arbitration_order(ts2, c, "first_come")] \
        == ["small", "big", "new"]
    with pytest.raises(ValueError):
        _arbitration_order(ts, c, "lottery")


def test_initial_placement_must_fit():
    with pytest.raises(ValueError):
        run_colocated([("ds2", "q1")], Cluster(cpu_slots=1, memory_mb=10.0),
                      windows=1)


# ----------------------------------------------------------- end-to-end
def quick_cfg():
    return ControllerConfig(decision_window_s=60.0, stabilization_s=30.0,
                            justin=JustinParams(max_level=2))


def run_pair(first_policy: str, windows: int = 5):
    """Two q1 tenants on a cluster sized so both fit only if the first
    tenant scales the Justin way (no managed grant on stateless tasks):
    ds2 needs 4096 MB at its final config, justin 2832 MB, budget 7000."""
    cluster = Cluster(cpu_slots=16, memory_mb=7000.0)
    res = run_colocated(
        [ColocatedSpec(first_policy, "q1", name="A"),
         ColocatedSpec("ds2", "q1", name="B")],
        cluster, windows=windows, cfg=quick_cfg())
    return res


def test_budget_never_exceeded_and_denials_retry():
    res = run_pair("ds2")
    # invariant: every window's totals stay within the budget
    for cpu, mem in res.usage:
        assert cpu <= res.cluster.cpu_slots
        assert mem <= res.cluster.memory_mb + 1e-9
    b = res.tenant("B")
    # B's scale-up was denied and re-requested at every following window
    assert len(b.denials) >= 2
    assert b.denials == sorted(b.denials)
    assert b.denials == list(range(b.denials[0],
                                   b.denials[0] + len(b.denials)))
    # a denied window is marked on the history row and B never converges
    assert any(h.denied for h in b.history)
    assert not b.slo().recovered


def test_justin_frees_capacity_ds2_blocks():
    """The PR's acceptance headline: the same neighbor B (always ds2) is
    blocked when A runs ds2, admitted — and back above its SLO — when A
    runs justin on the identical cluster budget."""
    blocked = run_pair("ds2")
    freed = run_pair("justin")
    b_blocked = blocked.tenant("B")
    b_freed = freed.tenant("B")
    assert len(b_blocked.denials) >= 1
    assert b_freed.denials == []
    assert not b_blocked.slo().recovered
    assert b_freed.slo().recovered
    # justin's A meets its own target with strictly less memory held
    a_ds2, a_justin = blocked.tenant("A"), freed.tenant("A")
    assert a_justin.slo().recovered and a_ds2.slo().recovered
    assert a_justin.history[-1].memory_mb < a_ds2.history[-1].memory_mb


def test_colocated_summary_shape():
    res = run_pair("justin", windows=3)
    s = res.summary()
    assert set(s["tenants"]) == {"A", "B"}
    assert s["cluster"] == {"cpu_slots": 16, "memory_mb": 7000.0}
    assert s["peak_cpu"] <= 16 and s["peak_mem"] <= 7000.0
    for t in s["tenants"].values():
        assert {"policy", "query", "steps", "denied_windows",
                "slo"} <= set(t)
