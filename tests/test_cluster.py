"""Cluster budget model + co-located admission arbitration.

Unit tests drive :class:`Cluster` directly (budget never overdrawn, atomic
deny, release frees capacity, arbitration orders); the end-to-end tests run
two real episodes on one shared cluster and pin the PR's headline: a
neighbor's scale-up that DS2's packaged allocation blocks is admitted when
the first tenant runs Justin instead — because Justin's stateless operators
hold no managed memory and its give-backs free shared capacity.
"""
import pytest

from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.core.placement import TMSpec, default_tm_spec
from repro.scenarios import Cluster, ColocatedSpec, run_colocated
from repro.scenarios.cluster import _arbitration_order


# ----------------------------------------------------------------- unit
def test_reserve_within_budget():
    c = Cluster(cpu_slots=8, memory_mb=1000.0)
    assert c.reserve("a", 4, 600.0)
    assert c.reserve("b", 4, 400.0)
    assert c.available() == (0, 0.0)


def test_deny_leaves_state_untouched():
    c = Cluster(cpu_slots=8, memory_mb=1000.0)
    assert c.reserve("a", 4, 600.0)
    assert not c.reserve("b", 4, 500.0)       # memory would overdraw
    assert not c.reserve("b", 5, 100.0)       # cpu would overdraw
    assert c.used_cpu == {"a": 4} and c.used_mem == {"a": 600.0}
    assert c.available() == (4, 400.0)


def test_reserve_replaces_own_footprint_not_adds():
    c = Cluster(cpu_slots=8, memory_mb=1000.0)
    assert c.reserve("a", 6, 900.0)
    # growing within own replacement headroom is fine even though the
    # naive sum (6+8, 900+1000) would not be
    assert c.reserve("a", 8, 1000.0)
    # shrink releases capacity for the neighbor
    assert c.reserve("a", 2, 200.0)
    assert c.reserve("b", 6, 800.0)


def test_release():
    c = Cluster(cpu_slots=4, memory_mb=100.0)
    assert c.reserve("a", 4, 100.0)
    assert not c.reserve("b", 1, 10.0)
    c.release("a")
    assert c.reserve("b", 1, 10.0)
    assert c.available() == (3, 90.0)


class _T:
    def __init__(self, name, first_pending=None):
        self.name = name
        self.first_pending = first_pending


def test_arbitration_orders():
    c = Cluster(cpu_slots=10, memory_mb=1000.0)
    c.reserve("big", 8, 200.0)
    c.reserve("small", 1, 100.0)
    ts = [_T("big"), _T("small", first_pending=2), _T("new")]
    assert [t.name for t in _arbitration_order(ts, c, "priority")] \
        == ["big", "small", "new"]
    # fair share: ascending budget share (new=0, small=.1, big=.8)
    assert [t.name for t in _arbitration_order(ts, c, "fair_share")] \
        == ["new", "small", "big"]
    # first come: oldest unserved request first, others keep spec order
    ts2 = [_T("big", first_pending=3), _T("small", first_pending=1),
           _T("new")]
    assert [t.name for t in _arbitration_order(ts2, c, "first_come")] \
        == ["small", "big", "new"]
    with pytest.raises(ValueError):
        _arbitration_order(ts, c, "lottery")


def test_initial_placement_must_fit():
    with pytest.raises(ValueError):
        run_colocated([("ds2", "q1")], Cluster(cpu_slots=1, memory_mb=10.0),
                      windows=1)


# ----------------------------------------------------------- end-to-end
def quick_cfg():
    return ControllerConfig(decision_window_s=60.0, stabilization_s=30.0,
                            justin=JustinParams(max_level=2))


def run_pair(first_policy: str, windows: int = 5,
             driver: str = "vectorized"):
    """Two q1 tenants on a cluster sized so both fit only if the first
    tenant scales the Justin way (no managed grant on stateless tasks):
    ds2 needs 4096 MB at its final config, justin 2832 MB, budget 7000."""
    cluster = Cluster(cpu_slots=16, memory_mb=7000.0)
    res = run_colocated(
        [ColocatedSpec(first_policy, "q1", name="A"),
         ColocatedSpec("ds2", "q1", name="B")],
        cluster, windows=windows, cfg=quick_cfg(), driver=driver)
    return res


def test_budget_never_exceeded_and_denials_retry():
    res = run_pair("ds2")
    # invariant: every window's totals stay within the budget
    for cpu, mem in res.usage:
        assert cpu <= res.cluster.cpu_slots
        assert mem <= res.cluster.memory_mb + 1e-9
    b = res.tenant("B")
    # B's scale-up was denied and re-requested at every following window
    assert len(b.denials) >= 2
    assert b.denials == sorted(b.denials)
    assert b.denials == list(range(b.denials[0],
                                   b.denials[0] + len(b.denials)))
    # a denied window is marked on the history row and B never converges
    assert any(h.denied for h in b.history)
    assert not b.slo().recovered


def test_justin_frees_capacity_ds2_blocks():
    """The PR's acceptance headline: the same neighbor B (always ds2) is
    blocked when A runs ds2, admitted — and back above its SLO — when A
    runs justin on the identical cluster budget."""
    blocked = run_pair("ds2")
    freed = run_pair("justin")
    b_blocked = blocked.tenant("B")
    b_freed = freed.tenant("B")
    assert len(b_blocked.denials) >= 1
    assert b_freed.denials == []
    assert not b_blocked.slo().recovered
    assert b_freed.slo().recovered
    # justin's A meets its own target with strictly less memory held
    a_ds2, a_justin = blocked.tenant("A"), freed.tenant("A")
    assert a_justin.slo().recovered and a_ds2.slo().recovered
    assert a_justin.history[-1].memory_mb < a_ds2.history[-1].memory_mb


def test_colocated_summary_shape():
    res = run_pair("justin", windows=3)
    s = res.summary()
    assert set(s["tenants"]) == {"A", "B"}
    assert s["cluster"] == {"cpu_slots": 16, "memory_mb": 7000.0,
                            "shared_tm": False}
    assert s["peak_cpu"] <= 16 and s["peak_mem"] <= 7000.0
    for t in s["tenants"].values():
        assert {"policy", "query", "steps", "denied_windows",
                "preempted_windows", "slo"} <= set(t)


# ------------------------------------------------- regression: bugfixes
def test_unique_names_never_compound_suffixes():
    """Three identical (policy, query) specs get deterministic unique
    names; repeated collisions must not compound (a#2#2...)."""
    res = run_colocated([("ds2", "q1")] * 3,
                        Cluster(cpu_slots=24, memory_mb=20000.0),
                        windows=0, cfg=quick_cfg())
    assert [t.name for t in res.tenants] \
        == ["ds2:q1", "ds2:q1#2", "ds2:q1#3"]
    # explicit names that collide with an already-suffixed name
    res = run_colocated(
        [ColocatedSpec("ds2", "q1", name="x"),
         ColocatedSpec("ds2", "q1", name="x#2"),
         ColocatedSpec("ds2", "q1", name="x")],
        Cluster(cpu_slots=24, memory_mb=20000.0), windows=0,
        cfg=quick_cfg())
    assert [t.name for t in res.tenants] == ["x", "x#2", "x#3"]


def test_resync_desync_fails_loudly(monkeypatch):
    """A post-step footprint that no longer fits the budget means the
    quoted admission and the enacted placement disagree — the driver must
    raise, not silently desync per-tenant accounting from reality."""
    orig = AutoScaler.step_window

    def sabotaged(self, w=0, **kw):
        out = orig(self, w, **kw)
        if self.tenant == "B":
            # grow the enacted footprint behind the arbiter's back
            self.engine.reconfigure({"currency_map": (12, 0)})
        return out

    monkeypatch.setattr(AutoScaler, "step_window", sabotaged)
    with pytest.raises(RuntimeError, match="accounting desync"):
        run_colocated([ColocatedSpec("ds2", "q1", name="A"),
                       ColocatedSpec("ds2", "q1", name="B")],
                      Cluster(cpu_slots=8, memory_mb=4000.0),
                      windows=1, cfg=quick_cfg())


def test_run_max_windows_zero_runs_zero_windows():
    """``max_windows=0`` must mean zero windows, not the falsy-default
    budget of ``max_reconfigs + 4``."""
    from repro.data.nexmark import QUERIES, TARGET_RATES
    from repro.streaming.engine import StreamEngine
    scaler = AutoScaler(StreamEngine(QUERIES["q1"](), seed=3),
                        TARGET_RATES["q1"], quick_cfg())
    assert scaler.run(max_windows=0) == []
    assert scaler.history == [] and scaler.engine.now == 0.0


# ------------------------------------------------- shared-TM + preemption
def test_shared_cluster_reserve_tasks_and_release():
    from repro.core.placement import TaskRequest
    spec = TMSpec(slots=4, managed_pool_mb=640.0, base_mb=1000.0)
    c = Cluster(cpu_slots=8, memory_mb=3000.0, tm_spec=spec)
    with pytest.raises(TypeError):
        c.reserve("a", 1, 100.0)      # scalar reserve is the wrong API
    a = [TaskRequest("op", i, 158.0) for i in range(2)]
    b = [TaskRequest("op", i, 158.0) for i in range(2)]
    assert c.reserve_tasks("a", a) and c.reserve_tasks("b", b)
    # both tenants co-reside on one TM: each pays half its base_mb
    assert c.placement().n_tms == 1
    assert c.used_mem["a"] == pytest.approx(2 * 158.0 + 500.0)
    assert c.mem_in_use == pytest.approx(c.placement().memory_mb)
    # denial leaves accounting untouched
    big = [TaskRequest("op", i, 158.0) for i in range(9)]   # > 8 slots
    before = (dict(c.used_cpu), dict(c.used_mem))
    assert not c.reserve_tasks("b", big)
    assert (c.used_cpu, c.used_mem) == before
    c.release("b")
    assert "b" not in c.used_mem
    assert c.used_mem["a"] == pytest.approx(2 * 158.0 + 1000.0)


def test_shared_tm_strictly_cheaper_than_private_fleets():
    """Three small tenants packed on one shared fleet pay two TMs' base
    memory instead of three — total strictly below the sum of the
    equivalent private per-tenant placements (the cap on CPU slots keeps
    every tenant at its initial 2-slot placement)."""
    cluster = Cluster(cpu_slots=6, memory_mb=20000.0,
                      tm_spec=default_tm_spec())
    res = run_colocated([("ds2", "q1")] * 3, cluster, windows=2,
                        cfg=quick_cfg())
    shared_total = cluster.placement().memory_mb
    private_sum = sum(t.scaler.resources()[1] for t in res.tenants)
    assert shared_total < private_sum
    # attribution sums exactly to the fleet totals and is what the
    # history rows carry
    assert cluster.mem_in_use == pytest.approx(shared_total)
    for t in res.tenants:
        assert t.history[-1].amortized_mb \
            == pytest.approx(cluster.used_mem[t.name])
        assert t.history[-1].amortized_mb <= t.scaler.resources()[1]
    # the two co-resident tenants pay strictly less than a private fleet
    # (the third happens to sit alone on its TM and pays in full)
    assert sum(t.history[-1].amortized_mb < t.scaler.resources()[1]
               for t in res.tenants) >= 2


def preemption_pair(admission: str, windows: int = 5,
                    driver: str = "vectorized", tm_spec=None,
                    migration_budget_mb=None):
    """The pinned §4.3 scenario: a static low-priority tenant pinned at
    storage level 2 holds the memory a high-priority DS2 tenant needs."""
    specs = [ColocatedSpec("ds2", "q1", name="H"),
             ColocatedSpec("static", "q11", name="V", target=5_000,
                           config={"user_sessions": (6, 2)})]
    return run_colocated(specs, Cluster(cpu_slots=16, memory_mb=8500.0,
                                        tm_spec=tm_spec),
                         windows=windows, cfg=quick_cfg(),
                         admission=admission, driver=driver,
                         migration_budget_mb=migration_budget_mb)


def test_fair_share_preemption_reclaims_over_allotment_hog():
    """Satellite pin: preemption victims are selected by resource share
    above the fair allotment (1/N of the budget), not by strict priority
    order.  Here the memory hog is the HIGHEST-priority tenant and the
    requester the lowest — under the old lower-priority-only victim walk
    the requester had nobody to reclaim from and starved forever; under
    fair-share selection the over-allotment hog gives back its levels and
    the requester recovers.  Priority stays a tiebreak between equally
    over-share victims."""
    specs = [ColocatedSpec("static", "q11", name="HOG", target=5_000,
                           config={"user_sessions": (6, 2)}),
             ColocatedSpec("ds2", "q1", name="REQ")]

    starved = run_colocated(specs, Cluster(cpu_slots=16, memory_mb=8500.0),
                            windows=5, cfg=quick_cfg(),
                            admission="priority")
    req = starved.tenant("REQ")
    assert req.denials == list(range(len(req.history)))
    assert not req.slo().recovered

    freed = run_colocated(specs, Cluster(cpu_slots=16, memory_mb=8500.0),
                          windows=5, cfg=quick_cfg(),
                          admission="preemption")
    req2, hog2 = freed.tenant("REQ"), freed.tenant("HOG")
    # the hog sat above its fair allotment (6624 of 8500 MB > 1/2), so
    # the LOWER-priority requester could reclaim it
    assert hog2.preemptions
    assert hog2.scaler.flow.nodes["user_sessions"].memory_level < 2
    assert req2.slo().recovered
    assert req2.history[-1].cpu_cores > req.history[-1].cpu_cores


def test_preemption_admits_what_priority_starves():
    """Acceptance headline: on the same budget, ``priority`` leaves the
    high-priority tenant denied every window; ``preemption`` forces the
    neighbor's storage level down (2 -> 1 -> 0) and the tenant recovers."""
    starved = preemption_pair("priority")
    h = starved.tenant("H")
    assert h.denials == list(range(len(h.history)))   # every window
    assert not h.slo().recovered
    assert starved.tenant("V").preemptions == []

    freed = preemption_pair("preemption")
    h2, v2 = freed.tenant("H"), freed.tenant("V")
    assert h2.denials == []
    assert h2.slo().recovered
    assert v2.preemptions == [0, 1]                   # two give-backs
    assert v2.scaler.flow.nodes["user_sessions"].memory_level == 0
    assert v2.scaler.preemptions == 2
    assert v2.slo().preempted_windows == 2
    assert [x.preempted for x in v2.history[:2]] == [True, True]
    # the admitted tenant actually got the capacity it was starved of
    assert h2.history[-1].cpu_cores > h.history[-1].cpu_cores
    assert freed.summary()["tenants"]["V"]["preempted_windows"] == [0, 1]


# ------------------------------------------------- vectorized fleet driver
def assert_drivers_identical(res_v, res_s):
    """Every observable decision must match between the vectorized driver
    and the scalar oracle: per-window usage, per-tenant admission
    outcomes, and the full history series."""
    assert [t.name for t in res_v.tenants] == [t.name for t in res_s.tenants]
    assert res_v.usage == res_s.usage
    for tv, ts in zip(res_v.tenants, res_s.tenants):
        assert tv.denials == ts.denials, tv.name
        assert tv.deferrals == ts.deferrals, tv.name
        assert tv.preemptions == ts.preemptions, tv.name
        assert tv.first_pending == ts.first_pending, tv.name
        assert tv.faults_fired == ts.faults_fired, tv.name
        assert tv.scaler.preemptions == ts.scaler.preemptions, tv.name
        assert len(tv.history) == len(ts.history), tv.name
        for hv, hs in zip(tv.history, ts.history):
            assert (hv.cpu_cores, hv.memory_mb, hv.denied,
                    getattr(hv, "preempted", False),
                    getattr(hv, "amortized_mb", None)) \
                == (hs.cpu_cores, hs.memory_mb, hs.denied,
                    getattr(hs, "preempted", False),
                    getattr(hs, "amortized_mb", None)), tv.name


def test_scalar_oracle_matches_vectorized_on_pr2_headline():
    """The PR 2 acceptance headline must be decision-identical under both
    drivers — same denials, same usage curve, same histories."""
    for policy in ("justin", "ds2"):
        assert_drivers_identical(run_pair(policy, driver="vectorized"),
                                 run_pair(policy, driver="scalar"))


def test_scalar_oracle_matches_vectorized_on_pr4_headline():
    """The PR 4 preemption headline, both drivers, all admission modes."""
    for admission in ("priority", "fair_share", "first_come", "preemption"):
        assert_drivers_identical(
            preemption_pair(admission, driver="vectorized"),
            preemption_pair(admission, driver="scalar"))


def test_run_colocated_rejects_unknown_driver():
    with pytest.raises(ValueError, match="unknown driver"):
        run_pair("justin", driver="simd")


# ------------------------------------------------ satellite: fits epsilon
def test_fits_epsilon_tolerates_attribution_drift():
    """Satellite pin: ``fits`` must use the same 1e-9 tolerance as the
    budget invariant.  Accumulated float additions drift the in-use total
    a few ULPs above the nominal budget (0.1 * 3 > 0.3); the old strict
    ``<=`` then denied a tenant RE-RESERVING its own unchanged footprint
    — a phantom denial no real capacity shortage caused."""
    c = Cluster(cpu_slots=3, memory_mb=0.3)
    for name in ("a", "b", "c"):
        assert c.reserve(name, 1, 0.1)
    # the drifted total sits above the budget by ~5e-17
    assert c.mem_in_use > c.memory_mb
    # re-reserving an identical footprint frees 0.1 and re-adds 0.1: any
    # real shortage is impossible, only drift can deny it
    assert c.fits("a", 1, 0.1)
    assert c.reserve("a", 1, 0.1)
    assert c.fits("b", 1, 0.1)


# ----------------------------------- satellite: give-backs cost migration
def test_preemption_giveback_charged_to_migration_budget():
    """Satellite pin: a forced give-back moves the victim's state, so it
    must draw from ``migration_budget_mb`` like any other reconfiguration.
    On a shared-TM cluster the victim's level-2 -> level-1 give-back quotes
    1580 MB; under an 800 MB window budget the old code enacted it for
    free and admitted the requester — now the give-back is skipped and the
    requester's scale-up is deferred, not force-funded."""
    res = preemption_pair("preemption", windows=3,
                          tm_spec=default_tm_spec(158.0),
                          migration_budget_mb=800.0)
    h, v = res.tenant("H"), res.tenant("V")
    # window 0's give-back fits the budget; window 1+'s does not
    assert v.preemptions == [0]
    assert v.scaler.flow.nodes["user_sessions"].memory_level == 1
    # the requester's follow-up windows are budget-deferrals, not
    # capacity denials: deferrals is the (marked) subset of denials
    assert h.deferrals == [1, 2]
    assert set(h.deferrals) <= set(h.denials)

    # an ample budget funds both give-backs (the pinned PR 4 ladder)
    ample = preemption_pair("preemption", windows=3,
                            tm_spec=default_tm_spec(158.0),
                            migration_budget_mb=1e9)
    assert ample.tenant("V").preemptions == [0, 1]

    # and the budgeted run is decision-identical under the scalar oracle
    assert_drivers_identical(
        res, preemption_pair("preemption", windows=3,
                             tm_spec=default_tm_spec(158.0),
                             migration_budget_mb=800.0, driver="scalar"))
