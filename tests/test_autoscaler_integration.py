"""End-to-end autoscaler behaviour: the paper's §5 claims as assertions."""
import pytest

from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.streaming.engine import StreamEngine


def run_policy(qname, policy, *, max_level=2, seed=3):
    flow = QUERIES[qname]()
    eng = StreamEngine(flow, seed=seed)
    ctl = AutoScaler(eng, TARGET_RATES[qname], ControllerConfig(
        policy=policy, justin=JustinParams(max_level=max_level)))
    ctl.run()
    return ctl.summary()


@pytest.fixture(scope="module")
def q11_results():
    return {p: run_policy("q11", p) for p in ("ds2", "justin")}


@pytest.fixture(scope="module")
def q1_results():
    return {p: run_policy("q1", p) for p in ("ds2", "justin")}


def test_both_policies_reach_target_q11(q11_results):
    for p, s in q11_results.items():
        assert s["achieved_rate"] >= 0.97 * s["target"], (p, s)


def test_justin_saves_cpu_on_stateful_query(q11_results):
    """§5.1: significant CPU reduction on the complex stateful queries."""
    d, j = q11_results["ds2"], q11_results["justin"]
    assert j["cpu_cores"] < d["cpu_cores"]
    assert 1 - j["cpu_cores"] / d["cpu_cores"] >= 0.25


def test_justin_saves_memory_on_stateful_query(q11_results):
    d, j = q11_results["ds2"], q11_results["justin"]
    assert j["memory_mb"] < d["memory_mb"]


def test_justin_uses_scale_up_on_q11(q11_results):
    p, lvl = q11_results["justin"]["config"]["user_sessions"]
    assert lvl >= 1                             # scaled up at least once
    pd, _ = q11_results["ds2"]["config"]["user_sessions"]
    assert p < pd                               # fewer tasks than DS2


def test_stateless_query_strips_memory(q1_results):
    """§5.1 q1: same parallelism, managed memory stripped (m = ⊥)."""
    d, j = q1_results["ds2"], q1_results["justin"]
    assert j["achieved_rate"] >= 0.97 * j["target"]
    _, lvl = j["config"]["currency_map"]
    assert lvl is None
    assert j["memory_mb"] < d["memory_mb"]


@pytest.mark.slow
def test_q5_no_penalty():
    """§5.1: a query that doesn't benefit must not be penalized."""
    d = run_policy("q5", "ds2")
    j = run_policy("q5", "justin")
    assert j["achieved_rate"] >= 0.97 * j["target"]
    assert j["cpu_cores"] <= d["cpu_cores"] + 1
    assert j["memory_mb"] <= d["memory_mb"] * 1.1


def test_reasonable_step_counts(q11_results):
    """§5.1: same or slightly more steps; never runaway."""
    d, j = q11_results["ds2"], q11_results["justin"]
    assert j["steps"] <= d["steps"] + 2
    assert j["steps"] <= 6


def test_epsilon_growth_is_not_gated():
    """A drifted re-quote of an identical footprint (mem_new = mem_cur +
    1e-12) is NOT a scale-up: the admission hook must not be consulted
    and the reconfiguration must be enacted.  Guards the epsilon-
    disciplined growth test in step_window (repro.core.units)."""
    from repro.core.policy import Proposal

    calls = []

    def deny(scaler, config, cpu, mem):
        calls.append(config)
        return False

    flow = QUERIES["q1"]()
    eng = StreamEngine(flow, seed=0)
    ctl = AutoScaler(eng, TARGET_RATES["q1"],
                     ControllerConfig(policy="ds2"), admission=deny)
    new_config = dict(flow.config())
    name = next(n for n in new_config if n not in flow.sources())
    p, lvl = new_config[name]
    new_config[name] = (p + 1, lvl)

    base = ctl.resources()
    ctl.policy.should_trigger = lambda *a, **k: True
    ctl.policy.propose = lambda *a, **k: Proposal(config=new_config)
    ctl.resources = lambda config=None, *, cluster=None: \
        base if config is None else (base[0], base[1] + 1e-12)

    ctl.step_window(0)
    assert calls == []                            # hook never consulted
    assert ctl.flow.config()[name] == (p + 1, lvl)   # proposal enacted
    assert not ctl.history[-1].denied
