"""SLO metrics on hand-built histories with known answers.

Each synthetic ``HistoryRow`` list encodes a specific violation/catch-up
shape so every reduction (violation windows, catch-up episodes, p95
backlog, resource integrals, the full scorecard) is checked against a
number derived by hand, not by re-running the engine.
"""
import pytest

from repro.core.controller import HistoryRow
from repro.scenarios.metrics import (CatchUp, catch_up_episodes,
                                     catch_up_time_s, p95_backlog,
                                     resource_integrals, slo_report,
                                     violation_windows)


def row(t, rate, target, *, cpu=4, mem=1000.0, backlog=0, denied=False):
    return HistoryRow(t=t, step=0, achieved_rate=rate, cpu_cores=cpu,
                      memory_mb=mem, config={}, triggered=False,
                      target=target, backlog=backlog, denied=denied)


# one 6-s window per row; target 100; slack 0.97 -> threshold 97
#   w0 ok, w1-w2 violate (spike), w3 recovered, w4 ok
SPIKE = [row(6.0, 100, 100), row(12.0, 80, 100, backlog=500),
         row(18.0, 90, 100, backlog=900), row(24.0, 100, 100, backlog=100),
         row(30.0, 100, 100)]


def test_violation_windows():
    assert violation_windows(SPIKE) == [1, 2]
    assert violation_windows(SPIKE, slack=0.5) == []
    # slack=1.0 turns the boundary windows into violations too
    assert violation_windows([row(6, 99, 100)], slack=1.0) == [0]


def test_catch_up_single_episode():
    eps = catch_up_episodes(SPIKE)
    assert eps == [CatchUp(onset_window=1, recovered_window=3,
                           duration_s=12.0)]
    assert catch_up_time_s(SPIKE) == 12.0


def test_catch_up_after_t_excludes_cold_start():
    # violation at w0 (cold start) and another at w3
    h = [row(6, 50, 100), row(12, 100, 100), row(18, 100, 100),
         row(24, 80, 100), row(30, 100, 100)]
    assert catch_up_time_s(h) == 6.0            # both episodes last 6 s
    eps = catch_up_episodes(h, after_t=10.0)    # cold start excluded
    assert eps == [CatchUp(3, 4, 6.0)]


def test_catch_up_after_t_excludes_ongoing_episode_whole():
    """An episode whose onset precedes after_t is excluded entirely —
    its tail windows must not re-enter as a fresh truncated episode."""
    h = [row(6, 50, 100), row(12, 50, 100), row(18, 100, 100),
         row(24, 80, 100), row(30, 100, 100)]
    eps = catch_up_episodes(h, after_t=10.0)   # cuts the first episode open
    assert eps == [CatchUp(3, 4, 6.0)]
    assert catch_up_episodes(h, after_t=25.0) == []


def test_catch_up_never_recovers_is_open_ended():
    # violation persists through the last window: duration extends one
    # (mean) window past the history's end rather than stopping at the
    # last onset — 18-12 plus one 6-s window
    h = [row(6, 100, 100), row(12, 50, 100), row(18, 60, 100)]
    eps = catch_up_episodes(h)
    assert eps == [CatchUp(onset_window=1, recovered_window=None,
                           duration_s=12.0)]
    assert not eps[0].recovered
    assert catch_up_time_s(h) == 12.0


def test_catch_up_open_final_window_scores_no_better_than_recovery():
    """A policy still violating at the end must not beat one that
    violated the same window and recovered in the next."""
    still_bad = [row(6, 100, 100), row(12, 50, 100)]
    recovered = [row(6, 100, 100), row(12, 50, 100), row(18, 100, 100)]
    assert catch_up_time_s(still_bad) >= catch_up_time_s(recovered)
    assert catch_up_time_s(still_bad) == 6.0


def test_catch_up_none_when_clean():
    assert catch_up_time_s([row(6, 100, 100), row(12, 100, 100)]) is None


def test_p95_backlog():
    assert p95_backlog([]) == 0.0
    assert p95_backlog([row(6, 1, 1, backlog=40)]) == 40.0
    h = [row(6 * i, 100, 100, backlog=b)
         for i, b in enumerate([0, 100, 200, 300, 400])]
    # sorted [0..400], pos = .95*4 = 3.8 -> 300 + .8*100
    assert p95_backlog(h) == pytest.approx(380.0)


def test_resource_integrals():
    h = [row(6, 100, 100, cpu=2, mem=500.0),
         row(12, 100, 100, cpu=4, mem=1500.0)]
    assert resource_integrals(h) == (6, 2000.0)


def test_slo_report_scorecard():
    rep = slo_report(SPIKE)
    assert rep.windows == 5
    assert rep.violations == 2
    assert rep.violation_windows == (1, 2)
    assert rep.catch_up_s == 12.0
    assert rep.recovered
    assert rep.p95_backlog == pytest.approx(820.0)  # [0,0,100,500,900] @ .95
    assert rep.cpu_slot_windows == 20
    assert rep.mb_windows == 5000.0
    assert rep.denied_windows == 0
    d = rep.to_dict()
    assert d["violation_windows"] == [1, 2]


def test_slo_report_counts_denials():
    h = [row(6, 100, 100), row(12, 80, 100, denied=True),
         row(18, 80, 100, denied=True), row(24, 100, 100)]
    assert slo_report(h).denied_windows == 2


def test_single_row_history_has_no_window_spacing():
    """Satellite pin: a lone row carries no spacing information — its
    ``t`` is the episode's absolute start offset.  The old fallback
    returned ``history[0].t`` as the "mean window", inflating a 1-window
    open-ended violation's catch-up to wherever the episode happened to
    sit on the clock (600 s here, for a 6 s window)."""
    lone = [row(600.0, 50, 100, backlog=400)]
    eps = catch_up_episodes(lone)
    assert eps == [CatchUp(onset_window=0, recovered_window=None,
                           duration_s=0.0)]
    assert catch_up_time_s(lone) == 0.0
    rep = slo_report(lone)
    assert rep.catch_up_s == 0.0 and not rep.recovered
