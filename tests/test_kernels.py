"""Pallas kernels: shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an optional [test] extra: only the property test needs it
# (pinned-seed fallback below); everything else runs regardless
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.kernels.decode_attn.ops import decode
from repro.kernels.flash_attn.ops import attention
from repro.kernels.sorted_probe.ops import probe
from repro.kernels.window_agg.ops import aggregate


# ------------------------------------------------------------- sorted_probe
@pytest.mark.parametrize("t_size", [17, 512, 2048, 5000])
@pytest.mark.parametrize("n_q", [1, 300, 1024])
@pytest.mark.parametrize("dtype", [np.int32, np.int64])
def test_sorted_probe_sweep(rng, t_size, n_q, dtype):
    table = np.unique(rng.integers(0, 1 << 20, t_size)).astype(dtype)
    queries = np.concatenate([
        rng.choice(table, min(n_q // 2 + 1, len(table))),
        rng.integers(0, 1 << 20, n_q // 2).astype(dtype)])[:n_q]
    p1, f1 = probe(jnp.asarray(table), jnp.asarray(queries))
    p2, f2 = probe(jnp.asarray(table), jnp.asarray(queries), impl="ref")
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))


def _check_sorted_probe(table_keys, query_keys):
    table = jnp.asarray(sorted(table_keys), jnp.int32)
    queries = jnp.asarray(query_keys, jnp.int32)
    pos, found = probe(table, queries)
    for q, p, f in zip(query_keys, np.asarray(pos), np.asarray(found)):
        assert bool(f) == (q in table_keys)
        assert int(p) == int(np.searchsorted(np.asarray(table), q))


if HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 10_000), min_size=1, max_size=200,
                    unique=True),
           st.lists(st.integers(0, 10_000), min_size=1, max_size=100))
    def test_sorted_probe_property(table_keys, query_keys):
        _check_sorted_probe(table_keys, query_keys)
else:
    @pytest.mark.parametrize("seed", range(20))
    def test_sorted_probe_property(seed):
        r = np.random.default_rng(seed)
        table_keys = np.unique(
            r.integers(0, 10_000, int(r.integers(1, 200)))).tolist()
        query_keys = r.integers(0, 10_000, int(r.integers(1, 100))).tolist()
        _check_sorted_probe(table_keys, query_keys)


# -------------------------------------------------------------- window_agg
@pytest.mark.parametrize("n,segs,v", [(100, 16, 1), (2048, 512, 4),
                                      (5000, 1000, 8), (1024, 513, 2)])
def test_window_agg_sweep(rng, n, segs, v):
    seg = jnp.asarray(rng.integers(0, segs, n), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, v)), jnp.float32)
    s1, c1 = aggregate(seg, vals, segs)
    s2, c2 = aggregate(seg, vals, segs, impl="ref")
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-3)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


# -------------------------------------------------------------- flash_attn
@pytest.mark.parametrize("s,dh", [(128, 64), (300, 64), (512, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attn_sweep(rng, s, dh, causal, dtype):
    q = jnp.asarray(rng.normal(size=(2, 4, s, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(2, 2, s, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(2, 2, s, dh)), dtype)
    o1 = attention(q, k, v, causal=causal)
    o2 = attention(q, k, v, causal=causal, impl="ref")
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attn_swa(rng, window):
    q = jnp.asarray(rng.normal(size=(1, 2, 384, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 384, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 384, 64)), jnp.float32)
    o1 = attention(q, k, v, causal=True, window=window)
    o2 = attention(q, k, v, causal=True, window=window, impl="ref")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


# -------------------------------------------------------------- decode_attn
@pytest.mark.parametrize("s,h,kv,dh", [(512, 8, 4, 64), (1000, 4, 4, 128),
                                       (513, 8, 2, 64)])
def test_decode_attn_sweep(rng, s, h, kv, dh):
    q = jnp.asarray(rng.normal(size=(2, h, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(2, kv, s, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(2, kv, s, dh)), jnp.float32)
    vl = jnp.asarray([s, max(1, s // 3)], jnp.int32)
    o1 = decode(q, kc, vc, vl)
    o2 = decode(q, kc, vc, vl, impl="ref")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_decode_attn_ragged_masking(rng):
    """Slots past valid_len must not affect the result."""
    q = jnp.asarray(rng.normal(size=(1, 2, 64)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.float32)
    o1 = decode(q, kc, vc, 100)
    kc2 = kc.at[:, :, 100:].set(999.0)          # garbage past valid_len
    vc2 = vc.at[:, :, 100:].set(-999.0)
    o2 = decode(q, kc2, vc2, 100)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_flash_attn_matches_model_attention(rng):
    """Pallas kernel == the model's chunked_attention (the dry-run path)."""
    from repro.models.layers import chunked_attention
    q = jnp.asarray(rng.normal(size=(2, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 4, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 4, 256, 64)), jnp.float32)
    o1 = attention(q, k, v, causal=True)
    o2 = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
