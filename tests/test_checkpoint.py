"""Checkpoint/restore + fault-tolerant training driver."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck
from repro.launch.train import train


def make_tree(key=0):
    k = jax.random.key(key)
    return {"a": jax.random.normal(k, (64, 32)),
            "nested": {"b": jnp.arange(100, dtype=jnp.int32),
                       "c": jax.random.normal(k, (7,))}}


def test_save_restore_roundtrip(tmp_path):
    tree = make_tree()
    ck.save(str(tmp_path), 10, tree, cfg="cfgA")
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = ck.restore(str(tmp_path), like, cfg="cfgA")
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_rejects_config_mismatch(tmp_path):
    tree = make_tree()
    ck.save(str(tmp_path), 1, tree, cfg="cfgA")
    with pytest.raises(ValueError, match="mismatch"):
        ck.restore(str(tmp_path), tree, cfg="cfgB")


def test_keep_n_pruning(tmp_path):
    tree = make_tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, tree, keep=2)
    assert ck.latest_step(str(tmp_path)) == 5
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step"))
    assert len(dirs) == 2


def test_uncommitted_checkpoint_ignored(tmp_path):
    tree = make_tree()
    ck.save(str(tmp_path), 1, tree)
    # fake a torn write at a later step
    os.makedirs(tmp_path / "step_00000009")
    with open(tmp_path / "step_00000009" / "manifest.json", "w") as f:
        f.write("{}")
    assert ck.latest_step(str(tmp_path)) == 1


@pytest.mark.slow
def test_train_restart_after_failure(tmp_path):
    """Injected preemption: training restores and completes all steps."""
    r = train("llama3.2-3b", steps=12, ckpt_dir=str(tmp_path), ckpt_every=4,
              fail_at=10, verbose=False)
    assert r["restarts"] == 1
    assert np.isfinite(r["final_loss"])


@pytest.mark.slow
def test_train_resume_continues_from_checkpoint(tmp_path):
    train("mamba2-130m", steps=8, ckpt_dir=str(tmp_path), ckpt_every=4,
          verbose=False)
    assert ck.latest_step(str(tmp_path)) == 8
    r = train("mamba2-130m", steps=12, ckpt_dir=str(tmp_path), ckpt_every=4,
              resume="auto", verbose=False)
    assert np.isfinite(r["final_loss"])


@pytest.mark.slow
def test_gradient_compression_training_converges():
    r_plain = train("llama3.2-3b", steps=10, verbose=False)
    r_comp = train("llama3.2-3b", steps=10, compress_grads=True,
                   verbose=False)
    # int8 + error feedback stays close to uncompressed training
    assert abs(r_comp["final_loss"] - r_plain["final_loss"]) < 0.2


@pytest.mark.slow
def test_microbatch_accumulation_matches(tmp_path):
    r1 = train("llama3.2-3b", steps=6, batch=4, microbatch=1, verbose=False)
    r2 = train("llama3.2-3b", steps=6, batch=4, microbatch=2, verbose=False)
    assert abs(r1["final_loss"] - r2["final_loss"]) < 0.1
