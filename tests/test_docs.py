"""Documentation stays real: the files exist, every documented command
refers to code that is present and compiles, and the commands the
acceptance criteria name are actually documented.

The full ``--help`` smokes run in CI's docs job (``tools/check_docs.py``
without ``--static``); tier-1 keeps to the static checks so the suite
stays fast.
"""
import importlib.util
import pathlib

REPO = pathlib.Path(__file__).resolve().parents[1]

spec = importlib.util.spec_from_file_location(
    "check_docs", REPO / "tools" / "check_docs.py")
check_docs = importlib.util.module_from_spec(spec)
spec.loader.exec_module(check_docs)


def test_docs_exist_and_are_substantial():
    for f in ("README.md", "docs/architecture.md", "docs/policies.md",
              "docs/golden-traces.md", "docs/static-analysis.md"):
        p = REPO / f
        assert p.exists(), f
        assert len(p.read_text()) > 1500, f"{f} is a stub"


def test_readme_documents_the_entry_points():
    text = (REPO / "README.md").read_text()
    for needle in ("--grid", "--policy", "nexmark_eval.py",
                   "colocation_demo.py", "pip install -e", "pytest"):
        assert needle in text, needle


def test_policies_doc_covers_registry_surface():
    text = (REPO / "docs" / "policies.md").read_text()
    for needle in ("register_policy", "make_policy", "available_policies",
                   "propose", "commit", "resources_config",
                   "should_trigger", "propose_shrink", "shrink_memory",
                   "Proposal",
                   "ds2", "justin", "static", "threshold",
                   "--policy threshold"):
        assert needle in text, needle


def test_architecture_covers_required_topics():
    text = (REPO / "docs" / "architecture.md").read_text().lower()
    for topic in ("decision window", "sim_time_scale", "admission",
                  "cluster", "bin-packing", "shared-tm placement",
                  "preemption", "amortized", "migration"):
        assert topic in text, topic


def test_golden_traces_doc_pins_the_quirks():
    text = (REPO / "docs" / "golden-traces.md").read_text().lower()
    assert "oldest" in text and "items()" in text     # memtable quirk...
    assert "newest" in text and "fixed in pr 4" in text   # ...now fixed
    assert "resize" in text and "spill" in text       # resize semantics
    assert "regenerat" in text                        # the workflow


def test_extractor_handles_continuations_and_prefixes(tmp_path):
    md = tmp_path / "x.md"
    md.write_text(
        "```bash\n"
        "PYTHONPATH=src python benchmarks/nexmark_eval.py --grid \\\n"
        "  --queries q1 --windows 3\n"
        "$ pip install -e \".[test]\"\n"
        "# a comment, not a command\n"
        "python benchmarks/run.py episode   # trailing comment stripped\n"
        "```\n")
    cmds = check_docs.extract_commands(str(md))
    assert cmds == [
        "python benchmarks/nexmark_eval.py --grid --queries q1 --windows 3",
        "pip install -e .[test]",
        "python benchmarks/run.py episode"]


def test_flag_surface_smoke_catches_dropped_flags():
    """The non-static checker --help-smokes documented commands AND
    verifies every documented long flag is still on the CLI surface
    (e.g. ``nexmark_eval.py --policy threshold`` in docs/policies.md)."""
    err = check_docs.check_command(
        "python examples/colocation_demo.py --no-such-flag")
    assert err is not None and "--no-such-flag" in err
    assert check_docs.check_command(
        "python examples/colocation_demo.py --tenant-a justin") is None


def test_every_documented_command_parses_statically():
    """All commands extracted from README/docs pass the static check
    (scripts exist and byte-compile; pip/pytest surfaces present)."""
    total, failures = 0, []
    for path in check_docs.doc_files():
        for cmd in check_docs.extract_commands(str(path)):
            total += 1
            err = check_docs.check_command(cmd, static=True)
            if err is not None:
                failures.append((cmd, err))
    assert total >= 8, f"docs only document {total} commands"
    assert not failures, failures


def test_static_analysis_doc_covers_the_rule_panel():
    """docs/static-analysis.md documents every registered rule id, the
    suppression syntax, and the baseline workflow."""
    import pathlib
    import sys
    sys.path.insert(0, str(REPO))
    from tools.lint.core import all_rules
    text = (REPO / "docs" / "static-analysis.md").read_text()
    for rule in all_rules():
        assert rule.id in text, f"rule {rule.id} undocumented"
    for needle in ("reprolint: ignore", "--write-baseline", "--fail-on-new",
                   "--self-check", "baseline.json", "# as:", "# expect:"):
        assert needle in text, needle
    readme = (REPO / "README.md").read_text()
    assert "python -m tools.lint" in readme
    assert "static-analysis.md" in readme


def test_checker_resolves_python_dash_m_modules():
    """check_docs --help-smokes `python -m <module>` commands: the module
    must resolve, and documented flags must be on its CLI surface."""
    assert check_docs.check_command(
        "python -m tools.lint --fail-on-new --baseline x.json") is None
    err = check_docs.check_command("python -m tools.lint --no-such-flag")
    assert err is not None and "--no-such-flag" in err
    err = check_docs.check_command("python -m tools.no_such_module")
    assert err is not None and "does not resolve" in err
    # static mode: resolve + byte-compile only, no subprocess
    assert check_docs.check_command(
        "python -m tools.lint --fail-on-new", static=True) is None
