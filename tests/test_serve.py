"""JustinServe: paged KV cache + hybrid serving elasticity."""
import numpy as np
import pytest

from repro.serve.engine import (BASE_HBM_BUDGET, JustinServeController,
                                RequestGen, ServingReplica, ServeCosts,
                                WorkloadSpec)
from repro.serve.kv_cache import PagedKVCache, PageSpec

pytestmark = pytest.mark.slow  # heavy jax compiles; run with -m slow


def test_prefix_cache_hit_after_insert():
    c = PagedKVCache(64 * 2**21)
    toks = np.arange(256, dtype=np.int32)
    reused, _ = c.lookup_prefix(toks)
    assert reused == 0
    c.insert_prefix(toks)
    reused, _ = c.lookup_prefix(toks)
    assert reused == 256
    assert c.metrics.hit_rate > 0


def test_eviction_to_host_tier_and_promotion():
    c = PagedKVCache(4 * 2**21)                 # tiny: 4 pages
    for i in range(8):
        toks = (np.arange(64, dtype=np.int32) + 1000 * i)
        c.insert_prefix(toks)
    assert c.metrics.evictions > 0
    assert c.hbm_pages <= c.hbm_capacity
    # a host-tier page promotes on reuse and charges fetch latency
    toks0 = np.arange(64, dtype=np.int32)
    before = c.metrics.host_fetches
    c.lookup_prefix(toks0)
    assert c.metrics.host_fetches >= before


def test_resize_changes_capacity():
    c = PagedKVCache(4 * 2**21)
    assert c.hbm_capacity == 4
    c.resize(16 * 2**21)
    assert c.hbm_capacity == 16


def test_replica_prefill_reuse_cuts_service_time():
    costs = ServeCosts()
    r = ServingReplica(BASE_HBM_BUDGET * 4, costs)
    gen = RequestGen(WorkloadSpec(n_prefixes=1))
    ms1 = r.serve(gen.make(1)[0])
    ms2 = r.serve(gen.make(1)[0])               # same prefix: mostly reused
    assert ms2 < 0.5 * ms1


def test_justin_serve_beats_replica_only():
    """The hybrid policy reaches the target with fewer replicas."""
    res = {}
    for policy in ("ds2", "justin"):
        ctl = JustinServeController(120, policy=policy)
        res[policy] = ctl.autoscale()
    assert res["justin"]["replicas"] <= res["ds2"]["replicas"]
    assert res["justin"]["level"] >= 1          # used vertical scaling
    assert res["justin"]["busyness"] <= 1.0


def test_serve_controller_converges():
    ctl = JustinServeController(30, policy="justin")
    res = ctl.autoscale()
    assert res["busyness"] <= 0.95
