"""Observability subsystem tests.

Four contracts:

* **Decision invariance** — attaching a live tracer to a golden episode
  changes no decision: the full decision fingerprint (steps, trigger
  sequence, every enacted config, final resources) is byte-identical
  with tracing on and off.  This is the load-bearing guarantee that
  lets CI record traces from the same episodes the goldens pin.
* **Provenance** — every policy's proposals carry an ``Explain`` record
  whose per-operator actions/signals describe the decision actually
  made, admission verdicts carry their quote, and ``HistoryRow.reason``
  / ``summary()["reasons"]`` / ``SLOReport.violations_by_reason`` agree
  with the enum.
* **Registry** — instruments behave, the disabled path is a shared
  no-op, and ``absorb_engine`` / ``absorb_fleet`` expose the legacy
  scattered totals behind one snapshot.
* **Schema** — exported traces round-trip and pass the stdlib checker
  (``tools/check_trace.py``), whose duplicated constants are pinned
  equal to ``repro.obs``'s.
"""
import importlib.util
import io
import json
import pathlib

import pytest

from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.core.policy import make_policy
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.obs import (CATS, MetricsRegistry, NULL_REGISTRY, NULL_TRACER,
                       REASONS, Tracer, chrome_trace, read_jsonl,
                       reason_counts, write_chrome, write_jsonl)
from repro.obs.export import TRACE_KIND, TRACE_VERSION
from repro.obs.registry import _NOOP
from repro.scenarios.metrics import slo_report
from repro.streaming.engine import StreamEngine

REPO = pathlib.Path(__file__).parent.parent
GOLDEN = json.loads((REPO / "tests/data/golden_autoscale.json").read_text())


def _load_tool(name: str):
    spec = importlib.util.spec_from_file_location(
        name, REPO / "tools" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


check_trace = _load_tool("check_trace")
trace_report = _load_tool("trace_report")


def run_episode(qname: str, policy: str, tracer=None,
                max_windows: int | None = None):
    """The golden episode protocol (tests/test_golden_trace.py), with an
    optional tracer attached; returns (decision fingerprint, scaler)."""
    meta = GOLDEN["_meta"]
    eng = StreamEngine(QUERIES[qname](), seed=meta["seed"])
    cfg = ControllerConfig(
        policy=policy, justin=JustinParams(max_level=meta["max_level"]))
    ctl = AutoScaler(eng, TARGET_RATES[qname], cfg,
                     policy=make_policy(policy, cfg), tracer=tracer)
    if max_windows is None:
        hist = ctl.run()
    else:
        hist = ctl.run(max_windows=max_windows)
    fingerprint = json.dumps({
        "steps": ctl.steps,
        "triggered": [h.triggered for h in hist],
        "configs": [sorted((op, list(pc)) for op, pc in h.config.items())
                    for h in hist],
        "reasons": [h.reason for h in hist],
        "cpu_cores": hist[-1].cpu_cores,
        "memory_mb": hist[-1].memory_mb,
    }, sort_keys=True)
    return fingerprint, ctl


# ------------------------------------------------------- decision invariance
@pytest.mark.parametrize("key", ["q8_justin", "q11_justin", "q11_ds2"])
def test_tracing_is_decision_invariant(key):
    """The four golden episodes decide byte-identically with tracing on
    and off — spans observe sim-time, they never steer."""
    qname, policy = key.split("_")
    off, _ = run_episode(qname, policy, tracer=None)
    tr = Tracer(enabled=True)
    on, _ = run_episode(qname, policy, tracer=tr)
    assert on == off
    assert tr.spans, "a live tracer on a golden episode must record spans"
    assert {s.cat for s in tr.spans} <= set(CATS)
    seqs = [s.seq for s in tr.spans]
    assert seqs == list(range(len(seqs)))


@pytest.mark.slow
def test_tracing_is_decision_invariant_q8_ds2():
    off, _ = run_episode("q8", "ds2", tracer=None)
    on, _ = run_episode("q8", "ds2", tracer=Tracer(enabled=True))
    assert on == off


def test_disabled_tracer_records_nothing():
    for tr in (Tracer(enabled=False), NULL_TRACER):
        tr.record("engine.window", "engine", 0.0, 1.0)
        assert tr.spans == []
        assert tr.summary() == {}


# --------------------------------------------------------------- provenance
def _propose_spans(tracer):
    return [s for s in tracer.spans if s.name == "policy.propose"]


def _explain_of(span):
    ops = span.args["operators"]
    assert isinstance(ops, dict) and ops
    for rec in ops.values():
        assert set(rec) == {"action", "signals"}
        assert isinstance(rec["signals"], dict)
    return ops


def test_explain_justin():
    tr = Tracer(enabled=True)
    run_episode("q8", "justin", tracer=tr)
    spans = _propose_spans(tr)
    assert spans
    actions = set()
    for s in spans:
        assert s.args["policy"] == "justin"
        assert set(s.args["thresholds"]) == {
            "delta_theta", "delta_tau_ms", "max_level", "hysteresis"}
        actions |= {r["action"] for r in _explain_of(s).values()}
    known = {"hold", "rescale", "memory_scale_up_again", "rollback_memory",
             "cancel_rescale_memory_up", "rescale_at_max_level"}
    assert actions <= known
    # the headline q8 trace exhibits Algorithm 1's hybrid branches
    assert "cancel_rescale_memory_up" in actions
    assert "rescale" in actions
    # stateful operators expose the exact theta/tau observations
    wj = [_explain_of(s)["window_join"]["signals"] for s in spans]
    assert all("theta" in sig and "tau_ms" in sig
               and "prev_scaled_up" in sig for sig in wj)


def test_explain_ds2():
    tr = Tracer(enabled=True)
    run_episode("q11", "ds2", tracer=tr)
    spans = _propose_spans(tr)
    assert spans
    actions = set()
    for s in spans:
        assert s.args["policy"] == "ds2"
        assert set(s.args["thresholds"]) == {"target_busyness",
                                             "max_parallelism"}
        for rec in _explain_of(s).values():
            actions.add(rec["action"])
            assert "true_rate_per_task" in rec["signals"]
            assert "ds2_parallelism" in rec["signals"]
    assert actions <= {"scale_out", "scale_in", "hold"}
    assert "scale_out" in actions


def test_explain_static():
    tr = Tracer(enabled=True)
    run_episode("q11", "static", tracer=tr, max_windows=3)
    for s in _propose_spans(tr):
        assert s.args["policy"] == "static"
        assert all(r["action"] == "hold"
                   for r in _explain_of(s).values())


def test_explain_threshold():
    tr = Tracer(enabled=True)
    run_episode("q11", "threshold", tracer=tr, max_windows=4)
    spans = _propose_spans(tr)
    assert spans
    actions = set()
    for s in spans:
        assert s.args["policy"] == "threshold"
        assert "busy_high" in s.args["thresholds"]
        for rec in _explain_of(s).values():
            actions.add(rec["action"])
            assert "hot" in rec["signals"]
    assert actions & {"scale_out", "scale_out_blamed_busiest"}


def test_admission_quote_span():
    tr = Tracer(enabled=True)
    run_episode("q8", "justin", tracer=tr)
    quotes = [s for s in tr.spans if s.name == "admission.quote"]
    assert quotes
    for q in quotes:
        assert {"cpu_cur", "mem_cur", "cpu_new", "mem_new", "grows",
                "admitted", "shared"} == set(q.args)
    # single-tenant episodes never consult a cluster: admitted stays None
    assert all(q.args["admitted"] is None for q in quotes)


def test_reason_enum_and_summary():
    _, ctl = run_episode("q8", "justin")
    reasons = [h.reason for h in ctl.history]
    assert set(reasons) <= set(REASONS)
    assert "reconfigured" in reasons
    counts = ctl.summary()["reasons"]
    assert counts == reason_counts(ctl.history)
    assert sum(counts.values()) == len(ctl.history)


def test_slo_violations_by_reason():
    _, ctl = run_episode("q8", "justin")
    rep = slo_report(ctl.history)
    assert sum(rep.violations_by_reason.values()) == rep.violations
    assert set(rep.violations_by_reason) <= set(REASONS)
    assert rep.to_dict()["violations_by_reason"] == rep.violations_by_reason


# ----------------------------------------------------------------- registry
def test_registry_instruments():
    reg = MetricsRegistry()
    reg.counter("a").inc()
    reg.counter("a").inc(2)
    reg.gauge("g").set(4.5)
    reg.histogram("h").observe(1.0)
    reg.histogram("h").observe(3.0)
    with reg.timer("t"):
        pass
    snap = reg.snapshot()
    assert snap["counters"] == {"a": 3}
    assert snap["gauges"] == {"g": 4.5}
    h = snap["histograms"]["h"]
    assert (h["count"], h["min"], h["max"], h["mean"]) == (2, 1.0, 3.0, 2.0)
    t = snap["timers"]["t"]
    assert t["count"] == 1 and t["total_s"] >= 0.0
    tm = reg.timer("t")
    assert tm.s == tm.last_s and tm.us == pytest.approx(tm.s * 1e6)


def test_registry_disabled_is_shared_noop():
    assert NULL_REGISTRY.counter("x") is _NOOP
    assert NULL_REGISTRY.timer("y") is _NOOP
    with NULL_REGISTRY.timer("y") as t:
        t.observe(1.0)
    assert NULL_REGISTRY.snapshot() == {"counters": {}, "gauges": {},
                                        "histograms": {}, "timers": {}}


def test_registry_absorb_engine():
    eng = StreamEngine(QUERIES["q8"](), seed=3)
    eng.run(12.0, TARGET_RATES["q8"])
    reg = MetricsRegistry()
    reg.absorb_engine(eng, prefix="e")
    gauges = reg.snapshot()["gauges"]
    assert gauges["e.lsm.window_join.writes"] > 0
    assert any(k.startswith("e.task.") and k.endswith(".cost_per_event")
               for k in gauges)


def test_registry_absorb_fleet():
    from repro.scenarios.population import run_fleet
    res = run_fleet(48, 10, admission="priority", seed=0)
    reg = MetricsRegistry()
    reg.absorb_fleet(res, prefix="f")
    snap = reg.snapshot()
    assert snap["counters"]["f.tenants"] == 48
    assert snap["counters"]["f.policy_steps"] > 0
    assert "f.moved_mb" in snap["gauges"]


# ------------------------------------------------------------ trace schema
def _small_trace():
    tr = Tracer(enabled=True)
    run_episode("q11", "justin", tracer=tr, max_windows=3)
    return tr


def test_jsonl_roundtrip_and_schema(tmp_path):
    tr = _small_trace()
    path = str(tmp_path / "t.jsonl")
    write_jsonl(tr.spans, path, meta={"seed": 3})
    header, spans = read_jsonl(path)
    assert header["kind"] == TRACE_KIND
    assert header["version"] == TRACE_VERSION and header["seed"] == 3
    assert spans == [s.to_dict() for s in tr.spans]
    lines = pathlib.Path(path).read_text().splitlines()
    assert check_trace.check_jsonl(lines) == []
    # the checker actually rejects drift
    bad = json.loads(lines[1])
    bad["cat"] = "mystery"
    assert check_trace.check_jsonl([lines[0], json.dumps(bad)])
    with pytest.raises(ValueError):
        read_jsonl(_write(tmp_path, '{"kind": "other"}\n'))


def _write(tmp_path, text):
    p = tmp_path / "bad.jsonl"
    p.write_text(text)
    return str(p)


def test_chrome_export_schema(tmp_path):
    tr = _small_trace()
    path = str(tmp_path / "t.json")
    write_chrome(tr.spans, path, meta={"profile": "none"})
    data = json.loads(pathlib.Path(path).read_text())
    assert check_trace.check_chrome(data) == []
    assert data == chrome_trace(tr.spans, meta={"profile": "none"})
    names = [e["args"]["name"] for e in data["traceEvents"]
             if e["ph"] == "M"]
    assert "repro control loop" in names and "episode" in names
    xs = [e for e in data["traceEvents"] if e["ph"] == "X"]
    assert xs and all(e["dur"] >= 1.0 for e in xs)


def test_checker_constants_pinned_to_repro_obs():
    """tools/check_trace.py is stdlib-only by convention and duplicates
    the schema constants; this pin keeps the copies from drifting."""
    assert check_trace.TRACE_KIND == TRACE_KIND
    assert check_trace.TRACE_VERSION == TRACE_VERSION
    assert tuple(check_trace.CATS) == tuple(CATS)


def test_committed_example_trace_is_valid():
    """docs/traces/q8_justin.trace.json must load in Perfetto: same
    schema gate CI applies."""
    path = REPO / "docs" / "traces" / "q8_justin.trace.json"
    data = json.loads(path.read_text())
    assert check_trace.check_chrome(data) == []


# ------------------------------------------------------------- trace report
def test_trace_report_answers_why():
    """The acceptance question: why did window 1 of q8-justin
    reconfigure?  The report names the Algorithm-1 action and the exact
    theta/tau signals it fired on."""
    tr = Tracer(enabled=True)
    run_episode("q8", "justin", tracer=tr)
    out = io.StringIO()
    shown = trace_report.render([s.to_dict() for s in tr.spans],
                                window=1, out=out)
    text = out.getvalue()
    assert shown > 0
    assert "== window 1 ==" in text
    assert "policy.propose" in text and "thresholds:" in text
    sig = next(s.args["operators"]["window_join"]["signals"]
               for s in tr.spans
               if s.name == "policy.propose" and s.window == 1)
    act = next(s.args["operators"]["window_join"]["action"]
               for s in tr.spans
               if s.name == "policy.propose" and s.window == 1)
    assert f"window_join: {act}" in text
    assert f"theta={sig['theta']:.6g}" in text
    assert f"tau_ms={sig['tau_ms']:.6g}" in text


def test_trace_report_tenant_filter():
    tr = _small_trace()
    spans = [s.to_dict() for s in tr.spans]
    assert trace_report.render(spans, tenant="no-such-tenant",
                               out=io.StringIO()) == 0
    assert trace_report.render(spans, tenant="", out=io.StringIO()) \
        == len(spans)


def test_tracer_summary_aggregates():
    tr = _small_trace()
    summ = tr.summary()
    assert summ
    key = next(k for k in summ if k.endswith("|engine|engine.window"))
    assert summ[key]["count"] >= 3
    assert summ[key]["sim_s"] > 0
