"""Fast-path invariants (engine.py module docstring): the coalesced
processing path and the optimized LSM internals must preserve determinism,
snapshot/restore and reconfigure semantics, and stay bit-identical to the
reference (sequential) CLOCK cache.
"""
import numpy as np
import pytest

from repro.data.nexmark import BidGen
from repro.state.lsm import LSMStore
from repro.streaming.engine import StreamEngine
from repro.streaming.graph import Dataflow
from repro.streaming.operators import KeyedStateOp, SinkOp, SourceOp


def pressured_flow(p=1, keyspace=50_000):
    """Update-heavy stateful flow driven hard enough that per-tick takes
    coalesce thousands of events per process call."""
    f = Dataflow("t")
    op = KeyedStateOp("agg", "update", keyspace=keyspace, prepopulate=False)
    f.chain(SourceOp("source", BidGen(seed=1)), op, SinkOp("sink"))
    f.nodes["agg"].parallelism = p
    return f


def task_items(eng, name):
    return [t.state.items() for t in eng.tasks[name]]


# ------------------------------------------------------------ determinism
def test_coalesced_run_is_deterministic():
    """Two engines, same seed, same drive -> identical metrics + state."""
    runs = []
    for _ in range(2):
        eng = StreamEngine(pressured_flow(p=2), seed=7)
        eng.run(8, 40_000)
        m = eng.collect()
        items = task_items(eng, "agg")
        runs.append((m, items))
    m0, m1 = runs[0][0], runs[1][0]
    assert m0 == m1
    for (k0, v0), (k1, v1) in zip(runs[0][1], runs[1][1]):
        np.testing.assert_array_equal(k0, k1)
        np.testing.assert_array_equal(v0, v1)


def test_snapshot_restore_under_coalesced_path():
    """snapshot()/restore() round-trips state contents exactly, and the
    restored engine replays identically (epoch-barrier semantics)."""
    eng = StreamEngine(pressured_flow(p=2), seed=3)
    eng.run(6, 40_000)
    snap = eng.snapshot()
    before = task_items(eng, "agg")

    eng.run(6, 40_000)                       # diverge
    eng.restore(snap)
    after = task_items(eng, "agg")
    for (k0, v0), (k1, v1) in zip(before, after):
        np.testing.assert_array_equal(k0, k1)
        np.testing.assert_array_equal(v0, v1)
    assert eng.now == snap["now"]
    # (replay equality across restores is NOT asserted: the source
    # generator's rng is deliberately outside the epoch snapshot, as on
    # the seed engine, so a replay sees fresh events)
    eng.run(4, 40_000)                       # restored engine keeps running
    assert eng.collect()["agg"]["processed"] > 0


def test_reconfigure_preserves_state_contents():
    """Scale out + memory level change re-partitions every live entry."""
    eng = StreamEngine(pressured_flow(p=2), seed=3)
    eng.run(6, 40_000)
    merged = {}
    for k, v in task_items(eng, "agg"):
        merged.update(zip(k.tolist(), map(tuple, v.tolist())))
    eng.reconfigure({"agg": (5, 1)})
    merged_after = {}
    for k, v in task_items(eng, "agg"):
        merged_after.update(zip(k.tolist(), map(tuple, v.tolist())))
    assert merged == merged_after
    assert len(eng.tasks["agg"]) == 5
    eng.run(2, 40_000)                       # still processes
    assert eng.collect()["sink"]["rate_in"] > 0


# ---------------------------------------------------- LSM micro-invariants
def reference_clock_update(store, keys, vals):
    """The seed's sequential CLOCK insert — the oracle the vectorized
    ``_cache_update`` must match bit-for-bit."""
    if len(keys) == 0:
        return
    uniq, idx = np.unique(keys[::-1], return_index=True)
    keys, vals = uniq, vals[::-1][idx]
    sets = store._sets(keys)
    match = store.cache_keys[sets] == keys[:, None]
    hit = match.any(axis=1)
    way = match.argmax(axis=1)
    store.cache_vals[sets[hit], way[hit]] = vals[hit]
    store.cache_ref[sets[hit], way[hit]] = 1
    for s, k, v in zip(sets[~hit], keys[~hit], vals[~hit]):
        hand = store.cache_hand[s]
        for _ in range(2 * store.cache_ways):
            if store.cache_ref[s, hand] == 0:
                break
            store.cache_ref[s, hand] = 0
            hand = (hand + 1) % store.cache_ways
        store.cache_keys[s, hand] = k
        store.cache_vals[s, hand] = v
        store.cache_ref[s, hand] = 1
        store.cache_hand[s] = (hand + 1) % store.cache_ways


def test_vectorized_clock_matches_sequential_reference(rng):
    a = LSMStore(2.0, value_words=2)
    b = LSMStore(2.0, value_words=2)
    for step in range(60):
        n = int(rng.integers(1, 8_000))
        keys = rng.integers(0, 30_000, n).astype(np.int64)
        vals = rng.integers(0, 1 << 30, (n, 2)).astype(np.int32)
        reference_clock_update(a, keys.copy(), vals.copy())
        b._cache_update(keys, vals)
        for attr in ("cache_keys", "cache_vals", "cache_ref", "cache_hand"):
            np.testing.assert_array_equal(getattr(a, attr),
                                          getattr(b, attr), err_msg=str(step))


def test_memtable_view_matches_dict_oracle(rng):
    """Interleaved put/get: reads must return the newest write per key."""
    s = LSMStore(4.0, value_words=2)
    oracle = {}
    for _ in range(40):
        n = int(rng.integers(1, 3_000))
        keys = rng.integers(0, 5_000, n).astype(np.int64)
        vals = rng.integers(0, 1 << 30, (n, 2)).astype(np.int32)
        s.put_batch(keys, vals)
        for k, v in zip(keys.tolist(), vals.tolist()):
            oracle[k] = v
        probe = rng.integers(0, 6_000, 500).astype(np.int64)
        got, found = s.get_batch(probe)
        for i, k in enumerate(probe.tolist()):
            if k in oracle:
                assert found[i], k
                assert got[i].tolist() == oracle[k], k
            else:
                assert not found[i], k


def test_bulk_load_equals_put_batch_content(rng):
    keys = rng.choice(100_000, 20_000, replace=False).astype(np.int64)
    vals = rng.integers(0, 1 << 30, (20_000, 2)).astype(np.int32)
    a = LSMStore(8.0, value_words=2)
    a.put_batch(keys, vals)
    b = LSMStore(8.0, value_words=2)
    b.bulk_load(keys, vals)
    ka, va = a.items()
    kb, vb = b.items()
    np.testing.assert_array_equal(ka, kb)
    np.testing.assert_array_equal(va, vb)


def test_duplicate_probes_counted_as_cache_hits():
    """In one coalesced call, later occurrences of a slow-tier-fetched key
    hit the admitted block (what chunked execution observed across chunks)."""
    s = LSMStore(8.0, value_words=2)
    keys = np.arange(1_000, dtype=np.int64)
    vals = np.ones((1_000, 2), np.int32)
    s.bulk_load(keys, vals)                   # slow tier only, cold cache
    probe = np.repeat(np.arange(100, dtype=np.int64), 3)   # 3 occurrences
    got, found = s.get_batch(probe)
    assert found.all()
    np.testing.assert_array_equal(got, np.ones((300, 2), np.int32))
    m = s.metrics
    assert m.cache_hits == 200                # the duplicate occurrences
    assert m.level_probes >= 100              # one real probe per unique key
    assert m.reads == 300


def test_items_resolves_memtable_duplicates_to_newest_write():
    """Regression (PR 4): keys written twice within one memtable must
    snapshot at their NEWEST value — exactly what a read returns.  The
    seed resolved to the oldest write, so mid-memtable snapshots (state
    re-partitioning) carried stale values for hot keys."""
    s = LSMStore(8.0, value_words=2)
    s.put_batch(np.array([5, 5, 7], np.int64),
                np.array([[1, 0], [2, 0], [3, 0]], np.int32))
    ik, iv = s.items()
    got, found = s.get_batch(ik)
    assert found.all()
    np.testing.assert_array_equal(got, iv)     # snapshot == read view
    assert iv[list(ik).index(5), 0] == 2
    # and the snapshot stays frozen across later writes
    s.put_batch(np.array([5], np.int64), np.array([[9, 0]], np.int32))
    assert iv[list(ik).index(5), 0] == 2


def test_items_memtable_still_wins_over_levels():
    s = LSMStore(0.5, value_words=2)           # tiny: force flushes
    s.put_batch(np.arange(2_000, dtype=np.int64),
                np.ones((2_000, 2), np.int32))   # spills to levels
    s.put_batch(np.array([17, 17], np.int64),
                np.array([[5, 0], [6, 0]], np.int32))     # memtable rewrite
    ik, iv = s.items()
    assert iv[list(ik).index(17), 0] == 6
    assert len(ik) == 2_000
