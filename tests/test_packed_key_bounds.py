"""Packed-key headroom and aliasing regression tests (PR 9).

The columnar store packs (source_rank << 45) + key into one int64 so a
single searchsorted covers every memtable run, and the prewarm path
packs (set << 47) | key.  These tests pin the bit-width boundaries that
B601 (the reprolint bit-width pass) now proves statically, and the
aliasing fixes that A701 (the escape pass) surfaced:

* a query key outside [0, 2^45) must NOT false-hit another source's
  band (failing before the ``fast`` range guard in ``get_batch``);
* ``items()`` / ``snapshot()`` must hand out copies, not live views,
  even when exactly one source is live (failing before the
  single-source copy in ``_items_weighted``);
* the fast packed probe and the per-run fallback must agree key for
  key, including at the 2^45 - 1 boundary.
"""
import numpy as np
import pytest

from repro.state.lsm import LSMStore, make_store

LIM45 = np.int64(1) << np.int64(45)


def _vals(keys, words=4):
    """Deterministic per-key payloads: val[i] = key * 10 + column."""
    k = np.asarray(keys, np.int64)
    return (k[:, None] * 10 + np.arange(words)).astype(np.int32)


def _store(**kw):
    return make_store(64, **kw)


# ------------------------------------------------- band-collision regression
def test_out_of_band_query_key_does_not_false_hit():
    # Two delta runs: querying key 2^45 + 7 used to pack (for source
    # rank 0) onto the SAME int64 as source rank 1's stored key 7 and
    # report a hit with another key's payload.
    st = _store()
    st.put_batch(np.array([5, 7]), _vals([5, 7]))
    st.put_batch(np.array([7, 9]), _vals([7, 9]))
    ghost = int(LIM45) + 7
    vals, found = st.get_batch(np.array([ghost], np.int64))
    assert not found[0]
    assert (vals[0] == 0).all()


def test_negative_query_key_forces_fallback_without_corrupting_batch():
    # A negative key would land below every band after packing; the
    # range guard must push the whole batch to the per-run fallback and
    # leave the in-band answers untouched.  (found[-1] itself is not
    # asserted: the block cache's empty-slot sentinel is -1, a
    # pre-existing keys>=0 domain assumption outside this regression.)
    st = _store()
    st.put_batch(np.array([5, 7]), _vals([5, 7]))
    st.put_batch(np.array([7, 9]), _vals([7, 9]))
    vals, found = st.get_batch(np.array([5, 9, -1], np.int64))
    assert found[0] and found[1]
    np.testing.assert_array_equal(vals[0], _vals([5])[0])
    np.testing.assert_array_equal(vals[1], _vals([9])[0])


def test_mixed_batch_with_out_of_band_key_matches_in_band_answers():
    # One out-of-band key forces the whole batch onto the per-run
    # fallback; the in-band keys must resolve exactly as the fast path
    # resolves them on their own.
    st = _store()
    rng = np.random.default_rng(9)
    for _ in range(3):
        keys = np.sort(rng.integers(0, 1000, 32))
        st.put_batch(keys, _vals(keys))
    probe = np.array([1, 17, 500, 999], np.int64)
    fast_vals, fast_found = st.get_batch(probe)
    slow_vals, slow_found = st.get_batch(
        np.concatenate([probe, [int(LIM45) + 1]]))
    np.testing.assert_array_equal(fast_found, slow_found[:-1])
    np.testing.assert_array_equal(fast_vals, slow_vals[:-1])
    assert not slow_found[-1]


def test_stored_key_at_45_bit_boundary_still_resolves():
    # Keys >= 2^45 make _mem_concat bail; the store must still serve
    # them through the per-run fallback with the right payload.
    st = _store()
    big = int(LIM45) + 7
    keys = np.array([3, big], np.int64)
    st.put_batch(keys, _vals(keys))
    vals, found = st.get_batch(np.array([big, 3, big + 1], np.int64))
    assert found[0] and found[1] and not found[2]
    np.testing.assert_array_equal(vals[0], _vals([big])[0])
    np.testing.assert_array_equal(vals[1], _vals([3])[0])


def test_fast_path_serves_key_at_band_edge():
    # 2^45 - 1 is the largest key the packed probe may handle.
    st = _store()
    edge = int(LIM45) - 1
    keys = np.array([0, edge], np.int64)
    st.put_batch(keys, _vals(keys))
    st.put_batch(np.array([1], np.int64), _vals([1]))
    vals, found = st.get_batch(np.array([edge, 0, 1], np.int64))
    assert found.all()
    np.testing.assert_array_equal(vals[0], _vals([edge])[0])


def test_fast_and_fallback_paths_agree_key_for_key():
    # Same writes into a numpy-kernel store (fast packed probe) and a
    # pallas-kernel store (always per-run fallback): reads must agree.
    a, b = _store(kernel_impl="numpy"), _store(kernel_impl="pallas")
    rng = np.random.default_rng(4)
    for _ in range(5):
        keys = rng.integers(0, 200, 48)
        vals = _vals(keys)
        a.put_batch(keys, vals)
        b.put_batch(keys, vals)
    probe = rng.integers(-5, 260, 64)
    va, fa = a.get_batch(probe)
    vb, fb = b.get_batch(probe)
    np.testing.assert_array_equal(fa, fb)
    np.testing.assert_array_equal(va, vb)


# ---------------------------------------------------- aliasing regressions
def test_items_returns_copies_even_with_single_live_source():
    # With exactly one live source _items_weighted used to return the
    # run arrays themselves; a caller mutating them corrupted the store.
    st = _store()
    keys = np.array([2, 4, 6], np.int64)
    st.put_batch(keys, _vals(keys))
    k, v = st.items()
    k2, v2 = k.copy(), v.copy()
    k[:] = -1
    v[:] = -999
    # a second derivation must see the store's own intact arrays
    k3, v3 = st.items()
    np.testing.assert_array_equal(k3, k2)
    np.testing.assert_array_equal(v3, v2)
    vals, found = st.get_batch(keys)
    assert found.all()
    np.testing.assert_array_equal(vals, _vals(keys))


def test_snapshot_arrays_are_not_live_views():
    st = _store()
    keys = np.array([11, 13], np.int64)
    st.put_batch(keys, _vals(keys))
    snap = st.snapshot()
    ref = {f: snap[f].copy() for f in ("keys", "vals", "weights")}
    snap["keys"][:] = 0
    snap["vals"][:] = 0
    snap["weights"][:] = 0
    again = st.snapshot()
    for f in ("keys", "vals", "weights"):
        np.testing.assert_array_equal(again[f], ref[f])
    vals, found = st.get_batch(keys)
    assert found.all()
    np.testing.assert_array_equal(vals, _vals(keys))


def test_snapshot_restore_round_trip_after_mutation():
    # The snapshot taken BEFORE extra writes must restore the old state.
    st = _store()
    keys = np.array([1, 2, 3], np.int64)
    st.put_batch(keys, _vals(keys))
    snap = st.snapshot()
    st.put_batch(keys, _vals(keys + 100))   # overwrite payloads in place?
    re = LSMStore.restore(snap)
    vals, found = re.get_batch(keys)
    assert found.all()
    np.testing.assert_array_equal(vals, _vals(keys))


# ----------------------------------------------------- prewarm 47-bit pack
def test_prewarm_fused_sort_matches_fallback_at_47_bit_edge():
    # prewarm's fused (set << 47) | key sort only fires for keys below
    # 2^47; a batch straddling the limit takes the dedup fallback.  Both
    # must leave the cache answering identically for the warmed keys.
    lim47 = np.int64(1) << np.int64(47)
    lo = np.arange(64, dtype=np.int64) * 3 + 1
    vals = _vals(lo)

    fused = _store()
    fused.prewarm_cache(lo, vals)
    fallback = _store()
    big_keys = np.concatenate([lo[:-1], [int(lim47) + 5]])
    fallback.prewarm_cache(big_keys, _vals(big_keys))

    fh = fused.cache_keys.copy()
    assert (fh != -1).any()              # fused path actually warmed sets
    # warmed entries must serve hits without touching the (empty) levels
    for st, keys in ((fused, lo), (fallback, lo[:-1])):
        st.put_batch(keys, _vals(keys))  # make keys live so probes resolve
        _, found = st.get_batch(keys)
        assert found.all()


def test_prewarm_respects_45_bit_store_guard():
    # Keys above 2^45 still prewarm (the cache packs at 47 bits), and
    # subsequent reads resolve through the fallback memtable probe.
    st = _store()
    big = int(LIM45) + 123
    keys = np.array([big, big + 2], np.int64)
    st.put_batch(keys, _vals(keys))
    st.prewarm_cache(keys, _vals(keys))
    vals, found = st.get_batch(keys)
    assert found.all()
    np.testing.assert_array_equal(vals, _vals(keys))


# ------------------------------------------------------- headroom asserts
def test_memtable_source_count_headroom_assert_is_lenient_in_range():
    # MEMTABLE_RUNS consolidation keeps run counts tiny; the 2^18 source
    # assert must never fire under sustained writes.
    st = _store()
    rng = np.random.default_rng(7)
    for _ in range(40):
        keys = rng.integers(0, 5000, 64)
        st.put_batch(keys, _vals(keys))
    probe = rng.integers(0, 5000, 128)
    _vals_out, _found = st.get_batch(probe)   # must not raise


def test_uint16_partition_cast_is_lossless_at_boundary():
    # engine's radix trick: argsort(part.astype(uint16)) must equal
    # argsort(part) whenever p <= 2^16 — pin the extreme p.
    p = 1 << 16
    rng = np.random.default_rng(3)
    part = rng.integers(0, p, 4096)
    a = np.argsort(part.astype(np.uint16), kind="stable")
    b = np.argsort(part, kind="stable")
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("shift", [45, 47])
def test_packed_word_round_trips_at_field_edges(shift):
    # algebraic pin of the packing identity at max field values
    s = np.int64(shift)
    hi = np.int64((1 << (63 - shift)) - 1)
    lo = np.int64((1 << shift) - 1)
    packed = (hi << s) | lo
    assert packed > 0                     # no sign-bit overflow
    assert packed >> s == hi
    assert packed & ((np.int64(1) << s) - 1) == lo
