"""Bin-packing placement: private fleets, the cluster-level shared packer
(per-tenant attribution, amortized base_mb), and repack/migration cost."""
import pytest

from repro.core.placement import (MigrationCost, TaskManager, TaskRequest,
                                  TMSpec, bin_pack, default_tm_spec,
                                  placement_for_config, placement_requests,
                                  repack, shared_pack)
from repro.core.units import MB_EPS, mem_close, mem_exceeds, mem_fits


def reqs(n: int, mb: float, op: str = "op") -> list[TaskRequest]:
    return [TaskRequest(op, i, mb) for i in range(n)]


def test_bin_pack_private_unchanged():
    spec = TMSpec(slots=4, managed_pool_mb=4 * 158.0, base_mb=1416.0)
    pl = bin_pack(reqs(6, 158.0), spec)
    assert pl.n_tms == 2 and pl.cpu_cores == 6
    assert pl.memory_mb == pytest.approx(2 * 1416.0 + 6 * 158.0)


def test_placement_requests_matches_placement_for_config():
    config = {"a": (2, 1), "b": (3, None), "src": (1, 0)}
    rs = placement_requests(config, base_mem_mb=158.0, exclude={"src"})
    assert len(rs) == 5
    assert {r.op for r in rs} == {"a", "b"}
    assert sorted(r.memory_mb for r in rs) == [0, 0, 0, 316.0, 316.0]
    pl = placement_for_config(config, base_mem_mb=158.0, exclude={"src"})
    pl2 = bin_pack(rs, default_tm_spec(158.0))
    assert (pl.cpu_cores, pl.memory_mb) == (pl2.cpu_cores, pl2.memory_mb)


def test_shared_pack_amortizes_base_mb():
    """Two 2-task tenants co-reside on ONE TM: total memory strictly below
    the sum of private placements, and each tenant's attribution carries
    half the base_mb (slot-proportional)."""
    spec = TMSpec(slots=4, managed_pool_mb=4 * 158.0, base_mb=1416.0)
    shared = shared_pack({"a": reqs(2, 158.0), "b": reqs(2, 158.0)}, spec)
    assert shared.n_tms == 1
    private = bin_pack(reqs(2, 158.0), spec).memory_mb \
        + bin_pack(reqs(2, 158.0), spec).memory_mb
    assert shared.memory_mb < private
    assert shared.tenants == ["a", "b"]
    assert shared.tenant_cpu("a") == 2
    assert shared.tenant_memory_mb("a") \
        == pytest.approx(2 * 158.0 + 1416.0 / 2)
    # attributions sum exactly to the fleet totals
    att = shared.attribution()
    assert sum(c for c, _ in att.values()) == shared.cpu_cores
    assert sum(m for _, m in att.values()) \
        == pytest.approx(shared.memory_mb)


def test_shared_pack_deterministic():
    spec = TMSpec(slots=2, managed_pool_mb=1000.0, base_mb=100.0)
    by_tenant = {"a": reqs(3, 300.0), "b": reqs(2, 300.0)}
    a1 = shared_pack(by_tenant, spec).assignment()
    a2 = shared_pack(by_tenant, spec).assignment()
    assert a1 == a2 and len(a1) == 5


def test_repack_counts_moved_tasks_and_state():
    spec = TMSpec(slots=2, managed_pool_mb=1000.0, base_mb=100.0)
    first = shared_pack(
        {"a": [TaskRequest("x", 0, 500.0), TaskRequest("x", 1, 400.0)]},
        spec)
    assert first.n_tms == 1
    # b's 450 MB task displaces a's 400 MB task to a second TM under FFD
    new, cost = repack(
        {"a": [TaskRequest("x", 0, 500.0), TaskRequest("x", 1, 400.0)],
         "b": [TaskRequest("y", 0, 450.0)]},
        spec, previous=first)
    assert new.n_tms == 2
    assert cost == MigrationCost(tasks_moved=1, state_mb=400.0)
    # identical requests: repacking is free
    same, cost2 = repack({"a": [TaskRequest("x", 0, 500.0),
                                TaskRequest("x", 1, 400.0)]},
                         spec, previous=first)
    assert cost2 == MigrationCost()
    # costs accumulate
    assert (cost + cost2) == cost


def test_tenant_isolation_in_attribution():
    """A tenant alone on its TMs pays its full base_mb — sharing only
    helps when tasks actually co-reside."""
    spec = TMSpec(slots=2, managed_pool_mb=1000.0, base_mb=100.0)
    shared = shared_pack({"a": reqs(2, 500.0), "b": reqs(2, 500.0)}, spec)
    assert shared.n_tms == 2                    # pool bound: no co-residency
    assert shared.tenant_memory_mb("a") == pytest.approx(1000.0 + 100.0)


def test_ffd_packing_is_non_monotone():
    """Characterization: first-fit-decreasing can pack a strictly SMALLER
    task list into a larger fleet.  This is why the preemption arbiter
    quotes a give-back before enacting it and why a denied resync of a
    shrunken footprint keeps the previous reservation standing instead of
    raising (see scenarios/cluster.py)."""
    spec = TMSpec(slots=4, managed_pool_mb=1000.0, base_mb=500.0)
    sizes = [96.0, 241.0, 129.0, 20.0, 207.0, 764.0, 504.0]
    big = bin_pack([TaskRequest("x", i, s) for i, s in enumerate(sizes)],
                   spec)
    sizes[1] = 233.0                              # one task SHRINKS
    small = bin_pack([TaskRequest("x", i, s) for i, s in enumerate(sizes)],
                     spec)
    assert small.n_tms > big.n_tms                # 3 TMs vs 2
    assert small.memory_mb > big.memory_mb        # 3453 vs 2961


def test_fits_tolerates_summation_drift():
    """0.1 + 0.1 + 0.1 > 0.3 in binary: an epsilon-free budget test
    denies a task that exactly fills the pool (the Cluster.fits
    phantom-denial class, PR 6).  TaskManager.fits routes through the
    blessed repro.core.units.mem_fits and must admit it."""
    spec = TMSpec(slots=4, managed_pool_mb=0.3)
    tm = TaskManager(spec)
    for i in range(2):
        tm.tasks.append(TaskRequest("op", i, 0.1))
    assert tm.used_mem + 0.1 > spec.managed_pool_mb     # the raw drift
    assert tm.fits(TaskRequest("op", 2, 0.1))           # ...is forgiven


def test_units_helpers_agree_on_drift():
    """The three blessed comparisons share ONE tolerance, so admission,
    growth gating and audit reconciliation can never disagree."""
    drifted = 0.1 + 0.1 + 0.1
    assert drifted != 0.3                               # binary float fact
    assert mem_fits(drifted, 0.3)
    assert not mem_exceeds(drifted, 0.3)
    assert mem_close(drifted, 0.3)
    assert mem_exceeds(0.3 + 2 * MB_EPS, 0.3)           # real growth still
    assert not mem_fits(0.3 + 2 * MB_EPS, 0.3)          # detected
