"""Streaming engine: operators, backpressure, reconfiguration, fault
tolerance, straggler mitigation, DS2 model."""
import numpy as np
import pytest

from repro.core.ds2 import ds2_parallelism, should_trigger
from repro.core.placement import TMSpec, bin_pack, TaskRequest, \
    placement_for_config
from repro.data.nexmark import QUERIES, BidGen
from repro.streaming.engine import StreamEngine, level_mb
from repro.streaming.events import EventBatch, hash_partition
from repro.streaming.graph import Dataflow
from repro.streaming.operators import (FilterOp, KeyedStateOp, MapOp, SinkOp,
                                       SourceOp)


def simple_flow(op=None, p=1):
    f = Dataflow("t")
    mid = op or MapOp("mid", lambda b: b)
    f.chain(SourceOp("source", BidGen(seed=1)), mid, SinkOp("sink"))
    f.nodes[mid.name].parallelism = p
    return f


def test_events_flow_to_sink():
    f = simple_flow()
    eng = StreamEngine(f, seed=0)
    eng.run(5, 10_000)
    m = eng.collect()
    assert m["sink"]["rate_in"] > 9_000


def test_filter_selectivity():
    f = Dataflow("t")
    f.chain(SourceOp("source", BidGen(seed=1)),
            FilterOp("f", lambda b: b.key % 2 == 0),
            SinkOp("sink"))
    eng = StreamEngine(f, seed=0)
    eng.run(5, 10_000)
    m = eng.collect()
    assert 0.4 < m["f"]["selectivity"] < 0.6


def test_backpressure_throttles_source():
    op = KeyedStateOp("slow", "update", keyspace=500_000, prepopulate=False)
    f = simple_flow(op)
    eng = StreamEngine(f, seed=0, queue_cap_events=20_000)
    eng.run(10, 500_000)                        # far beyond capacity
    m = eng.collect()
    assert m["source"]["rate_out"] < 500_000    # throttled
    assert m["slow"]["busyness"] > 0.9


def test_hash_partition_deterministic_and_balanced(rng):
    keys = rng.integers(0, 1 << 40, 100_000).astype(np.int64)
    p1 = hash_partition(keys, 8)
    p2 = hash_partition(keys, 8)
    np.testing.assert_array_equal(p1, p2)
    counts = np.bincount(p1, minlength=8)
    assert counts.min() > 0.8 * counts.max()


def test_reconfigure_preserves_state_semantics():
    """Scale-out re-partitions state: counts must continue, not reset."""
    op = KeyedStateOp("agg", "update", keyspace=1_000, prepopulate=False)
    f = simple_flow(op)
    f.nodes["source"].op.users = 1_000          # narrow keyspace
    eng = StreamEngine(f, seed=0)
    eng.run(5, 5_000)
    items_before = sum(len(t.state.items()[0]) for t in eng.tasks["agg"])
    eng.reconfigure({"agg": (4, 1)})
    items_after = sum(len(t.state.items()[0]) for t in eng.tasks["agg"])
    assert items_after == items_before
    assert len(eng.tasks["agg"]) == 4
    eng.run(5, 5_000)                            # keeps processing
    assert eng.collect()["sink"]["rate_in"] > 0


def test_snapshot_restore_roundtrip():
    op = KeyedStateOp("agg", "update", keyspace=1_000, prepopulate=False)
    f = simple_flow(op)
    eng = StreamEngine(f, seed=0)
    eng.run(5, 5_000)
    snap = eng.snapshot()
    k0, v0 = eng.tasks["agg"][0].state.items()
    eng.run(5, 5_000)                            # diverge
    eng.restore(snap)
    k1, v1 = eng.tasks["agg"][0].state.items()
    np.testing.assert_array_equal(k0, k1)
    np.testing.assert_array_equal(v0, v1)
    assert eng.now == snap["now"]


def test_kill_task_then_restore_recovers():
    op = KeyedStateOp("agg", "update", keyspace=1_000, prepopulate=False)
    f = simple_flow(op, p=2)
    eng = StreamEngine(f, seed=0)
    eng.run(5, 5_000)
    snap = eng.snapshot()
    eng.kill_task("agg", 0)                      # node failure
    assert len(eng.tasks["agg"][0].state.items()[0]) == 0
    eng.restore(snap)
    total = sum(len(t.state.items()[0]) for t in eng.tasks["agg"])
    assert total > 0


def test_straggler_mitigation_rebalances():
    f = simple_flow(MapOp("m", lambda b: b), p=4)
    eng = StreamEngine(f, seed=0, queue_cap_events=10**9)
    eng.set_straggler("m", 0, 50.0)              # 50x slowdown
    eng.run(10, 200_000)
    loads = [t.queued_events for t in eng.tasks["m"]]
    # straggler's queue must not dominate: stolen work went to peers
    assert max(loads) < 8 * (np.median(loads) + 2048)


def test_ds2_scales_toward_target():
    op = KeyedStateOp("agg", "update", keyspace=2_000, prepopulate=False)
    f = simple_flow(op)
    eng = StreamEngine(f, seed=0)
    eng.run(12, 100_000)
    metrics = eng.collect()
    assert should_trigger(f, metrics, 100_000)
    newp = ds2_parallelism(f, metrics, 100_000)
    assert newp["agg"] > 1


def test_bin_packing_spawns_tms():
    reqs = [TaskRequest("op", i, 158.0) for i in range(9)]
    pl = bin_pack(reqs, TMSpec(slots=4, managed_pool_mb=4 * 158))
    assert pl.n_tms == 3                          # ceil(9/4)
    assert pl.cpu_cores == 9


def test_bin_packing_memory_constraint():
    """A 632 MB task uses a whole lot of a TM's pool: packing respects it."""
    reqs = [TaskRequest("op", i, 632.0) for i in range(4)]
    pl = bin_pack(reqs, TMSpec(slots=4, managed_pool_mb=4 * 158 * 4))
    assert pl.n_tms == 1                          # 4x632 = pool exactly
    reqs = [TaskRequest("op", i, 632.0) for i in range(5)]
    pl = bin_pack(reqs, TMSpec(slots=4, managed_pool_mb=4 * 158 * 4))
    assert pl.n_tms == 2


def test_level_mb():
    assert level_mb(None) == 0.0
    assert level_mb(0) == 158.0
    assert level_mb(2) == 632.0


@pytest.mark.parametrize("qname", list(QUERIES))
def test_nexmark_queries_run(qname):
    flow = QUERIES[qname]()
    eng = StreamEngine(flow, seed=0, warm=qname in ("q1", "q2", "q3"))
    eng.run(3, 20_000)
    m = eng.collect()
    assert m["source"]["rate_out"] > 0


def test_rebalance_chunk_assignment_stable_under_ties():
    """The round-robin rebalance ranks downstream tasks by queue depth;
    at >=17 tasks, quicksort's tie order diverges from index order, so
    which tied task receives the larger chunk would depend on sort
    internals.  kind="stable" pins it: among ties, lower task index
    drains first."""
    f = simple_flow(p=20)
    eng = StreamEngine(f, seed=0)
    tasks = eng.tasks["mid"]
    for i, t in enumerate(tasks):        # interleaved ties: 0,1,0,1,...
        t.queued_events = i % 2
    n = 25                               # q=1, r=5: five chunks of 2
    batch = EventBatch(np.arange(n, dtype=np.int64),
                       np.zeros((n, 4), np.int32),
                       np.zeros(n), np.zeros(n, np.int8))
    before = [t.queued_events for t in tasks]
    eng._emit("source", batch)
    deltas = [t.queued_events - b for t, b in zip(tasks, before)]
    # stable order visits the tied-at-0 tasks 0,2,4,...,18 first, so the
    # five remainder-carrying chunks land on tasks 0,2,4,6,8 — never on
    # a quicksort-chosen subset
    assert deltas == [2, 1] * 5 + [1] * 10
