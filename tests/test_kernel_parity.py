"""Edge-shape parity: sorted_probe / window_agg pallas kernels vs their
numpy/jnp references, in interpret mode (no accelerator needed), plus the
columnar LSM store's kernel dispatch (``kernel_impl="pallas"``) vs its
numpy oracle path.

The shape sweep here deliberately covers what tests/test_kernels.py's
random sweeps don't pin: empty inputs, single-key tables, all-duplicate
batches, and dtype-boundary keys (0, int_max — the kernel pads tables
with int_max, which used to false-positive a genuine int_max probe).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.sorted_probe.ops import probe
from repro.kernels.window_agg.ops import aggregate
from repro.state.lsm import LSMStore


def assert_probe_parity(table, queries):
    p1, f1 = probe(jnp.asarray(table), jnp.asarray(queries))
    p2, f2 = probe(jnp.asarray(table), jnp.asarray(queries), impl="ref")
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    return np.asarray(p1), np.asarray(f1)


# ------------------------------------------------------------- sorted_probe
def test_probe_empty_table():
    pos, found = assert_probe_parity(np.empty(0, np.int64),
                                     np.array([1, 2, 3], np.int64))
    assert not found.any()
    assert (pos == 0).all()


def test_probe_empty_queries():
    pos, found = assert_probe_parity(np.array([1, 2, 3], np.int64),
                                     np.empty(0, np.int64))
    assert len(pos) == 0 and len(found) == 0


def test_probe_single_key_table():
    pos, found = assert_probe_parity(np.array([42], np.int64),
                                     np.array([41, 42, 43], np.int64))
    np.testing.assert_array_equal(found, [False, True, False])
    np.testing.assert_array_equal(pos, [0, 0, 1])


def test_probe_all_duplicate_queries():
    table = np.arange(0, 1000, 7, dtype=np.int64)
    queries = np.full(2048, 700, np.int64)          # all one present key
    pos, found = assert_probe_parity(table, queries)
    assert found.all()
    assert (table[pos] == 700).all()


def test_probe_duplicate_table_entries():
    """Sorted but NOT unique table: rank = leftmost insertion point."""
    table = np.array([5, 5, 5, 9, 9], np.int64)
    pos, found = assert_probe_parity(table, np.array([5, 7, 9], np.int64))
    np.testing.assert_array_equal(pos, [0, 3, 3])
    np.testing.assert_array_equal(found, [True, False, True])


@pytest.mark.parametrize("dtype", [np.int32, np.int64])
def test_probe_dtype_boundaries(dtype):
    """0 and int_max as real keys AND as absent probes — the kernel pads
    its table tiles with int_max, which must not read as a match.  int64
    needs x64 enabled or jax silently truncates the arrays to int32."""
    from jax.experimental import enable_x64
    hi = np.iinfo(dtype).max
    with enable_x64():
        table = np.array([0, 17, hi], dtype)
        pos, found = assert_probe_parity(table, np.array([0, 1, hi, hi - 1],
                                                         dtype))
        np.testing.assert_array_equal(found, [True, False, True, False])
        table_no_hi = np.array([0, 17], dtype)
        _, found = assert_probe_parity(table_no_hi, np.array([hi], dtype))
        assert not found.any()                  # padding must NOT match


def test_probe_exact_tile_multiple():
    """Table/query sizes exactly at the kernel tile sizes (no padding)."""
    table = np.arange(2048, dtype=np.int64) * 3
    queries = np.arange(512, dtype=np.int64) * 3 + 1   # all absent
    _, found = assert_probe_parity(table, queries)
    assert not found.any()


# -------------------------------------------------------------- window_agg
def assert_agg_parity(seg, vals, n_segments):
    s1, c1 = aggregate(jnp.asarray(seg), jnp.asarray(vals), n_segments)
    s2, c2 = aggregate(jnp.asarray(seg), jnp.asarray(vals), n_segments,
                       impl="ref")
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-3)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    return np.asarray(s1), np.asarray(c1)


def test_agg_empty_events():
    sums, counts = assert_agg_parity(np.empty(0, np.int32),
                                     np.empty((0, 3), np.float32), 16)
    assert sums.shape == (16, 3) and (sums == 0).all()
    assert (counts == 0).all()


def test_agg_zero_segments():
    sums, counts = assert_agg_parity(np.empty(0, np.int32),
                                     np.empty((0, 2), np.float32), 0)
    assert sums.shape == (0, 2) and counts.shape == (0,)


def test_agg_single_segment_all_duplicates():
    seg = np.zeros(1500, np.int32)
    vals = np.ones((1500, 1), np.float32)
    sums, counts = assert_agg_parity(seg, vals, 1)
    assert sums[0, 0] == 1500.0 and counts[0] == 1500.0


def test_agg_segment_count_off_tile():
    """n_segments just past a SEG_BLOCK boundary; events off EVENT_TILE."""
    rng = np.random.default_rng(5)
    seg = rng.integers(0, 513, 1025).astype(np.int32)
    vals = rng.normal(size=(1025, 2)).astype(np.float32)
    assert_agg_parity(seg, vals, 513)


# ------------------------------------------- LSM store dispatch: pallas path
def test_store_pallas_impl_matches_numpy_oracle():
    """The columnar store's get/put/flush behavior must not depend on which
    kernel backend serves its probes and weight sums."""
    rng = np.random.default_rng(11)
    a = LSMStore(0.5, value_words=2, kernel_impl="numpy")
    b = LSMStore(0.5, value_words=2, kernel_impl="pallas")
    for step in range(6):
        n = int(rng.integers(1, 800))
        keys = rng.integers(0, 2_000, n).astype(np.int64)
        vals = rng.integers(0, 1 << 30, (n, 2)).astype(np.int32)
        a.put_batch(keys, vals)
        b.put_batch(keys, vals)
        q = rng.integers(0, 2_500, 300).astype(np.int64)
        ga, fa = a.get_batch(q)
        gb, fb = b.get_batch(q)
        np.testing.assert_array_equal(fa, fb, err_msg=str(step))
        np.testing.assert_array_equal(ga, gb, err_msg=str(step))
        assert a.metrics.snapshot() == b.metrics.snapshot(), step
    ka, va = a.items()
    kb, vb = b.items()
    np.testing.assert_array_equal(ka, kb)
    np.testing.assert_array_equal(va, vb)
