"""JustinServe: elastic LLM serving with hybrid replica/memory scaling.

Continuous-batching inference *is* stream processing (DESIGN.md §4):
requests are events, prefill/decode are the stateful operators, the paged
KV cache is the state backend.  The controller drives a registry
:class:`~repro.core.policy.ScalingPolicy` (``ds2``, ``justin``,
``threshold``, or anything ``@register_policy``-ed) over a one-operator
dataflow view of the fleet (:class:`_ServeFlow`) — the same pluggable
surface the streaming controller uses.  Under ``justin``, the unmodified
Algorithm 1 arbitrates:

  * scale OUT  — add decode replicas (more data-parallel mesh slices),
  * scale UP   — double a replica's HBM page budget (bigger prefix cache),

using θ = prefix-page hit rate, τ = average page-fetch latency, and
busyness = fraction of each wall-tick spent in model steps.

The data plane runs *real* prefill/decode on a reduced config (this host is
CPU-only); wall-clock per step comes from the calibrated cost model over the
really-executed work, mirroring the streaming engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.controller import ControllerConfig
from repro.core.justin import JustinParams
from repro.core.policy import make_policy
from repro.serve.kv_cache import PagedKVCache, PageSpec


@dataclass(frozen=True)
class ServeCosts:
    """Per-request service-time model (ms)."""
    prefill_ms_per_token: float = 0.02
    decode_ms_per_token: float = 0.4
    sched_ms: float = 0.05


@dataclass
class Request:
    rid: int
    prompt: np.ndarray          # token ids
    decode_tokens: int


@dataclass
class ReplicaStats:
    busy_ms: float = 0.0
    served_tokens: int = 0
    prefill_tokens: int = 0
    reused_tokens: int = 0


class ServingReplica:
    """One model replica: paged cache + service accounting."""

    def __init__(self, hbm_budget_bytes: int, costs: ServeCosts,
                 spec: PageSpec = PageSpec()):
        self.cache = PagedKVCache(hbm_budget_bytes, spec)
        self.costs = costs
        self.stats = ReplicaStats()

    def serve(self, req: Request) -> float:
        """Process one request; returns service time (ms)."""
        reused, fetch_ms = self.cache.lookup_prefix(req.prompt)
        prefill = len(req.prompt) - reused
        self.cache.insert_prefix(req.prompt)
        ms = (self.costs.sched_ms + fetch_ms
              + prefill * self.costs.prefill_ms_per_token
              + req.decode_tokens * self.costs.decode_ms_per_token)
        self.stats.busy_ms += ms
        self.stats.prefill_tokens += prefill
        self.stats.reused_tokens += reused
        self.stats.served_tokens += req.decode_tokens
        return ms


@dataclass(frozen=True)
class WorkloadSpec:
    """Shared-prefix request mix (system prompts + few-shot prefixes)."""
    n_prefixes: int = 64
    prefix_tokens: int = 2048       # long shared system/few-shot prefixes
    unique_tokens: int = 64
    decode_tokens: int = 16
    seed: int = 0


class RequestGen:
    def __init__(self, spec: WorkloadSpec):
        self.spec = spec
        self.rng = np.random.default_rng(spec.seed)
        self._prefixes = [
            self.rng.integers(0, 30_000, spec.prefix_tokens).astype(np.int32)
            for _ in range(spec.n_prefixes)]
        self._rid = 0

    def make(self, n: int) -> list[Request]:
        out = []
        for _ in range(n):
            pre = self._prefixes[int(self.rng.integers(len(self._prefixes)))]
            uniq = self.rng.integers(0, 30_000,
                                     self.spec.unique_tokens).astype(np.int32)
            out.append(Request(self._rid,
                               np.concatenate([pre, uniq]),
                               self.spec.decode_tokens))
            self._rid += 1
        return out


BASE_HBM_BUDGET = 512 * 2 * 1024 * 1024      # level 0: 512 pages (1 GB)


class _ServeFlow:
    """The :class:`~repro.core.policy.ScalingPolicy` protocol's dataflow
    view of the serving fleet: one source (the request stream) feeding one
    stateful operator (the replica pool).  Lets the generic registry
    policies — ds2's true-rate model, Justin's Algorithm 1, threshold's
    reactive doubling — drive serving without any serve-specific
    dispatch."""

    def __init__(self, controller: "JustinServeController"):
        self._ctl = controller

    def topo_order(self) -> list[str]:
        return ["requests", "serving"]

    def sources(self) -> list[str]:
        return ["requests"]

    def sinks(self) -> list[str]:
        return []

    def upstream(self, name: str) -> list[str]:
        return ["requests"] if name == "serving" else []

    def downstream(self, name: str) -> list[str]:
        return ["serving"] if name == "requests" else []

    def config(self) -> dict[str, tuple[int, int | None]]:
        return {"requests": (1, None),
                "serving": (len(self._ctl.replicas), self._ctl.level)}


class JustinServeController:
    """A registry :class:`ScalingPolicy` driving (replicas, page-budget
    level) — ``policy`` is any registered name (``ds2``, ``justin``,
    ``threshold``, ...), resolved through ``make_policy`` exactly like
    the streaming controller's; the old internal ds2/justin string
    switch is gone."""

    def __init__(self, target_rps: float, *, policy: str = "justin",
                 costs: ServeCosts = ServeCosts(),
                 workload: WorkloadSpec = WorkloadSpec(),
                 params: JustinParams = JustinParams(),
                 max_replicas: int = 64):
        self.target_rps = target_rps
        self.policy = policy
        self.costs = costs
        self.params = params
        self.max_replicas = max_replicas
        self.gen = RequestGen(workload)
        self.level = 0
        self.replicas = [self._new_replica()]
        # the serve-shaped ControllerConfig the registry policy runs under
        self._cfg = ControllerConfig(policy=policy, justin=params,
                                     max_parallelism=max_replicas)
        self._policy = make_policy(policy, self._cfg)
        self._flow = _ServeFlow(self)
        self.history: list[dict] = []
        self.steps = 0

    def _new_replica(self) -> ServingReplica:
        return ServingReplica(BASE_HBM_BUDGET * (2 ** self.level), self.costs)

    # ------------------------------------------------------------- metrics
    def _run_window(self, seconds: float = 10.0) -> dict:
        n_req = int(self.target_rps * seconds)
        reqs = self.gen.make(n_req)
        for r in self.replicas:
            r.stats = ReplicaStats()
            r.cache.metrics.reset()
        # round-robin dispatch (stateless load balancer)
        for i, req in enumerate(reqs):
            self.replicas[i % len(self.replicas)].serve(req)
        budget_ms = seconds * 1000.0
        busy = np.mean([r.stats.busy_ms / budget_ms for r in self.replicas])
        theta = float(np.mean([r.cache.metrics.hit_rate
                               for r in self.replicas]))
        tau = float(np.mean([r.cache.metrics.avg_fetch_ms
                             for r in self.replicas]))
        served = sum(r.stats.busy_ms <= budget_ms for r in self.replicas)
        capacity_rps = sum(
            min(1.0, budget_ms / max(r.stats.busy_ms, 1e-9))
            for r in self.replicas) * n_req / len(self.replicas) / seconds
        return {
            # the request stream, as the policy protocol's source operator
            "requests": {
                "stateful": False,
                "parallelism": 1,
                "memory_level": None,
                "busyness": 0.0,
                "busy_s": 0.0,
                "processed": n_req,
                "rate_in": n_req / seconds,
                "rate_out": n_req / seconds,
                "rate_processed": n_req / seconds,
                "selectivity": 1.0,
                "theta": None,
                "tau_ms": None,
                "backlog": 0,
                "blocked": False,
            },
            "serving": {
                "stateful": True,
                "parallelism": len(self.replicas),
                "memory_level": self.level,
                "busyness": min(float(busy), 1.0),
                "busy_s": sum(r.stats.busy_ms for r in self.replicas) / 1e3,
                "processed": n_req,
                "rate_in": n_req / seconds,
                "rate_out": min(capacity_rps, n_req / seconds),
                "rate_processed": n_req / seconds,
                "selectivity": 1.0,
                "theta": theta,
                "tau_ms": tau,
                "backlog": max(0.0, busy - 1.0),
                "blocked": busy > 1.0,
            }
        }

    # -------------------------------------------------------------- control
    def autoscale(self, max_rounds: int = 8) -> dict:
        for _ in range(max_rounds):
            metrics = self._run_window()
            m = metrics["serving"]
            over = m["busyness"] > 0.8
            self.history.append({"replicas": len(self.replicas),
                                 "level": self.level, **m})
            if not over:
                break
            # the registry policy owns the whole proposal surface: ds2's
            # true-rate model, Justin's Algorithm 1 over it (cancel-out +
            # HBM scale-up), threshold's doubling — no string dispatch.
            # Serving always enacts, so propose-and-commit in one go.
            proposal = self._policy.propose(self._flow, metrics,
                                            self.target_rps, self._cfg)
            self._policy.commit(metrics)
            p_new, lvl = proposal.config["serving"]
            if (p_new, lvl or 0) == (len(self.replicas), self.level):
                continue                    # proposal == current config
            self.steps += 1
            self.level = lvl or 0
            while len(self.replicas) < p_new:
                self.replicas.append(self._new_replica())
            del self.replicas[p_new:]
            for r in self.replicas:
                r.cache.resize(BASE_HBM_BUDGET * (2 ** self.level))
        last = self.history[-1]
        hbm_gb = (len(self.replicas) * BASE_HBM_BUDGET * (2 ** self.level)
                  / 2**30)
        return {"policy": self.policy, "steps": self.steps,
                "replicas": len(self.replicas), "level": self.level,
                "busyness": last["busyness"], "theta": last["theta"],
                "hbm_cache_gb": hbm_gb}
