"""Paged, two-tier KV cache with prefix reuse — the serving analogue of the
paper's RocksDB state backend (DESIGN.md §4).

* Pages (fixed token count) live in an HBM tier (fast, budgeted) or a host
  tier (slow).  The HBM budget is Justin's "managed memory": scale-up grows
  it by powers of two.
* A prefix index maps token-block hashes to pages (vLLM-style prefix
  caching).  The prefix *hit rate* is θ; the average *page-fetch latency*
  (host->HBM promotions on miss) is τ — exactly the metrics Algorithm 1
  consumes.
* Eviction HBM->host is CLOCK, like the LSM block cache.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class PageMetrics:
    lookups: int = 0
    prefix_hits: int = 0
    hbm_hits: int = 0
    host_fetches: int = 0
    evictions: int = 0
    fetch_latency_total_ms: float = 0.0

    @property
    def hit_rate(self) -> float:
        return self.hbm_hits / self.lookups if self.lookups else 1.0

    @property
    def avg_fetch_ms(self) -> float:
        return (self.fetch_latency_total_ms / self.lookups
                if self.lookups else 0.0)

    def reset(self) -> None:
        self.__init__()


@dataclass(frozen=True)
class PageSpec:
    page_tokens: int = 64
    page_bytes: int = 2 * 1024 * 1024     # kv bytes per page (model-dep.)
    host_fetch_ms: float = 0.5            # PCIe/DMA promotion cost
    hbm_hit_ms: float = 0.002


class PagedKVCache:
    """Control-plane page table (data plane stays dense inside serve_step)."""

    def __init__(self, hbm_budget_bytes: int, spec: PageSpec = PageSpec()):
        self.spec = spec
        self.metrics = PageMetrics()
        self.resize(hbm_budget_bytes)
        self.prefix_index: dict[int, int] = {}     # block hash -> page id
        self.page_tier: dict[int, str] = {}        # page id -> "hbm"|"host"
        self.page_ref: dict[int, int] = {}         # CLOCK reference bits
        self._next_page = 0
        self._clock: list[int] = []
        self._hand = 0

    def resize(self, hbm_budget_bytes: int) -> None:
        self.hbm_budget_bytes = int(hbm_budget_bytes)
        self.hbm_capacity = max(1, self.hbm_budget_bytes
                                // self.spec.page_bytes)

    @property
    def hbm_pages(self) -> int:
        return sum(1 for t in self.page_tier.values() if t == "hbm")

    # ------------------------------------------------------------------ ops
    @staticmethod
    def block_hash(tokens: np.ndarray, upto: int) -> int:
        return hash(tokens[:upto].tobytes())

    def lookup_prefix(self, tokens: np.ndarray) -> tuple[int, float]:
        """Longest cached prefix for a request: returns (tokens reused,
        latency charged).  Every page of the reused prefix is touched (the
        decode step reads all of its KV blocks); pages evicted to the host
        tier are promoted back and charged the fetch cost — that is the τ
        Algorithm 1 watches, and per-page HBM residency is its θ.
        """
        pt = self.spec.page_tokens
        reused = 0
        lat = 0.0
        depth = 0
        for nblocks in range(len(tokens) // pt, 0, -1):
            if self.block_hash(tokens, nblocks * pt) in self.prefix_index:
                depth = nblocks
                break
        if depth == 0:
            self.metrics.lookups += 1          # full miss
            self.metrics.fetch_latency_total_ms += lat
            return 0, lat
        self.metrics.prefix_hits += 1
        for j in range(1, depth + 1):          # touch every reused page
            page = self.prefix_index.get(self.block_hash(tokens, j * pt))
            if page is None:
                continue
            self.metrics.lookups += 1
            if self.page_tier[page] == "hbm":
                self.metrics.hbm_hits += 1
                lat += self.spec.hbm_hit_ms
            else:
                self.metrics.host_fetches += 1
                lat += self.spec.host_fetch_ms
                self._promote(page)
            self.page_ref[page] = 1
        reused = depth * pt
        self.metrics.fetch_latency_total_ms += lat
        return reused, lat

    def insert_prefix(self, tokens: np.ndarray) -> None:
        pt = self.spec.page_tokens
        for nblocks in range(1, len(tokens) // pt + 1):
            h = self.block_hash(tokens, nblocks * pt)
            if h not in self.prefix_index:
                self.prefix_index[h] = self._alloc_page()

    def _alloc_page(self) -> int:
        page = self._next_page
        self._next_page += 1
        self.page_tier[page] = "hbm"
        self.page_ref[page] = 1
        self._clock.append(page)
        self._evict_to_budget()
        return page

    def _promote(self, page: int) -> None:
        self.page_tier[page] = "hbm"
        self._evict_to_budget(exclude=page)

    def _evict_to_budget(self, exclude: int | None = None) -> None:
        guard = 0
        while self.hbm_pages > self.hbm_capacity and self._clock \
                and guard < 4 * len(self._clock):
            guard += 1
            page = self._clock[self._hand % len(self._clock)]
            self._hand = (self._hand + 1) % max(len(self._clock), 1)
            if page == exclude or self.page_tier.get(page) != "hbm":
                continue
            if self.page_ref.get(page, 0):
                self.page_ref[page] = 0
                continue
            self.page_tier[page] = "host"
            self.metrics.evictions += 1
