"""Fleet-scale tenant population generator.

The co-location scenarios so far hand-pick 2–3 tenants; the fleet driver
needs *populations* — a thousand tenants whose load shapes look like a
production cluster rather than a benchmark pair.  This module samples
them deterministically:

* **heavy-tailed rates** — per-tenant target rates are lognormal around
  ``rate_median`` (σ = ``rate_sigma``), capped at ``rate_cap``: most
  tenants are small, a fat tail is not;
* **query/policy mixes** — weighted draws over Nexmark queries and
  registered scaling policies (stateless-heavy by default: q1/q2
  dominate, as filter/map jobs dominate real fleets);
* **staggered diurnal phases** — a fraction of tenants ride a
  raised-cosine day/night cycle whose phase is drawn uniformly over the
  period, so the fleet's peaks don't align (the realistic case a single
  synchronized sinusoid hides);
* **flash crowds** — a correlated subset spikes to ``flash_factor`` ×
  its base rate in a narrow band around the same instant
  (``flash_at_frac`` of the horizon ± ``flash_spread_frac`` jitter): the
  co-ordinated scale-out burst that stresses admission arbitration;
* **faults on top** — a fraction of tenants carries a
  :class:`~repro.scenarios.faults.SetStraggler` or
  :class:`~repro.scenarios.faults.KillTask` schedule (emitted as plain
  lists so each ``run_colocated`` call builds a fresh, unfired
  ``FaultSchedule``).

:func:`run_fleet` ties it together: sample a population, size a cluster
that holds the initial placements with bounded headroom (scaling must
contend), and drive :func:`~repro.scenarios.cluster.run_colocated` —
what the ``benchmarks/run.py fleet`` bench, the CI smoke and
``examples/fleet_demo.py`` all call.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass

from repro.core.controller import ControllerConfig
from repro.core.justin import JustinParams
from repro.core.placement import placement_for_config
from repro.core.policy import make_policy
from repro.data.nexmark import QUERIES
from repro.scenarios.cluster import (Cluster, ColocatedResult, ColocatedSpec,
                                     run_colocated)
from repro.scenarios.faults import KillTask, SetStraggler
from repro.scenarios.profiles import Diurnal, Ramp, Spike
from repro.scenarios.runner import scenario_horizon_s

# stateless-heavy mixes: most of a production fleet is filter/map (q1/q2);
# q5/q11 contribute the windowed-state tenants Justin's levels matter for.
# (q3/q8 are excluded from the default mix: their person/auction generator
# is an order of magnitude slower per event, which would make fleet
# benches measure the data generator instead of the driver.)
DEFAULT_QUERY_MIX = (("q1", 0.52), ("q2", 0.33), ("q5", 0.09), ("q11", 0.06))
DEFAULT_POLICY_MIX = (("justin", 0.45), ("ds2", 0.30),
                      ("threshold", 0.15), ("static", 0.10))


@dataclass(frozen=True)
class PopulationSpec:
    """Knobs for one sampled fleet; every draw comes from ``seed``."""
    tenants: int = 1000
    seed: int = 0
    query_mix: tuple = DEFAULT_QUERY_MIX
    policy_mix: tuple = DEFAULT_POLICY_MIX
    rate_median: float = 800.0       # events/s, lognormal median
    rate_sigma: float = 1.0          # lognormal σ — the heavy tail
    rate_cap: float = 8000.0         # keep the tail simulable
    diurnal_fraction: float = 0.45   # staggered day/night riders
    ramp_fraction: float = 0.15      # slow organic growth
    flash_fraction: float = 0.15     # correlated flash-crowd members
    flash_at_frac: float = 0.5       # crowd instant, as horizon fraction
    flash_spread_frac: float = 0.05  # per-tenant jitter around it
    flash_factor: float = 3.0        # spike height over base rate
    flash_len_frac: float = 0.15     # spike length, as horizon fraction
    fault_fraction: float = 0.05     # tenants carrying a fault schedule
    underprov_fraction: float = 0.6  # stateful tenants that join at
                                     # (parallelism 1, level 0) and must
                                     # scale out through admission
    stateful_rate_boost: float = 2.5  # stateful tenants' rate multiplier
                                      # (their per-task capacity is lower,
                                      # so this is where scaling happens)


def _pick(rng: random.Random, mix: tuple) -> str:
    r = rng.random() * sum(w for _, w in mix)
    for name, w in mix:
        r -= w
        if r <= 0:
            return name
    return mix[-1][0]


def _first_op(query: str) -> str:
    """The query's first non-source operator — the fault target."""
    flow = QUERIES[query]()
    srcs = set(flow.sources())
    return next(n for n in flow.nodes if n not in srcs)


def sample_population(spec: PopulationSpec,
                      horizon_s: float) -> list[ColocatedSpec]:
    """Draw ``spec.tenants`` deterministic :class:`ColocatedSpec`\\ s.

    Flash-crowd membership overrides the base shape (the spike IS the
    tenant's profile); diurnal/ramp riders keep their own staggered
    phase; everyone else runs the fixed-target protocol."""
    rng = random.Random(spec.seed)
    fault_ops = {q: _first_op(q) for q, _ in spec.query_mix}
    stateful_ops = {"q5": "hot_auctions", "q11": "user_sessions",
                    "q8": "window_join", "q3": "incr_join"}
    out: list[ColocatedSpec] = []
    for i in range(spec.tenants):
        query = _pick(rng, spec.query_mix)
        policy = _pick(rng, spec.policy_mix)
        rate = min(spec.rate_cap,
                   spec.rate_median * math.exp(rng.gauss(0.0,
                                                         spec.rate_sigma)))
        config = None
        if query in stateful_ops:
            # stateful operators are where per-task capacity actually
            # binds, so this is where the fleet's scaling traffic comes
            # from: boost their rates, start half of them under-
            # provisioned (they grow through admission), and pin the
            # static ones at a raised level (the fair-share preemption
            # victims)
            rate = min(spec.rate_cap, rate * spec.stateful_rate_boost)
            if policy == "static":
                config = {stateful_ops[query]: (2, 2)}
            elif rng.random() < spec.underprov_fraction:
                config = {stateful_ops[query]: (1, 0)}
        shape = rng.random()
        profile = None
        if rng.random() < spec.flash_fraction:
            t0 = (spec.flash_at_frac
                  + rng.uniform(-spec.flash_spread_frac,
                                spec.flash_spread_frac)) * horizon_s
            profile = Spike(base=rate,
                            peak=min(spec.flash_factor * rate,
                                     spec.rate_cap),
                            t0=t0, duration_s=spec.flash_len_frac
                            * horizon_s)
        elif shape < spec.diurnal_fraction:
            period = horizon_s / 2.0
            profile = Diurnal(low=0.5 * rate, high=rate, period_s=period,
                              phase_s=rng.uniform(0.0, period))
        elif shape < spec.diurnal_fraction + spec.ramp_fraction:
            profile = Ramp(start=0.6 * rate, end=rate,
                           duration_s=0.7 * horizon_s,
                           t0=rng.uniform(0.0, 0.3 * horizon_s))
        faults = None
        if rng.random() < spec.fault_fraction:
            op = fault_ops[query]
            t = rng.uniform(0.1, 0.7) * horizon_s
            # lists, not FaultSchedule: the schedule is stateful, the
            # population must be re-runnable (oracle vs vectorized)
            if rng.random() < 0.5:
                faults = [SetStraggler(t=t, op=op, factor=4.0,
                                       duration_s=0.1 * horizon_s)]
            else:
                faults = [KillTask(t=t, op=op)]
        out.append(ColocatedSpec(policy, query, profile=profile,
                                 name=f"t{i:04d}", target=rate,
                                 faults=faults, config=config))
    return out


def size_cluster(specs: list[ColocatedSpec], cfg: ControllerConfig, *,
                 slots_factor: float = 1.1, mem_factor: float = 1.01,
                 tm_spec=None) -> Cluster:
    """A cluster that holds every initial placement with bounded headroom
    (``factor`` × the initial totals) — big enough that
    :func:`run_colocated`'s sizing check passes, small enough that
    scale-outs contend and admission actually arbitrates."""
    cpu = 0
    mem = 0.0
    quotes: dict[tuple, tuple[int, float]] = {}
    for s in specs:
        key = (s.query, s.policy, tuple(sorted((s.config or {}).items())))
        q = quotes.get(key)
        if q is None:
            flow = QUERIES[s.query]()
            init = dict(flow.config())
            init.update(s.config or {})
            # quote through the tenant's policy: its memory-coupling
            # model (e.g. DS2's uniform per-slot package) is what the
            # driver's initial reservation will actually charge
            init = make_policy(s.policy, cfg).resources_config(init)
            pl = placement_for_config(init, base_mem_mb=cfg.base_mem_mb,
                                      exclude=set(flow.sources()))
            q = quotes[key] = (pl.cpu_cores, pl.memory_mb)
        cpu += q[0]
        mem += q[1]
    return Cluster(cpu_slots=int(math.ceil(cpu * slots_factor)),
                   memory_mb=mem * mem_factor, tm_spec=tm_spec)


def fleet_cfg(*, decision_window_s: float = 8.0,
              stabilization_s: float = 4.0,
              busyness: float = 0.12,
              max_level: int = 2) -> ControllerConfig:
    """The fleet preset: short decision windows keep a tenant-window's
    engine cost in the low-millisecond range, and a LOW busyness
    setpoint rescales the whole control loop to low (cheap-to-simulate)
    event rates — per-task capacity ≈ busyness / cpu_cost, so at 0.12 a
    q1 tenant triggers near 5.5k ev/s instead of the paper's 36k.  The
    trigger/propose/admit dynamics are rate-ratio driven and unchanged;
    only the absolute event volume (and thus wall-clock cost) drops."""
    return ControllerConfig(decision_window_s=decision_window_s,
                            stabilization_s=stabilization_s,
                            busy_high=busyness,
                            target_busyness=busyness,
                            justin=JustinParams(max_level=max_level))


def run_fleet(tenants: int = 1000, windows: int = 100, *,
              admission: str = "fair_share", seed: int = 0,
              driver: str = "vectorized",
              migration_budget_mb: float | None = None,
              spec: PopulationSpec | None = None,
              cfg: ControllerConfig | None = None,
              slots_factor: float = 1.1,
              mem_factor: float = 1.01,
              tracer=None) -> ColocatedResult:
    """Sample a population, size its cluster, run the fleet driver."""
    cfg = cfg or fleet_cfg()
    spec = spec or PopulationSpec(tenants=tenants, seed=seed)
    specs = sample_population(spec, scenario_horizon_s(cfg, windows))
    cluster = size_cluster(specs, cfg, slots_factor=slots_factor,
                           mem_factor=mem_factor)
    return run_colocated(specs, cluster, windows=windows, seed=seed,
                         cfg=cfg, admission=admission, driver=driver,
                         migration_budget_mb=migration_budget_mb,
                         tracer=tracer)


def fleet_stats(result: ColocatedResult,
                elapsed_s: float | None = None) -> dict:
    """Fleet-level reductions for benches and demos: tenant-window
    outcome counts, peak usage, policy steps — and simulated
    tenant-windows per wall-clock second when ``elapsed_s`` is given
    (the BENCH_cluster.json headline)."""
    n = len(result.tenants)
    windows = len(result.usage)
    if result.fleet is not None:
        denied = int(result.fleet.denied.sum())
        deferred = int(result.fleet.deferred.sum())
        preempted = int(result.fleet.preempted.sum())
    else:
        denied = sum(len(t.denials) for t in result.tenants)
        deferred = sum(len(t.deferrals) for t in result.tenants)
        preempted = sum(len(t.preemptions) for t in result.tenants)
    out = {
        "tenants": n,
        "windows": windows,
        "tenant_windows": n * windows,
        "admission": result.admission,
        "denied_tenant_windows": denied,
        "deferred_tenant_windows": deferred,
        "preempted_tenant_windows": preempted,
        "policy_steps": sum(t.scaler.steps for t in result.tenants),
        "peak_cpu": max((c for c, _ in result.usage), default=0),
        "peak_mem_mb": max((m for _, m in result.usage), default=0.0),
        "cluster_cpu_slots": result.cluster.cpu_slots,
        "cluster_memory_mb": result.cluster.memory_mb,
    }
    if elapsed_s is not None:
        out["seconds"] = elapsed_s
        out["tenant_windows_per_s"] = (n * windows / elapsed_s
                                       if elapsed_s > 0 else 0.0)
    return out
