"""Multi-query co-location on one shared, finite cluster.

The paper's headline — hybrid CPU/memory scaling supports a target rate
with *fewer total cluster resources* than CPU-only DS2 — is invisible when
every episode owns an unbounded cluster.  This module makes it measurable:

* :class:`Cluster` is a shared budget of CPU slots and memory MB with
  per-tenant accounting.  ``reserve`` is atomic (admit or deny, never
  overdraw) and an invariant check keeps total usage within budget.
* :func:`run_colocated` steps N ``(policy, query, profile)`` episodes in
  lockstep, one decision window at a time.  Each episode's scale-up request
  hits the cluster through the controller's admission hook; denied requests
  leave the episode's configuration untouched, so its trigger persists and
  the request is retried at the next window.  Scale-downs bypass admission
  and *release* capacity — which is precisely how Justin's give-back-memory
  decisions free room for a neighbor's scale-out that DS2's one-size-fits-
  all packages would keep blocked.

Admission arbitration (who gets first claim on the remaining budget each
window) supports three orders:

* ``"priority"``   — the spec list is the priority order, every window;
* ``"fair_share"`` — episodes using the smallest fraction of the budget go
  first (max of CPU share and memory share, ascending);
* ``"first_come"`` — episodes with the oldest unserved (denied) request go
  first; ties fall back to spec order.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.core.policy import make_policy
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.scenarios.faults import FaultSchedule
from repro.scenarios.metrics import SLOReport, slo_report
from repro.scenarios.profiles import Profile, make_profile
from repro.scenarios.runner import scenario_horizon_s
from repro.streaming.engine import StreamEngine

ADMISSION_POLICIES = ("priority", "fair_share", "first_come")


@dataclass
class Cluster:
    """A finite pool of CPU slots and memory, shared by named tenants.

    Usage is tracked per tenant as the *absolute* footprint of that
    tenant's current placement (not deltas), so a reservation is simply
    "replace my footprint with this one" — admitted iff the cluster-wide
    totals stay within budget.
    """
    cpu_slots: int
    memory_mb: float
    used_cpu: dict[str, int] = field(default_factory=dict)
    used_mem: dict[str, float] = field(default_factory=dict)

    # ------------------------------------------------------------- accounting
    @property
    def cpu_in_use(self) -> int:
        return sum(self.used_cpu.values())

    @property
    def mem_in_use(self) -> float:
        return sum(self.used_mem.values())

    def available(self) -> tuple[int, float]:
        return (self.cpu_slots - self.cpu_in_use,
                self.memory_mb - self.mem_in_use)

    def fits(self, tenant: str, cpu: int, mem: float) -> bool:
        """Would replacing ``tenant``'s footprint with (cpu, mem) fit?"""
        cpu_total = self.cpu_in_use - self.used_cpu.get(tenant, 0) + cpu
        mem_total = self.mem_in_use - self.used_mem.get(tenant, 0.0) + mem
        return cpu_total <= self.cpu_slots and mem_total <= self.memory_mb

    def reserve(self, tenant: str, cpu: int, mem: float) -> bool:
        """Atomically replace ``tenant``'s footprint; False if it would
        overdraw the budget (nothing changes on denial)."""
        if not self.fits(tenant, cpu, mem):
            return False
        self.used_cpu[tenant] = cpu
        self.used_mem[tenant] = mem
        assert self.cpu_in_use <= self.cpu_slots \
            and self.mem_in_use <= self.memory_mb + 1e-9, "budget overdrawn"
        return True

    def release(self, tenant: str) -> None:
        self.used_cpu.pop(tenant, None)
        self.used_mem.pop(tenant, None)

    def share(self, tenant: str) -> float:
        """Tenant's budget share: max of its CPU and memory fractions —
        the fair-share arbitration key."""
        return max(self.used_cpu.get(tenant, 0) / max(self.cpu_slots, 1),
                   self.used_mem.get(tenant, 0.0) / max(self.memory_mb, 1e-9))


# ---------------------------------------------------------------------------
# Co-located episodes
# ---------------------------------------------------------------------------

@dataclass
class ColocatedSpec:
    """One tenant: a policy driving a query under a rate profile.  ``name``
    defaults to ``{policy}:{query}`` (suffixed for uniqueness by the
    driver).  ``profile`` may be a Profile, a named shape ("ramp", ...) or
    None for the paper's fixed-target protocol; ``target`` overrides the
    query's default target rate."""
    policy: str
    query: str
    profile: Profile | str | None = None
    name: str | None = None
    target: float | None = None
    faults: FaultSchedule | list | None = None


@dataclass
class TenantRun:
    """One tenant's episode state + outcome."""
    spec: ColocatedSpec
    name: str
    scaler: AutoScaler
    profile: Profile | None
    faults: FaultSchedule | None
    denials: list[int] = field(default_factory=list)   # window indices
    faults_fired: list = field(default_factory=list)
    first_pending: int | None = None   # window of oldest unserved request

    @property
    def history(self) -> list:
        return self.scaler.history

    def slo(self, slack: float = 0.97) -> SLOReport:
        return slo_report(self.history, slack)


@dataclass
class ColocatedResult:
    cluster: Cluster
    tenants: list[TenantRun]
    admission: str
    # per-window cluster totals [(cpu_in_use, mem_in_use), ...]
    usage: list = field(default_factory=list)

    def tenant(self, name: str) -> TenantRun:
        for t in self.tenants:
            if t.name == name:
                return t
        raise KeyError(name)

    def summary(self, slack: float = 0.97) -> dict:
        return {
            "admission": self.admission,
            "cluster": {"cpu_slots": self.cluster.cpu_slots,
                        "memory_mb": self.cluster.memory_mb},
            "peak_cpu": max((c for c, _ in self.usage), default=0),
            "peak_mem": max((m for _, m in self.usage), default=0.0),
            "tenants": {t.name: {
                "policy": t.spec.policy, "query": t.spec.query,
                "steps": t.scaler.steps,
                "denied_windows": list(t.denials),
                "slo": t.slo(slack).to_dict(),
            } for t in self.tenants},
        }


def _arbitration_order(tenants: list[TenantRun], cluster: Cluster,
                       admission: str) -> list[TenantRun]:
    if admission == "priority":
        return list(tenants)
    if admission == "fair_share":
        return sorted(tenants, key=lambda t: cluster.share(t.name))
    if admission == "first_come":
        return sorted(tenants, key=lambda t: (t.first_pending is None,
                                              t.first_pending or 0))
    raise ValueError(f"unknown admission policy {admission!r} "
                     f"(have: {', '.join(ADMISSION_POLICIES)})")


def run_colocated(specs: list[ColocatedSpec | tuple], cluster: Cluster,
                  *, windows: int = 8, seed: int = 3, max_level: int = 2,
                  admission: str = "priority",
                  cfg: ControllerConfig | None = None,
                  warm: bool = True) -> ColocatedResult:
    """Step every episode through ``windows`` decision windows in lockstep,
    arbitrating each window's scale-up requests against ``cluster``'s
    remaining budget.

    ``specs`` entries may be :class:`ColocatedSpec` or bare
    ``(policy, query)`` / ``(policy, query, profile)`` tuples.  ``cfg`` is a
    *template* shared by every tenant; each tenant's policy is constructed
    from the registry by its spec's name (any registered policy works, not
    just ds2/justin).  Episodes whose *initial* placement already exceeds
    the budget raise — a cluster that cannot hold the starting
    configurations is a sizing error, not an admission decision.
    """
    specs = [s if isinstance(s, ColocatedSpec) else ColocatedSpec(*s)
             for s in specs]
    base = cfg or ControllerConfig(justin=JustinParams(max_level=max_level))
    tenants: list[TenantRun] = []
    names: set[str] = set()
    for i, spec in enumerate(specs):
        name = spec.name or f"{spec.policy}:{spec.query}"
        while name in names:
            name = f"{name}#{i}"
        names.add(name)
        target = spec.target if spec.target is not None \
            else TARGET_RATES[spec.query]
        profile = spec.profile
        if isinstance(profile, str):
            profile = make_profile(profile, target,
                                   scenario_horizon_s(base, windows))
        faults = spec.faults
        if isinstance(faults, (list, tuple)):
            faults = FaultSchedule(list(faults))
        engine = StreamEngine(QUERIES[spec.query](), seed=seed, warm=warm)
        scaler = AutoScaler(engine, profile(0.0) if profile else target,
                            base, policy=make_policy(spec.policy, base))
        tenants.append(TenantRun(spec=spec, name=name, scaler=scaler,
                                 profile=profile, faults=faults))

    # initial placements must fit — this is cluster sizing, not admission
    for t in tenants:
        cpu0, mem0 = t.scaler.resources()
        if not cluster.reserve(t.name, cpu0, mem0):
            raise ValueError(
                f"cluster {cluster.cpu_slots} slots/{cluster.memory_mb} MB "
                f"cannot hold {t.name}'s initial placement "
                f"({cpu0} slots, {mem0} MB)")

    result = ColocatedResult(cluster=cluster, tenants=tenants,
                             admission=admission)

    for w in range(windows):
        for t in _arbitration_order(tenants, cluster, admission):
            def admit(scaler, new_config, cpu, mem, _t=t):
                ok = cluster.reserve(_t.name, cpu, mem)
                if not ok:
                    _t.denials.append(w)
                    if _t.first_pending is None:
                        _t.first_pending = w
                return ok

            def hook(eng, _w, _t=t):
                if _t.faults is not None:
                    _t.faults_fired.extend(
                        _t.faults.apply_due(eng, eng.now))

            t.scaler.admission = admit
            t.scaler.step_window(w, target_profile=t.profile,
                                 window_hook=hook)
            # sync the enacted footprint (scale-downs release capacity;
            # admitted scale-ups were already reserved at the quoted size,
            # re-reserving the enacted placement keeps them in lockstep)
            cpu_now, mem_now = t.scaler.resources()
            cluster.reserve(t.name, cpu_now, mem_now)
            if not t.history[-1].denied:
                t.first_pending = None
        result.usage.append((cluster.cpu_in_use, cluster.mem_in_use))
    return result
