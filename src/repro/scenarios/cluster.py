"""Multi-query co-location on one shared, finite cluster.

The paper's headline — hybrid CPU/memory scaling supports a target rate
with *fewer total cluster resources* than CPU-only DS2 — is invisible when
every episode owns an unbounded cluster.  This module makes it measurable:

* :class:`Cluster` is a shared budget of CPU slots and memory MB with
  per-tenant accounting.  ``reserve`` is atomic (admit or deny, never
  overdraw) and an invariant check keeps total usage within budget.
* **Shared-TM packing** (``Cluster(..., tm_spec=...)``): instead of scalar
  footprints, tenants reserve tenant-tagged task lists that the cluster
  packs into ONE TaskManager fleet (``repro.core.placement.shared_pack``).
  Each TM's ``base_mb`` is amortized across its co-resident tenants, so N
  co-located queries pay ~1 fleet's heap/network share instead of N — the
  resource-efficiency headline private fleets hide.  Every accepted
  re-reservation is priced as a repack (``MigrationCost``: tasks moved ×
  state MB).
* :func:`run_colocated` steps N ``(policy, query, profile)`` episodes in
  lockstep, one decision window at a time.  Each episode's scale-up request
  hits the cluster through the controller's admission hook; denied requests
  leave the episode's configuration untouched, so its trigger persists and
  the request is retried at the next window.  Scale-downs bypass admission
  and *release* capacity — which is precisely how Justin's give-back-memory
  decisions free room for a neighbor's scale-out that DS2's one-size-fits-
  all packages would keep blocked.

Admission arbitration (who gets first claim on the remaining budget each
window) supports four orders:

* ``"priority"``   — the spec list is the priority order, every window;
* ``"fair_share"`` — episodes using the smallest fraction of the budget go
  first (max of CPU share and memory share, ascending);
* ``"first_come"`` — episodes with the oldest unserved (denied) request go
  first; ties fall back to spec order.
* ``"preemption"`` — priority order for requests, plus the §4.3 re-shape
  mechanism with **fair-share victim selection**: when a request is
  denied, the arbiter forces tenants holding more than their fair
  allotment of the budget (1/N of the larger of their CPU and memory
  fractions) to give back one storage level at a time
  (``AutoScaler.shrink_memory``, built on the policy protocol's
  ``propose_shrink``) — biggest excess first, spec priority breaking
  ties — until the request fits or no over-allotment tenant can shrink.
  A tenant at or below its fair share is never preempted; a hog above it
  is reclaimable even by a lower-priority requester.  Give-backs are
  recorded per window in ``TenantRun.preemptions`` alongside
  ``denials``.

A per-window **migration budget** (``migration_budget_mb``) additionally
caps the state MB admissions may move each window: an admission whose
quoted migration cost exceeds the remaining allowance is deferred
through the same denial/retry path (``TenantRun.deferrals``) — the
"migration-cost budgets in the arbiter" item the ROADMAP queued.  The
budget covers *everything* an admission moves: preemption give-backs are
quoted and charged like any other migration (an unaffordable give-back
is skipped, and the whole request deferred when the fleet cannot be
re-shaped within the window's remaining allowance), and after give-backs
re-shape the fleet the requester's own move is re-quoted at the
post-preemption price rather than charged its stale pre-preemption
quote.

Two interchangeable drivers step the fleet (``driver=`` on
:func:`run_colocated`):

* ``"scalar"`` — the original per-tenant Python loop: dict lookups,
  ``sorted`` arbitration, per-tenant list bookkeeping.  It is the
  *oracle*: simple enough to audit, kept byte-for-byte decision-
  compatible.
* ``"vectorized"`` (default) — structure-of-arrays fleet state
  (:class:`_FleetState`): per-tenant footprints, targets,
  pending/denial/deferral flags and per-window attribution live in numpy
  arrays; arbitration order, fair-share ranking and preemption victim
  ranking are array programs; admission quotes are cached fleet-wide
  (one ``bin_pack`` per distinct (query, config) instead of one per
  tenant per window); and the per-reservation full-sum budget audit
  becomes one fleet-level invariant check per window.  Decision-
  identical to the oracle — same denials, deferrals, preemptions, usage
  series — at thousand-tenant scale.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.controller import AutoScaler, ControllerConfig
from repro.core.justin import JustinParams
from repro.core.placement import (MigrationCost, SharedPlacement,
                                  TaskRequest, TMSpec, repack, shared_pack)
from repro.core.policy import make_policy
from repro.core.units import MB_EPS, mem_close
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.obs.provenance import (REASON_DEFERRED, REASON_SHRUNK,
                                  REASON_STEADY, REASON_TRIGGERED,
                                  reason_counts)
from repro.scenarios.faults import FaultSchedule
from repro.scenarios.metrics import SLOReport, slo_report
from repro.scenarios.profiles import Profile, make_profile
from repro.scenarios.runner import scenario_horizon_s
from repro.streaming.engine import StreamEngine

ADMISSION_POLICIES = ("priority", "fair_share", "first_come", "preemption")
DRIVERS = ("vectorized", "scalar")

# one tolerance for every budget comparison: ``fits``, ``reserve_tasks``
# and the invariant asserts must agree, or float drift in the summed
# attribution can deny re-reserving an IDENTICAL footprint that the
# invariant happily accepts (the post-step resync then dies with a
# spurious "accounting desync").  The value is the repo-wide blessed
# tolerance from repro.core.units, shared with the placement packer and
# the controller's admission-gating growth test.
_EPS = MB_EPS


@dataclass
class Cluster:
    """A finite pool of CPU slots and memory, shared by named tenants.

    Usage is tracked per tenant as the *absolute* footprint of that
    tenant's current placement (not deltas), so a reservation is simply
    "replace my footprint with this one" — admitted iff the cluster-wide
    totals stay within budget.  The totals are maintained incrementally
    (``cpu_in_use`` / ``mem_in_use`` are O(1), not a dict sum), which is
    what keeps a thousand-tenant window O(N) instead of O(N²).

    With ``tm_spec`` set the cluster runs in **shared-TM mode**: tenants
    reserve task lists (:meth:`reserve_tasks`) that are bin-packed into
    one fleet, and ``used_cpu`` / ``used_mem`` hold each tenant's
    *amortized attribution* (own slots + managed grants + its
    slot-proportional share of co-resident TMs' ``base_mb``), which sums
    exactly to the fleet totals."""
    cpu_slots: int
    memory_mb: float
    used_cpu: dict[str, int] = field(default_factory=dict)
    used_mem: dict[str, float] = field(default_factory=dict)
    tm_spec: TMSpec | None = None
    tasks: dict[str, list[TaskRequest]] = field(default_factory=dict)
    migrations: list[MigrationCost] = field(default_factory=list)
    _placement: SharedPlacement | None = field(default=None, repr=False)
    _cpu_total: int = field(default=0, init=False, repr=False)
    _mem_total: float = field(default=0.0, init=False, repr=False)

    def __post_init__(self) -> None:
        self._recount()

    def _recount(self) -> None:
        self._cpu_total = sum(self.used_cpu.values())
        self._mem_total = sum(self.used_mem.values())

    # ------------------------------------------------------------- accounting
    @property
    def shared(self) -> bool:
        return self.tm_spec is not None

    @property
    def cpu_in_use(self) -> int:
        return self._cpu_total

    @property
    def mem_in_use(self) -> float:
        return self._mem_total

    def available(self) -> tuple[int, float]:
        return (self.cpu_slots - self.cpu_in_use,
                self.memory_mb - self.mem_in_use)

    def fits(self, tenant: str, cpu: int, mem: float) -> bool:
        """Would replacing ``tenant``'s footprint with (cpu, mem) fit?
        Memory is compared with the same ``_EPS`` tolerance the invariant
        asserts and ``reserve_tasks`` use, so accumulated float drift in
        the attribution sum can never reject a footprint the invariant
        would accept."""
        cpu_total = self._cpu_total - self.used_cpu.get(tenant, 0) + cpu
        mem_total = self._mem_total - self.used_mem.get(tenant, 0.0) + mem
        return cpu_total <= self.cpu_slots \
            and mem_total <= self.memory_mb + _EPS

    def reserve(self, tenant: str, cpu: int, mem: float) -> bool:
        """Atomically replace ``tenant``'s footprint; False if it would
        overdraw the budget (nothing changes on denial)."""
        if self.shared:
            raise TypeError("shared-TM cluster: reserve task lists via "
                            "reserve_tasks, not scalar footprints")
        if not self.fits(tenant, cpu, mem):
            return False
        self._cpu_total += cpu - self.used_cpu.get(tenant, 0)
        self._mem_total += mem - self.used_mem.get(tenant, 0.0)
        self.used_cpu[tenant] = cpu
        self.used_mem[tenant] = mem
        assert self._cpu_total <= self.cpu_slots \
            and self._mem_total <= self.memory_mb + _EPS, "budget overdrawn"
        return True

    # ------------------------------------------------------ shared-TM packing
    def placement(self) -> SharedPlacement | None:
        """The current fleet (shared-TM mode), None before any reservation."""
        return self._placement

    def migration_total(self) -> MigrationCost:
        """Cumulative repack cost across accepted reservations."""
        return sum(self.migrations, MigrationCost())

    def _trial(self, tenant: str,
               reqs: list[TaskRequest]) -> dict[str, list[TaskRequest]]:
        trial = dict(self.tasks)
        trial[tenant] = list(reqs)
        return trial

    def quote(self, tenant: str, reqs: list[TaskRequest]) -> tuple[int, float]:
        """(cpu slots, amortized memory MB) ``tenant`` would be attributed
        if its task list became ``reqs`` — the admission hook's
        pre-enactment quote against the shared placement."""
        pl = shared_pack(self._trial(tenant, reqs), self.tm_spec)
        return pl.tenant_cpu(tenant), pl.tenant_memory_mb(tenant)

    def quote_migration(self, tenant: str,
                        reqs: list[TaskRequest]) -> MigrationCost:
        """The fleet-level repack cost ``tenant``'s reservation would
        incur (tasks moved × state MB) WITHOUT committing anything — what
        a per-window migration budget gates before admission."""
        _, cost = repack(self._trial(tenant, reqs), self.tm_spec,
                         self._placement)
        return cost

    def reserve_tasks(self, tenant: str, reqs: list[TaskRequest]) -> bool:
        """Atomically replace ``tenant``'s task list and repack the whole
        fleet; False if the packed totals would overdraw the budget
        (nothing changes on denial).  Accepted reservations append their
        :class:`MigrationCost` to ``migrations``."""
        pl, cost = repack(self._trial(tenant, reqs), self.tm_spec,
                          self._placement)
        if pl.cpu_cores > self.cpu_slots \
                or pl.memory_mb > self.memory_mb + _EPS:
            return False
        self.tasks[tenant] = list(reqs)
        self.migrations.append(cost)
        self._commit_placement(pl)
        return True

    def _commit_placement(self, pl: SharedPlacement) -> None:
        self._placement = pl
        att = pl.attribution()
        self.used_cpu = {t: att.get(t, (0, 0.0))[0] for t in self.tasks}
        self.used_mem = {t: att.get(t, (0, 0.0))[1] for t in self.tasks}
        self._recount()
        assert self._cpu_total <= self.cpu_slots \
            and self._mem_total <= self.memory_mb + _EPS, "budget overdrawn"

    def release(self, tenant: str) -> None:
        self._cpu_total -= self.used_cpu.pop(tenant, 0)
        self._mem_total -= self.used_mem.pop(tenant, 0.0)
        # releases can't overdraw, but the O(1) counters must stay honest
        # against the dicts they mirror: going negative means a double
        # release / stale-tenant bug upstream
        assert self._cpu_total >= 0 and self._mem_total >= -_EPS, \
            "budget counters negative after release"
        if self.shared and tenant in self.tasks:
            del self.tasks[tenant]
            self._commit_placement(shared_pack(self.tasks, self.tm_spec))

    def share(self, tenant: str) -> float:
        """Tenant's budget share: max of its CPU and memory fractions —
        the fair-share arbitration key."""
        return max(self.used_cpu.get(tenant, 0) / max(self.cpu_slots, 1),
                   self.used_mem.get(tenant, 0.0) / max(self.memory_mb, 1e-9))


# ---------------------------------------------------------------------------
# Co-located episodes
# ---------------------------------------------------------------------------

@dataclass
class ColocatedSpec:
    """One tenant: a policy driving a query under a rate profile.  ``name``
    defaults to ``{policy}:{query}`` (suffixed for uniqueness by the
    driver).  ``profile`` may be a Profile, a named shape ("ramp", ...) or
    None for the paper's fixed-target protocol; ``target`` overrides the
    query's default target rate.  ``config`` is an optional initial
    configuration override (partial ``{op: (parallelism, level)}``),
    enacted before the first window — e.g. a static tenant pinned at a
    raised storage level, the preemption scenarios' victim."""
    policy: str
    query: str
    profile: Profile | str | None = None
    name: str | None = None
    target: float | None = None
    faults: FaultSchedule | list | None = None
    config: dict | None = None


@dataclass
class TenantRun:
    """One tenant's episode state + outcome."""
    spec: ColocatedSpec
    name: str
    scaler: AutoScaler
    profile: Profile | None
    faults: FaultSchedule | None
    denials: list[int] = field(default_factory=list)     # window indices
    preemptions: list[int] = field(default_factory=list)  # windows with >= 1
                                                          # forced give-back
                                                          # (the give-back
                                                          # COUNT lives in
                                                          # scaler.preemptions)
    deferrals: list[int] = field(default_factory=list)   # windows denied by
                                                         # the migration
                                                         # budget (subset of
                                                         # ``denials``)
    faults_fired: list = field(default_factory=list)
    first_pending: int | None = None   # window of oldest unserved request

    @property
    def history(self) -> list:
        return self.scaler.history

    def slo(self, slack: float = 0.97) -> SLOReport:
        return slo_report(self.history, slack)


@dataclass
class ColocatedResult:
    cluster: Cluster
    tenants: list[TenantRun]
    admission: str
    # per-window cluster totals [(cpu_in_use, mem_in_use), ...]
    usage: list = field(default_factory=list)
    # the vectorized driver's structure-of-arrays state (None under the
    # scalar oracle): per-window denial/deferral/preemption flags and
    # attribution as (windows, tenants) numpy arrays — what fleet-scale
    # consumers reduce over without touching per-tenant lists
    fleet: "_FleetState | None" = None

    def tenant(self, name: str) -> TenantRun:
        for t in self.tenants:
            if t.name == name:
                return t
        raise KeyError(name)

    def summary(self, slack: float = 0.97) -> dict:
        out = {
            "admission": self.admission,
            "cluster": {"cpu_slots": self.cluster.cpu_slots,
                        "memory_mb": self.cluster.memory_mb,
                        "shared_tm": self.cluster.shared},
            "peak_cpu": max((c for c, _ in self.usage), default=0),
            "peak_mem": max((m for _, m in self.usage), default=0.0),
            "tenants": {t.name: {
                "policy": t.spec.policy, "query": t.spec.query,
                "steps": t.scaler.steps,
                "denied_windows": list(t.denials),
                "preempted_windows": list(t.preemptions),
                "deferred_windows": list(t.deferrals),
                "reasons": reason_counts(t.history),
                "slo": t.slo(slack).to_dict(),
            } for t in self.tenants},
        }
        # always emitted (zeroed on private-fleet clusters, which never
        # repack) so grid JSON keeps one schema across modes
        mig = self.cluster.migration_total()
        out["migration"] = {"tasks_moved": mig.tasks_moved,
                            "state_mb": mig.state_mb}
        return out


def _arbitration_order(tenants: list[TenantRun], cluster: Cluster,
                       admission: str) -> list[TenantRun]:
    if admission in ("priority", "preemption"):
        return list(tenants)
    if admission == "fair_share":
        return sorted(tenants, key=lambda t: cluster.share(t.name))
    if admission == "first_come":
        return sorted(tenants, key=lambda t: (t.first_pending is None,
                                              t.first_pending or 0))
    raise ValueError(f"unknown admission policy {admission!r} "
                     f"(have: {', '.join(ADMISSION_POLICIES)})")


# ---------------------------------------------------------------------------
# Driver-shared plumbing
# ---------------------------------------------------------------------------

def _reserve(cluster: Cluster, t: TenantRun, config: dict | None = None,
             cpu: int | None = None, mem: float | None = None) -> bool:
    """Replace ``t``'s cluster footprint: its task list under ``config``
    (shared-TM mode) or the scalar (cpu, mem) quote."""
    if cluster.shared:
        return cluster.reserve_tasks(t.name, t.scaler.task_requests(config))
    if cpu is None:
        cpu, mem = t.scaler.resources()
    return cluster.reserve(t.name, cpu, mem)


def _cfg_key(config: dict) -> tuple:
    return tuple(sorted(config.items()))


def _migration_quote(cluster: Cluster, base: ControllerConfig, t: TenantRun,
                     config: dict | None, cache: dict | None = None) -> float:
    """State MB ``t``'s reservation would move — the migration-budget
    currency.  Fleet-level repack cost on shared-TM clusters; the
    migration planner over the tenant's own placements otherwise (a pure
    function of (query, policy, old config, new config), which is what
    the vectorized driver's fleet-wide ``cache`` keys on)."""
    if cluster.shared:
        return cluster.quote_migration(
            t.name, t.scaler.task_requests(config)).state_mb
    from repro.core.placement import bin_pack, default_tm_spec
    from repro.migration import plan_migration
    key = None
    if cache is not None:
        key = (t.spec.query, t.scaler.policy.name,
               _cfg_key(t.scaler.flow.config()),
               _cfg_key(config if config is not None
                        else t.scaler.flow.config()))
        hit = cache.get(key)
        if hit is not None:
            return hit
    spec = default_tm_spec(base.base_mem_mb)
    old_pl = bin_pack(t.scaler.task_requests(), spec)
    new_pl = bin_pack(t.scaler.task_requests(config), spec)
    out = plan_migration(old_pl, new_pl).migration_cost().state_mb
    if cache is not None:
        cache[key] = out
    return out


def _footprint_shrank(cluster: Cluster, t: TenantRun) -> bool:
    """Is ``t``'s current task list no larger (slots and managed MB)
    than the one the cluster holds for it?"""
    old = cluster.tasks.get(t.name, [])
    new = t.scaler.task_requests()
    return (len(new) <= len(old)
            and sum(r.memory_mb for r in new)
            <= sum(r.memory_mb for r in old) + _EPS)


def _desync_error(cluster: Cluster, t: TenantRun, cpu_now: int,
                  mem_now: float) -> RuntimeError:
    return RuntimeError(
        f"cluster accounting desync: {t.name}'s enacted "
        f"placement ({cpu_now} slots, {mem_now:.0f} MB) does "
        f"not fit the budget its quoted admission passed "
        f"({cluster.cpu_slots} slots, "
        f"{cluster.memory_mb:.0f} MB, "
        f"{cluster.cpu_in_use - cluster.used_cpu.get(t.name, 0)}"
        f" slots/"
        f"{cluster.mem_in_use - cluster.used_mem.get(t.name, 0.0):.0f}"
        f" MB held by neighbors)")


def _setup_tenants(specs, cluster: Cluster, *, windows: int, seed: int,
                   base: ControllerConfig, warm: bool,
                   cost_model, tracer=None) -> list[TenantRun]:
    from repro.migration import MigrationRuntime
    tenants: list[TenantRun] = []
    names: set[str] = set()
    for spec in specs:
        # deterministic unique names: always suffix the ORIGINAL base name
        # (compounding the suffixed name produced a#2#2... on repeated
        # collisions)
        base_name = spec.name or f"{spec.policy}:{spec.query}"
        name, k = base_name, 2
        while name in names:
            name = f"{base_name}#{k}"
            k += 1
        names.add(name)
        target = spec.target if spec.target is not None \
            else TARGET_RATES[spec.query]
        profile = spec.profile
        if isinstance(profile, str):
            profile = make_profile(profile, target,
                                   scenario_horizon_s(base, windows))
        faults = spec.faults
        if isinstance(faults, (list, tuple)):
            faults = FaultSchedule(list(faults))
        engine = StreamEngine(QUERIES[spec.query](), seed=seed, warm=warm)
        if spec.config:
            engine.reconfigure(spec.config)
        scaler = AutoScaler(engine, profile(0.0) if profile else target,
                            base, policy=make_policy(spec.policy, base),
                            migration=None if cost_model.mechanism
                            == "instant" else MigrationRuntime(cost_model))
        scaler.tenant = name
        scaler.cluster = cluster
        if tracer is not None:
            scaler.tracer = tracer
        tenants.append(TenantRun(spec=spec, name=name, scaler=scaler,
                                 profile=profile, faults=faults))

    # initial placements must fit — this is cluster sizing, not admission
    for t in tenants:
        if not _reserve(cluster, t):
            cpu0, mem0 = t.scaler.resources()
            raise ValueError(
                f"cluster {cluster.cpu_slots} slots/{cluster.memory_mb} MB "
                f"cannot hold {t.name}'s initial placement "
                f"({cpu0} slots, {mem0} MB)")
    return tenants


# ---------------------------------------------------------------------------
# Scalar driver — the oracle
# ---------------------------------------------------------------------------

def _run_scalar(tenants: list[TenantRun], cluster: Cluster,
                result: ColocatedResult, *, windows: int, admission: str,
                migration_budget_mb: float | None,
                base: ControllerConfig) -> ColocatedResult:
    """The original per-tenant Python loop: dict snapshots, ``sorted``
    arbitration, per-tenant list bookkeeping.  Kept as the simple,
    auditable oracle the vectorized driver is equivalence-tested
    against."""
    prio = {t.name: i for i, t in enumerate(tenants)}

    def _preempt_for(requester: TenantRun, new_config: dict, cpu, mem,
                     w: int, budget_left: float | None
                     ) -> tuple[bool, float, bool]:
        """Fair-share victim selection: force give-backs from tenants
        holding MORE than their fair allotment of the budget (1/N of the
        max of CPU and memory fractions), biggest excess first, spec
        priority breaking ties (lower-priority tenants shrink first).
        One level at a time, re-ranking after every give-back (shares
        move), until the requester's reservation fits or no
        over-allotment tenant can shrink.  Every give-back is recorded
        on the victim.

        Unlike strict-priority victim selection, a tenant sitting at or
        below its fair share is never preempted — and a hog above its
        allotment is reclaimable even by a lower-priority requester.

        Under a migration budget, give-backs are migrations too: each is
        quoted before enactment and charged against the window's
        remaining allowance (an unaffordable give-back is skipped —
        blocked, not exhausted), and once the fleet has been re-shaped
        the requester's own move is re-quoted at the post-preemption
        price.  Returns ``(admitted, state MB charged, blocked)`` —
        ``blocked`` marks a request the budget (not capacity) turned
        away, the driver's deferral signal.
        """
        fair = 1.0 / max(len(tenants), 1)
        exhausted: set[str] = set()
        spent = 0.0
        blocked = False
        while True:
            victims = [v for v in tenants
                       if v is not requester and v.name not in exhausted
                       and cluster.share(v.name) > fair]
            victims.sort(key=lambda v: (fair - cluster.share(v.name),
                                        -prio[v.name]))
            for victim in victims:
                sc = victim.scaler
                prop = sc.policy.propose_shrink(sc.flow, sc.cfg)
                if prop is None or prop.config == sc.flow.config():
                    exhausted.add(victim.name)   # nothing left to give back
                    continue
                gb_mb = 0.0
                if budget_left is not None:
                    gb_mb = _migration_quote(cluster, base, victim,
                                             prop.config)
                    if gb_mb > budget_left - spent + _EPS:
                        blocked = True   # this victim's give-back moves
                        continue         # more than the window has left
                # FFD packing is non-monotone (see tests/test_placement.py
                # ::test_ffd_packing_is_non_monotone): a shrunk task list
                # can pack into a LARGER fleet.  Quote the give-back
                # BEFORE enacting it and skip this victim when shrinking
                # would not actually free budget — but do NOT mark it
                # exhausted: a later give-back reshapes the fleet and the
                # quote may succeed on the re-rank (only propose_shrink
                # exhaustion, which depends solely on the victim's own
                # config, is stable enough to cache).
                if cluster.shared:
                    if not cluster.reserve_tasks(
                            victim.name, sc.task_requests(prop.config)):
                        continue
                elif not cluster.fits(victim.name,
                                      *sc.resources(prop.config)):
                    continue
                shrunk = sc.shrink_memory()
                assert shrunk is not None   # prop said there was a level
                if not victim.preemptions or victim.preemptions[-1] != w:
                    victim.preemptions.append(w)
                if not cluster.shared:
                    freed = cluster.reserve(victim.name, *shrunk)
                    assert freed            # same quote fits() passed above
                spent += gb_mb
                if budget_left is not None:
                    # the give-backs re-shaped the fleet: the requester's
                    # own move costs the post-preemption price, not the
                    # stale quote taken before victims shrank
                    req_mb = _migration_quote(cluster, base, requester,
                                              new_config)
                    if req_mb > budget_left - spent + _EPS:
                        return False, spent, True
                else:
                    req_mb = 0.0
                if _reserve(cluster, requester, new_config, cpu, mem):
                    return True, spent + req_mb, False
                break               # shares moved: re-rank the victims
            else:
                return False, spent, blocked

    for w in range(windows):
        # the attribution backing the configs that RUN during this window
        # is the one reservations left behind at the previous boundary —
        # matching HistoryRow.memory_mb, which quotes the pre-reconfig
        # config (on preempted windows the victim's mid-window shrink
        # makes its row slightly conservative: it held the pre-shrink
        # grants when the window began)
        att_start = dict(cluster.used_mem)
        budget_left = migration_budget_mb     # per-window allowance
        for t in _arbitration_order(tenants, cluster, admission):
            def admit(scaler, new_config, cpu, mem, _t=t, _w=w):
                nonlocal budget_left
                quote_mb = 0.0
                if budget_left is not None:
                    # a quoted admission whose migration cost exceeds the
                    # window's remaining budget is DEFERRED — the normal
                    # denial/retry path, additionally marked a deferral
                    quote_mb = _migration_quote(cluster, base, _t,
                                                new_config)
                    if quote_mb > budget_left + _EPS:
                        _t.deferrals.append(_w)
                        _t.denials.append(_w)
                        if _t.first_pending is None:
                            _t.first_pending = _w
                        _t.scaler.tracer.record(
                            "admission.defer", "admission",
                            _t.scaler.engine.now, _t.scaler.engine.now,
                            tenant=_t.name, window=_w,
                            args={"quote_mb": quote_mb,
                                  "budget_left_mb": budget_left})
                        return False
                ok = _reserve(cluster, _t, new_config, cpu, mem)
                if ok:
                    if budget_left is not None:
                        budget_left -= quote_mb
                    return True
                if admission == "preemption":
                    ok, spent, blocked = _preempt_for(
                        _t, new_config, cpu, mem, _w, budget_left)
                    if budget_left is not None:
                        # give-backs moved state whether or not the
                        # request ultimately landed
                        budget_left -= spent
                    _t.scaler.tracer.record(
                        "admission.preempt", "admission",
                        _t.scaler.engine.now, _t.scaler.engine.now,
                        tenant=_t.name, window=_w,
                        args={"admitted": ok, "spent_mb": spent,
                              "blocked": blocked})
                    if ok:
                        return True
                    if blocked:
                        _t.deferrals.append(_w)
                _t.denials.append(_w)
                if _t.first_pending is None:
                    _t.first_pending = _w
                return False

            def hook(eng, _w, _t=t):
                if _t.faults is not None:
                    _t.faults_fired.extend(
                        _t.faults.apply_due(eng, eng.now))

            t.scaler.admission = admit
            t.scaler.step_window(w, target_profile=t.profile,
                                 window_hook=hook)
            # sync the enacted footprint (scale-downs release capacity;
            # admitted scale-ups were already reserved at the quoted size,
            # re-reserving the enacted placement keeps them in lockstep).
            # A failed resync of a footprint that GREW means per-tenant
            # accounting has desynced from reality (the enacted placement
            # differs from the quoted one and no longer fits) — a driver
            # invariant violation, never a legitimate denial, so fail
            # loudly.
            cpu_now, mem_now = t.scaler.resources()
            if not _reserve(cluster, t, None, cpu_now, mem_now) \
                    and not (cluster.shared and _footprint_shrank(cluster,
                                                                  t)):
                # (a shared-TM resync of a footprint that SHRANK may be
                # denied by FFD non-monotonicity — a smaller task list
                # repacking into a larger fleet; the previous, larger
                # reservation stays standing, which never under-states
                # the tenant and is corrected at its next successful
                # reservation)
                raise _desync_error(cluster, t, cpu_now, mem_now)
            if not t.history[-1].denied:
                t.first_pending = None
        for t in tenants:
            row = t.history[-1]
            row.amortized_mb = att_start.get(t.name)
            row.preempted = w in t.preemptions
            # provenance reasons the controller cannot see: a budget
            # deferral upgrades this window's denial, and a preemption
            # victim that did not itself reconfigure was "shrunk"
            if t.deferrals and t.deferrals[-1] == w:
                row.reason = REASON_DEFERRED
            if row.preempted and row.reason in (REASON_STEADY,
                                                REASON_TRIGGERED):
                row.reason = REASON_SHRUNK
        result.usage.append((cluster.cpu_in_use, cluster.mem_in_use))
    return result


# ---------------------------------------------------------------------------
# Vectorized driver — structure-of-arrays fleet state
# ---------------------------------------------------------------------------

class _FleetState:
    """Structure-of-arrays tenant state for the vectorized fleet driver.

    Per-tenant scalars the scalar oracle keeps in dicts and Python lists
    live here as numpy arrays indexed by spec order:

    * ``used_cpu`` / ``used_mem`` — each tenant's current cluster
      attribution (mirrors ``Cluster.used_cpu/used_mem``; maintained
      incrementally on private clusters, refreshed from the dicts after
      shared-TM repacks rewrite everyone's attribution);
    * ``targets`` — each tenant's current target rate;
    * ``first_pending`` — window of the oldest unserved request
      (−1 ≡ none), the ``first_come`` age key;
    * ``denied`` / ``deferred`` / ``preempted`` — (windows, tenants)
      per-window outcome flags;
    * ``attributed`` — (windows, tenants) start-of-window memory
      attribution (what ``HistoryRow.amortized_mb`` reports).

    Arbitration order, fair-share ranking and preemption victim ranking
    are array programs over this state — stable sorts chosen to be
    order-identical to the oracle's ``sorted`` calls.
    """

    def __init__(self, tenants: list[TenantRun], cluster: Cluster,
                 windows: int):
        n = len(tenants)
        self.tenants = tenants
        self.cluster = cluster
        self.n = n
        self.names = [t.name for t in tenants]
        self.used_cpu = np.zeros(n, dtype=np.int64)
        self.used_mem = np.zeros(n, dtype=np.float64)
        self.targets = np.zeros(n, dtype=np.float64)
        self.first_pending = np.full(n, -1, dtype=np.int64)
        self.denied = np.zeros((windows, n), dtype=bool)
        self.deferred = np.zeros((windows, n), dtype=bool)
        self.preempted = np.zeros((windows, n), dtype=bool)
        self.attributed = np.zeros((windows, n), dtype=np.float64)
        self.refresh()

    def refresh(self) -> None:
        """Pull every tenant's attribution from the cluster dicts into
        the arrays — needed after shared-TM repacks, which rewrite all
        co-residents' amortized shares at once."""
        uc, um = self.cluster.used_cpu, self.cluster.used_mem
        self.used_cpu[:] = np.fromiter((uc.get(nm, 0) for nm in self.names),
                                       np.int64, self.n)
        self.used_mem[:] = np.fromiter(
            (um.get(nm, 0.0) for nm in self.names), np.float64, self.n)

    def set_footprint(self, i: int) -> None:
        """Mirror one tenant's cluster attribution into the arrays (the
        private-cluster incremental path: a reserve touches one row)."""
        name = self.names[i]
        self.used_cpu[i] = self.cluster.used_cpu.get(name, 0)
        self.used_mem[i] = self.cluster.used_mem.get(name, 0.0)

    def shares(self) -> np.ndarray:
        """Every tenant's budget share at once — elementwise the same
        arithmetic as ``Cluster.share`` so the two drivers rank
        identically."""
        return np.maximum(
            self.used_cpu / max(self.cluster.cpu_slots, 1),
            self.used_mem / max(self.cluster.memory_mb, 1e-9))

    def order(self, admission: str) -> np.ndarray:
        """This window's arbitration order as tenant indices — the
        vectorized ``_arbitration_order``.  Stable sorts keep ties in
        spec order, exactly like the oracle's Timsort."""
        if admission in ("priority", "preemption"):
            return np.arange(self.n)
        if self.cluster.shared:
            self.refresh()
        if admission == "fair_share":
            return np.argsort(self.shares(), kind="stable")
        if admission == "first_come":
            fp = self.first_pending
            served = fp < 0
            # sorted(key=(t.first_pending is None, t.first_pending or 0))
            return np.lexsort((np.where(served, 0, fp), served))
        raise ValueError(f"unknown admission policy {admission!r} "
                         f"(have: {', '.join(ADMISSION_POLICIES)})")

    def rank_victims(self, req_idx: int, fair: float,
                     exhausted: np.ndarray) -> np.ndarray:
        """Preemption victim ranking: tenants over their fair allotment,
        biggest excess first, spec priority breaking ties (larger index
        = lower priority = shrinks first) — the vectorized form of the
        oracle's ``sort(key=(fair - share, -prio))``."""
        if self.cluster.shared:
            self.refresh()
        sh = self.shares()
        mask = (sh > fair) & ~exhausted
        mask[req_idx] = False
        idx = np.nonzero(mask)[0]
        if idx.size:
            idx = idx[np.lexsort((-idx, fair - sh[idx]))]
        return idx


def _run_vectorized(tenants: list[TenantRun], cluster: Cluster,
                    result: ColocatedResult, *, windows: int, admission: str,
                    migration_budget_mb: float | None,
                    base: ControllerConfig) -> ColocatedResult:
    """The fleet driver: batches each window's bookkeeping across tenants
    (ordering, ranking, flags, attribution as array programs), caches
    admission quotes fleet-wide, and audits the budget once per window —
    decision-identical to :func:`_run_scalar` (see
    tests/test_fleet.py)."""
    fleet = _FleetState(tenants, cluster, windows)
    result.fleet = fleet
    # admission quotes are pure functions of (query, transformed config)
    # on private clusters: one cache shared by the whole fleet turns N
    # bin_packs per window into one per DISTINCT configuration.  (Shared
    # TM quotes depend on every co-resident's task list — uncacheable.)
    if not cluster.shared:
        quote_cache: dict = {}
        mig_cache: dict | None = {}
        for t in tenants:
            t.scaler.quote_cache = quote_cache
    else:
        mig_cache = None

    def _preempt_for(requester: TenantRun, req_idx: int, new_config: dict,
                     cpu, mem, w: int, budget_left: float | None
                     ) -> tuple[bool, float, bool]:
        """Same state machine as the oracle's ``_preempt_for`` (see
        :func:`_run_scalar` for the full commentary); victim ranking and
        preemption marks go through the fleet arrays."""
        fair = 1.0 / max(fleet.n, 1)
        exhausted = np.zeros(fleet.n, dtype=bool)
        spent = 0.0
        blocked = False
        while True:
            for vi in fleet.rank_victims(req_idx, fair, exhausted):
                vi = int(vi)
                victim = tenants[vi]
                sc = victim.scaler
                prop = sc.policy.propose_shrink(sc.flow, sc.cfg)
                if prop is None or prop.config == sc.flow.config():
                    exhausted[vi] = True
                    continue
                gb_mb = 0.0
                if budget_left is not None:
                    gb_mb = _migration_quote(cluster, base, victim,
                                             prop.config, mig_cache)
                    if gb_mb > budget_left - spent + _EPS:
                        blocked = True
                        continue
                if cluster.shared:
                    if not cluster.reserve_tasks(
                            victim.name, sc.task_requests(prop.config)):
                        continue
                elif not cluster.fits(victim.name,
                                      *sc.resources(prop.config)):
                    continue
                shrunk = sc.shrink_memory()
                assert shrunk is not None
                fleet.preempted[w, vi] = True
                if not cluster.shared:
                    freed = cluster.reserve(victim.name, *shrunk)
                    assert freed
                    fleet.set_footprint(vi)
                spent += gb_mb
                if budget_left is not None:
                    req_mb = _migration_quote(cluster, base, requester,
                                              new_config, mig_cache)
                    if req_mb > budget_left - spent + _EPS:
                        return False, spent, True
                else:
                    req_mb = 0.0
                if _reserve(cluster, requester, new_config, cpu, mem):
                    return True, spent + req_mb, False
                break               # shares moved: re-rank the victims
            else:
                return False, spent, blocked

    for w in range(windows):
        # start-of-window attribution snapshot — one array copy instead
        # of the oracle's dict(cluster.used_mem)
        fleet.attributed[w, :] = fleet.used_mem
        budget_left = migration_budget_mb     # per-window allowance
        for i in fleet.order(admission):
            i = int(i)
            t = tenants[i]

            def admit(scaler, new_config, cpu, mem, _t=t, _i=i, _w=w):
                nonlocal budget_left
                quote_mb = 0.0
                if budget_left is not None:
                    quote_mb = _migration_quote(cluster, base, _t,
                                                new_config, mig_cache)
                    if quote_mb > budget_left + _EPS:
                        fleet.deferred[_w, _i] = True
                        fleet.denied[_w, _i] = True
                        if fleet.first_pending[_i] < 0:
                            fleet.first_pending[_i] = _w
                        _t.scaler.tracer.record(
                            "admission.defer", "admission",
                            _t.scaler.engine.now, _t.scaler.engine.now,
                            tenant=_t.name, window=_w,
                            args={"quote_mb": quote_mb,
                                  "budget_left_mb": budget_left})
                        return False
                ok = _reserve(cluster, _t, new_config, cpu, mem)
                if ok:
                    fleet.set_footprint(_i)
                    if budget_left is not None:
                        budget_left -= quote_mb
                    return True
                if admission == "preemption":
                    ok, spent, blocked = _preempt_for(
                        _t, _i, new_config, cpu, mem, _w, budget_left)
                    if budget_left is not None:
                        budget_left -= spent
                    _t.scaler.tracer.record(
                        "admission.preempt", "admission",
                        _t.scaler.engine.now, _t.scaler.engine.now,
                        tenant=_t.name, window=_w,
                        args={"admitted": ok, "spent_mb": spent,
                              "blocked": blocked})
                    if ok:
                        fleet.set_footprint(_i)
                        return True
                    if blocked:
                        fleet.deferred[_w, _i] = True
                fleet.denied[_w, _i] = True
                if fleet.first_pending[_i] < 0:
                    fleet.first_pending[_i] = _w
                return False

            def hook(eng, _w, _t=t):
                if _t.faults is not None:
                    _t.faults_fired.extend(
                        _t.faults.apply_due(eng, eng.now))

            t.scaler.admission = admit
            t.scaler.step_window(w, target_profile=t.profile,
                                 window_hook=hook)
            cpu_now, mem_now = t.scaler.resources()
            if not _reserve(cluster, t, None, cpu_now, mem_now) \
                    and not (cluster.shared and _footprint_shrank(cluster,
                                                                  t)):
                raise _desync_error(cluster, t, cpu_now, mem_now)
            fleet.set_footprint(i)
            fleet.targets[i] = t.scaler.target
            if not t.history[-1].denied:
                fleet.first_pending[i] = -1
        # ONE fleet-level budget audit per window (each Cluster.reserve
        # already asserts the O(1) running totals; this checks the
        # per-tenant array mirror still sums to them)
        if cluster.shared:
            fleet.refresh()
        assert int(fleet.used_cpu.sum()) == cluster.cpu_in_use \
            and mem_close(float(fleet.used_mem.sum()),
                          cluster.mem_in_use, eps=1e-6) \
            and cluster.cpu_in_use <= cluster.cpu_slots \
            and cluster.mem_in_use <= cluster.memory_mb + _EPS, \
            "fleet accounting desync"
        for j, t in enumerate(tenants):
            row = t.history[-1]
            row.amortized_mb = float(fleet.attributed[w, j])
            row.preempted = bool(fleet.preempted[w, j])
            # provenance reasons the controller cannot see: a budget
            # deferral upgrades this window's denial, and a preemption
            # victim that did not itself reconfigure was "shrunk"
            if fleet.deferred[w, j]:
                row.reason = REASON_DEFERRED
            if row.preempted and row.reason in (REASON_STEADY,
                                                REASON_TRIGGERED):
                row.reason = REASON_SHRUNK
        result.usage.append((cluster.cpu_in_use, cluster.mem_in_use))

    # fold the array flags back into the per-tenant lists the scalar API
    # (and every existing consumer) reads
    for j, t in enumerate(tenants):
        t.denials = [int(x) for x in np.nonzero(fleet.denied[:, j])[0]]
        t.deferrals = [int(x) for x in np.nonzero(fleet.deferred[:, j])[0]]
        t.preemptions = [int(x) for x in np.nonzero(fleet.preempted[:, j])[0]]
        fp = int(fleet.first_pending[j])
        t.first_pending = None if fp < 0 else fp
        t.scaler.quote_cache = None
    return result


def run_colocated(specs: list[ColocatedSpec | tuple], cluster: Cluster,
                  *, windows: int = 8, seed: int = 3, max_level: int = 2,
                  admission: str = "priority",
                  cfg: ControllerConfig | None = None,
                  warm: bool = True,
                  reconfig_cost="instant",
                  migration_budget_mb: float | None = None,
                  driver: str = "vectorized",
                  tracer=None
                  ) -> ColocatedResult:
    """Step every episode through ``windows`` decision windows in lockstep,
    arbitrating each window's scale-up requests against ``cluster``'s
    remaining budget.

    ``specs`` entries may be :class:`ColocatedSpec` or bare
    ``(policy, query)`` / ``(policy, query, profile)`` tuples.  ``cfg`` is a
    *template* shared by every tenant; each tenant's policy is constructed
    from the registry by its spec's name (any registered policy works, not
    just ds2/justin).  Episodes whose *initial* placement already exceeds
    the budget raise — a cluster that cannot hold the starting
    configurations is a sizing error, not an admission decision.

    With ``admission="preemption"`` the spec list is the priority order
    for *requests*; victims are selected fair-share (see module
    docstring).  On a shared-TM cluster, footprints are task lists packed
    into one fleet and history rows carry each tenant's amortized
    attribution (``amortized_mb``).

    ``reconfig_cost`` (a mechanism name or
    :class:`repro.migration.CostModel`) attaches a migration runtime to
    every tenant: reconfigurations pause the tenant's engine for their
    priced downtime.  ``migration_budget_mb`` caps the state MB the
    arbiter lets *admissions* move per window, across all tenants: a
    quoted admission whose migration cost would blow the remaining
    window budget is deferred — the ordinary denial/retry path, recorded
    additionally in ``TenantRun.deferrals``.  Preemption give-backs are
    migrations too: they are quoted and charged against the same window
    allowance, and the requester is re-quoted after the give-backs
    re-shape the fleet.  (On private-fleet clusters the quote comes from
    the migration planner over the tenant's own placements; on shared-TM
    clusters from the fleet repack.)

    ``driver`` selects the fleet stepping implementation:
    ``"vectorized"`` (default) batches per-window bookkeeping across
    tenants as numpy array programs and scales to thousand-tenant
    fleets; ``"scalar"`` is the original per-tenant loop, kept as the
    decision-identical oracle.

    ``tracer`` (a ``repro.obs.trace.Tracer``) is shared by every tenant's
    controller: all window/policy/admission/migration spans land in one
    stream, tagged per tenant.  Both drivers emit the same spans — the
    per-tenant summary aggregates are equivalence-tested alongside the
    decisions.
    """
    if admission not in ADMISSION_POLICIES:
        raise ValueError(f"unknown admission policy {admission!r} "
                         f"(have: {', '.join(ADMISSION_POLICIES)})")
    if driver not in DRIVERS:
        raise ValueError(f"unknown driver {driver!r} "
                         f"(have: {', '.join(DRIVERS)})")
    from repro.migration import CostModel
    cost_model = reconfig_cost if isinstance(reconfig_cost, CostModel) \
        else CostModel(mechanism=reconfig_cost)
    specs = [s if isinstance(s, ColocatedSpec) else ColocatedSpec(*s)
             for s in specs]
    base = cfg or ControllerConfig(justin=JustinParams(max_level=max_level))
    tenants = _setup_tenants(specs, cluster, windows=windows, seed=seed,
                             base=base, warm=warm, cost_model=cost_model,
                             tracer=tracer)
    result = ColocatedResult(cluster=cluster, tenants=tenants,
                             admission=admission)
    run = _run_vectorized if driver == "vectorized" else _run_scalar
    return run(tenants, cluster, result, windows=windows,
               admission=admission,
               migration_budget_mb=migration_budget_mb, base=base)
