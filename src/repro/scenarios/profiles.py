"""Declarative target-rate profiles r(t), in events/s over sim-seconds
(the :class:`~repro.streaming.engine.StreamEngine` clock, i.e. ``engine.now``).

Every profile is a frozen dataclass callable ``profile(t) -> float``; the
controller samples it at each decision-window boundary.  Profiles compose
the workload shapes the dynamic-autoscaling literature evaluates against:
constant load, linear ramps, transient spikes, diurnal (day/night) cycles
and sinusoids, plus arbitrary piecewise-constant steps.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Profile:
    """Base: subclasses implement ``rate(t)``; negative rates are clamped."""

    def rate(self, t: float) -> float:
        raise NotImplementedError

    def __call__(self, t: float) -> float:
        return max(0.0, float(self.rate(t)))


@dataclass(frozen=True)
class Constant(Profile):
    """The paper's fixed-target protocol."""
    value: float

    def rate(self, t: float) -> float:
        return self.value


@dataclass(frozen=True)
class Ramp(Profile):
    """Linear ramp from ``start`` to ``end`` over [t0, t0 + duration_s],
    flat on both sides."""
    start: float
    end: float
    duration_s: float
    t0: float = 0.0

    def rate(self, t: float) -> float:
        if t <= self.t0:
            return self.start
        if t >= self.t0 + self.duration_s:
            return self.end
        frac = (t - self.t0) / self.duration_s
        return self.start + frac * (self.end - self.start)


@dataclass(frozen=True)
class Spike(Profile):
    """``base`` load with a flat transient burst of ``peak`` during
    [t0, t0 + duration_s] — the flash-crowd case."""
    base: float
    peak: float
    t0: float
    duration_s: float

    def rate(self, t: float) -> float:
        return self.peak if self.t0 <= t < self.t0 + self.duration_s \
            else self.base


@dataclass(frozen=True)
class Diurnal(Profile):
    """Raised-cosine day/night cycle between ``low`` (at t=0, "midnight")
    and ``high`` (half a period later) with period ``period_s``."""
    low: float
    high: float
    period_s: float
    phase_s: float = 0.0

    def rate(self, t: float) -> float:
        c = math.cos(2 * math.pi * (t + self.phase_s) / self.period_s)
        return self.low + (self.high - self.low) * (1.0 - c) / 2.0


@dataclass(frozen=True)
class Sinusoid(Profile):
    """``mean`` ± ``amplitude`` sinusoid with period ``period_s``."""
    mean: float
    amplitude: float
    period_s: float
    phase_s: float = 0.0

    def rate(self, t: float) -> float:
        return self.mean + self.amplitude * math.sin(
            2 * math.pi * (t + self.phase_s) / self.period_s)


@dataclass(frozen=True)
class Step(Profile):
    """Piecewise-constant: ``rates[i]`` applies from ``times[i]`` until
    ``times[i+1]`` (``rates[0]`` before ``times[0]`` is never consulted —
    supply ``times[0] == 0``).  ``times`` must be ascending."""
    times: tuple = field(default=(0.0,))
    rates: tuple = field(default=(0.0,))

    def __post_init__(self):
        if len(self.times) != len(self.rates) or not self.times:
            raise ValueError("times and rates must be equal-length, nonempty")
        if list(self.times) != sorted(self.times):
            raise ValueError("times must be ascending")

    def rate(self, t: float) -> float:
        idx = 0
        for i, t0 in enumerate(self.times):
            if t >= t0:
                idx = i
        return self.rates[idx]


def make_profile(name: str, target: float, horizon_s: float) -> Profile:
    """Named profile scaled to a query's target rate — the shapes the
    CLI/benchmarks expose.  ``horizon_s`` is the scenario length used to
    place ramps/spikes/cycles."""
    if name == "constant":
        return Constant(target)
    if name == "ramp":
        return Ramp(start=0.4 * target, end=target,
                    duration_s=0.6 * horizon_s)
    if name == "spike":
        return Spike(base=0.5 * target, peak=target,
                     t0=0.3 * horizon_s, duration_s=0.4 * horizon_s)
    if name == "diurnal":
        return Diurnal(low=0.3 * target, high=target, period_s=horizon_s)
    if name == "sinusoid":
        return Sinusoid(mean=0.7 * target, amplitude=0.3 * target,
                        period_s=0.5 * horizon_s)
    if name == "step":
        return Step(times=(0.0, 0.4 * horizon_s, 0.8 * horizon_s),
                    rates=(0.5 * target, target, 0.7 * target))
    raise ValueError(f"unknown profile {name!r} "
                     f"(have: constant ramp spike diurnal sinusoid step)")
