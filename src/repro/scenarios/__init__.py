"""Scenario vocabulary for autoscaling experiments.

Declarative rate profiles (constant, ramp, spike, diurnal, sinusoid, step),
fault/straggler injection schedules, and a ``run_scenario`` driver that runs
a policy against a Nexmark query under a time-varying workload and returns
the controller history — the Daedalus/Phoebe-style dynamic evaluations the
paper's fixed-rate protocol doesn't cover.
"""
from repro.scenarios.faults import (FaultSchedule, KillTask, SetStraggler,
                                    parse_fault)
from repro.scenarios.profiles import (Constant, Diurnal, Profile, Ramp,
                                      Sinusoid, Spike, Step, make_profile)
from repro.scenarios.runner import ScenarioResult, run_scenario

__all__ = [
    "Constant", "Diurnal", "Profile", "Ramp", "Sinusoid", "Spike", "Step",
    "make_profile", "FaultSchedule", "KillTask", "SetStraggler",
    "parse_fault", "ScenarioResult", "run_scenario",
]
