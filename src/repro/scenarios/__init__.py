"""Scenario vocabulary for autoscaling experiments.

Declarative rate profiles (constant, ramp, spike, diurnal, sinusoid, step),
fault/straggler injection schedules, and a ``run_scenario`` driver that runs
a policy against a Nexmark query under a time-varying workload and returns
the controller history — the Daedalus/Phoebe-style dynamic evaluations the
paper's fixed-rate protocol doesn't cover.

On top of the single-episode driver sit three layers (see
docs/architecture.md):

* ``metrics`` — SLO scorecards over controller histories (violation
  windows, catch-up time, p95 backlog, resource-time integrals);
* ``cluster`` — a shared finite ``Cluster`` budget plus ``run_colocated``,
  stepping N (policy, query, profile) episodes in lockstep with per-window
  admission arbitration (priority / fair_share / first_come / preemption),
  with a vectorized structure-of-arrays fleet driver (and the original
  scalar loop as its decision-identical oracle);
* ``population`` — fleet-scale tenant sampling (heavy-tailed rates,
  staggered diurnal phases, flash crowds, faults) plus ``run_fleet``;
* ``grid`` — the {policy} × {profile} × {query} evaluation grid behind
  ``benchmarks/nexmark_eval.py --grid``.
"""
from repro.scenarios.cluster import (ADMISSION_POLICIES, DRIVERS, Cluster,
                                     ColocatedResult, ColocatedSpec,
                                     TenantRun, run_colocated)
from repro.scenarios.faults import (FaultSchedule, KillTask, SetStraggler,
                                    parse_fault)
from repro.scenarios.grid import (colocation_markdown, comparison_rows,
                                  grid_markdown, run_colocation, run_grid)
from repro.scenarios.metrics import (CatchUp, SLOReport,
                                     amortized_mb_windows,
                                     catch_up_episodes, catch_up_time_s,
                                     p95_backlog, resource_integrals,
                                     slo_report, violation_windows)
from repro.scenarios.population import (PopulationSpec, fleet_cfg,
                                        fleet_stats, run_fleet,
                                        sample_population, size_cluster)
from repro.scenarios.profiles import (Constant, Diurnal, Profile, Ramp,
                                      Sinusoid, Spike, Step, make_profile)
from repro.scenarios.runner import ScenarioResult, run_scenario

__all__ = [
    "Constant", "Diurnal", "Profile", "Ramp", "Sinusoid", "Spike", "Step",
    "make_profile", "FaultSchedule", "KillTask", "SetStraggler",
    "parse_fault", "ScenarioResult", "run_scenario",
    "CatchUp", "SLOReport", "catch_up_episodes", "catch_up_time_s",
    "p95_backlog", "resource_integrals", "slo_report", "violation_windows",
    "amortized_mb_windows",
    "ADMISSION_POLICIES", "DRIVERS", "Cluster", "ColocatedResult",
    "ColocatedSpec", "TenantRun", "run_colocated",
    "PopulationSpec", "fleet_cfg", "fleet_stats", "run_fleet",
    "sample_population", "size_cluster",
    "colocation_markdown", "comparison_rows", "grid_markdown",
    "run_colocation", "run_grid",
]
