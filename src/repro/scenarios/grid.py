"""Policy × profile × query evaluation grid.

Runs every registered scaling policy (``repro.core.policy``; ds2, justin,
static, threshold out of the box) × {rate profiles} × {queries} through
``run_scenario`` and reduces each episode to its SLO scorecard
(``scenarios.metrics``): steps to converge, SLO-violation count, worst
catch-up time, and the CPU/memory resource-time integrals — the axes
Daedalus/Phoebe-style evaluations compare autoscalers on, and the ones the
paper's "fewer total cluster resources" claim lives on.

``benchmarks/nexmark_eval.py --grid`` is the CLI front end; the JSON it
writes feeds ``benchmarks/render_experiments.py`` (tables + per-query
plots), and :func:`grid_markdown` renders the same data as README-ready
tables: one all-policies cell table plus the ds2-vs-justin savings
comparison when both are present.

``--grid --admission <mode>`` adds a **co-location section**
(:func:`run_colocation`): per query, the ds2/justin pair competing on one
shared-TM cluster under the chosen admission mode.  Its savings table
carries the admission-aware-placement axes — per-tenant amortized-memory
integrals (base_mb amortized across co-resident TMs), a preemption column
(forced give-backs suffered), and the shared-fleet vs
sum-of-private-fleets memory saving.

``--reconfig-cost {instant,savepoint,handoff}`` makes every episode PAY
for its reconfigurations (``repro.migration``): cells gain downtime
windows / paused seconds / moved-MB columns and :func:`reconfig_markdown`
renders the headline comparison.  ``--migration-budget-mb`` caps the
state MB co-location admissions may move per window (deferrals reuse the
denial/retry path).
"""
from __future__ import annotations

from repro.core.policy import available_policies
from repro.data.nexmark import QUERIES
from repro.scenarios.metrics import DEFAULT_SLACK, slo_report
from repro.scenarios.runner import run_scenario

PROFILES = ("constant", "ramp", "spike", "diurnal", "sinusoid", "step")
# the pair the savings comparison (and the paper's Fig. 5) is built on
BASELINE, CONTENDER = "ds2", "justin"


def run_colocation(queries=None, admission: str = "preemption", *,
                   windows: int = 5, seed: int = 3, max_level: int = 2,
                   cpu_slots: int = 0, memory_mb: float = 0.0,
                   slack: float = DEFAULT_SLACK,
                   reconfig_cost: str = "instant",
                   migration_budget_mb: float | None = None,
                   driver: str = "vectorized",
                   verbose: bool = True) -> list[dict]:
    """Per query: the ds2/justin pair competing on ONE shared-TM cluster
    under ``admission`` (ds2 is the higher-priority tenant, so under
    ``"preemption"`` its denied scale-outs may reclaim the justin
    neighbor's storage levels).  ``cpu_slots``/``memory_mb`` of 0 auto-size
    the budget from the pair's initial placements (2x the slots, 1.5x the
    memory — room to grow, but contended).  Returns one cell per query
    with per-tenant SLO scorecards (incl. amortized-MB integrals and
    preemption counts) plus the shared-vs-private memory saving."""
    from repro.core.controller import ControllerConfig
    from repro.core.justin import JustinParams
    from repro.core.placement import default_tm_spec, placement_for_config
    from repro.core.policy import make_policy
    from repro.scenarios.cluster import (Cluster, ColocatedSpec,
                                         run_colocated)
    queries = list(queries or QUERIES)
    cells = []
    for qname in queries:
        cfg = ControllerConfig(justin=JustinParams(max_level=max_level))
        specs = [ColocatedSpec(BASELINE, qname, name="hi"),
                 ColocatedSpec(CONTENDER, qname, name="lo")]
        # auto-size from the pair's initial private footprints — straight
        # placement quotes over the query's starting config, no engines
        flow = QUERIES[qname]()
        cpu0, mem0 = 0, 0.0
        for pol in (BASELINE, CONTENDER):
            quote = placement_for_config(
                make_policy(pol, cfg).resources_config(flow.config()),
                base_mem_mb=cfg.base_mem_mb,
                exclude=set(flow.sources()))
            cpu0 += quote.cpu_cores
            mem0 += quote.memory_mb
        slots = cpu_slots or 2 * cpu0
        mem = memory_mb or 1.5 * mem0
        cluster = Cluster(slots, mem,
                          tm_spec=default_tm_spec(cfg.base_mem_mb))
        res = run_colocated(specs, cluster, windows=windows, seed=seed,
                            admission=admission, cfg=cfg,
                            reconfig_cost=reconfig_cost,
                            migration_budget_mb=migration_budget_mb,
                            driver=driver)
        # both integrals quote the config running during each window:
        # private fleets vs the tenant's amortized shared-TM attribution
        shared_mb_w = sum(t.slo(slack).amortized_mb_windows
                          for t in res.tenants)
        private_mb_w = sum(t.slo(slack).mb_windows for t in res.tenants)
        cell = {"query": qname, "admission": admission,
                "cluster": {"cpu_slots": slots, "memory_mb": mem,
                            "shared_tm": True},
                "reconfig_cost": reconfig_cost,
                "migration_budget_mb": migration_budget_mb,
                "tenants": {t.name: {
                    "policy": t.spec.policy,
                    "denied": len(t.denials),
                    "preempted": len(t.preemptions),
                    "deferred": len(t.deferrals),
                    "slo": t.slo(slack).to_dict()} for t in res.tenants},
                "shared_mb_windows": shared_mb_w,
                "private_mb_windows": private_mb_w,
                "shared_mem_saving": 1 - shared_mb_w
                / max(private_mb_w, 1e-9)}
        mig = cluster.migration_total()
        cell["migration"] = {"tasks_moved": mig.tasks_moved,
                             "state_mb": mig.state_mb}
        cells.append(cell)
        if verbose:
            ten = cell["tenants"]
            print(f"{qname:4s} colocated {admission:10s} "
                  f"denied={[ten[n]['denied'] for n in ten]} "
                  f"preempted={[ten[n]['preempted'] for n in ten]} "
                  f"shared_saving={cell['shared_mem_saving']:.0%}",
                  flush=True)
    return cells


def run_grid(queries=None, profiles=None, policies=None, *,
             windows: int = 8, seed: int = 3, max_level: int = 2,
             slack: float = DEFAULT_SLACK, verbose: bool = True,
             admission: str | None = None, windows_colocated: int = 5,
             cluster_slots: int = 0, cluster_mb: float = 0.0,
             reconfig_cost: str = "instant",
             migration_budget_mb: float | None = None,
             driver: str = "vectorized") -> dict:
    """Run the full grid; returns ``{"cells": [...], "meta": {...}}`` where
    each cell is one (policy, query, profile) episode's summary + SLO
    scorecard.  ``policies`` defaults to every registered policy.  With
    ``admission`` set, a ``"colocation"`` section is added (see
    :func:`run_colocation`).

    ``reconfig_cost`` selects the reconfiguration mechanism every episode
    pays (``instant`` — the free default — or ``savepoint``/``handoff``;
    see ``repro.migration``): cells then carry downtime-window counts,
    total paused seconds and the moved-MB integral in their scorecards.
    ``migration_budget_mb`` caps the state MB the co-location arbiter
    lets admissions move per window (requires ``admission``)."""
    queries = list(queries or QUERIES)
    profiles = list(profiles or PROFILES)
    policies = list(policies or available_policies())
    cells = []
    for qname in queries:
        for prof in profiles:
            for policy in policies:
                res = run_scenario(policy, qname, prof, windows=windows,
                                   seed=seed, max_level=max_level,
                                   reconfig_cost=reconfig_cost)
                rep = slo_report(res.history, slack)
                cell = {"policy": policy, "query": qname, "profile": prof,
                        "steps": res.steps,
                        "final_cpu": res.final.cpu_cores,
                        "final_mem": res.final.memory_mb,
                        "slo": rep.to_dict()}
                cells.append(cell)
                if verbose:
                    cu = rep.catch_up_s
                    print(f"{qname:4s} {prof:8s} {policy:9s} "
                          f"steps={res.steps} viol={rep.violations} "
                          f"catchup={'-' if cu is None else f'{cu:.0f}s'} "
                          f"cpu_w={rep.cpu_slot_windows} "
                          f"mb_w={rep.mb_windows:,.0f} "
                          f"down_w={rep.downtime_windows}", flush=True)
    out = {"cells": cells,
           "meta": {"queries": queries, "profiles": profiles,
                    "policies": list(policies), "windows": windows,
                    "seed": seed, "max_level": max_level, "slack": slack,
                    "admission": admission,
                    "reconfig_cost": reconfig_cost,
                    "migration_budget_mb": migration_budget_mb}}
    if admission is not None:
        out["colocation"] = run_colocation(
            queries, admission, windows=windows_colocated, seed=seed,
            max_level=max_level, cpu_slots=cluster_slots,
            memory_mb=cluster_mb, slack=slack,
            reconfig_cost=reconfig_cost,
            migration_budget_mb=migration_budget_mb, driver=driver,
            verbose=verbose)
    return out


def grid_cell(grid: dict, policy: str, query: str, profile: str) -> dict | None:
    """The (policy, query, profile) cell of a ``run_grid`` result, or None
    — shared with benchmarks/render_experiments.py."""
    for c in grid["cells"]:
        if (c["policy"], c["query"], c["profile"]) == (policy, query,
                                                       profile):
            return c
    return None


def comparison_rows(grid: dict) -> list[dict]:
    """One row per (query, profile): ds2 vs justin on every SLO axis, plus
    the resource-integral savings justin achieved.  Empty when the grid
    was run without the ds2/justin pair."""
    rows = []
    for q in grid["meta"]["queries"]:
        for prof in grid["meta"]["profiles"]:
            d = grid_cell(grid, BASELINE, q, prof)
            j = grid_cell(grid, CONTENDER, q, prof)
            if d is None or j is None:
                continue
            row = {"query": q, "profile": prof}
            for tag, c in ((BASELINE, d), (CONTENDER, j)):
                row[f"{tag}_steps"] = c["steps"]
                row[f"{tag}_viol"] = c["slo"]["violations"]
                row[f"{tag}_catchup_s"] = c["slo"]["catch_up_s"]
                row[f"{tag}_cpu_w"] = c["slo"]["cpu_slot_windows"]
                row[f"{tag}_mb_w"] = c["slo"]["mb_windows"]
            row["cpu_w_saving"] = 1 - row["justin_cpu_w"] \
                / max(row["ds2_cpu_w"], 1)
            row["mb_w_saving"] = 1 - row["justin_mb_w"] \
                / max(row["ds2_mb_w"], 1e-9)
            rows.append(row)
    return rows


def _fmt_catchup(v) -> str:
    return "-" if v is None else f"{v:.0f}s"


def cells_markdown(grid: dict) -> str:
    """Every (query, profile, policy) cell as one table row — works for any
    policy set, which is what ``--grid`` runs by default."""
    out = ["| query | profile | policy | steps | SLO viol | catch-up | "
           "CPU-slot-w | MB-w |",
           "|" + "---|" * 8]
    for q in grid["meta"]["queries"]:
        for prof in grid["meta"]["profiles"]:
            for pol in grid["meta"]["policies"]:
                c = grid_cell(grid, pol, q, prof)
                if c is None:
                    continue
                s = c["slo"]
                out.append(
                    f"| {q} | {prof} | {pol} | {c['steps']} "
                    f"| {s['violations']} | {_fmt_catchup(s['catch_up_s'])} "
                    f"| {s['cpu_slot_windows']} | {s['mb_windows']:,.0f} |")
    return "\n".join(out)


def colocation_markdown(cells: list[dict]) -> str:
    """The co-location savings table: per tenant the denials/preemptions/
    budget-deferrals and both memory integrals (private quote vs
    amortized shared-TM attribution), per cell the shared-fleet saving
    over private fleets."""
    out = ["| query | admission | tenant | policy | denied | preempted | "
           "deferred | recovered | MB-w private | MB-w amortized | "
           "shared saving |",
           "|" + "---|" * 11]
    for c in cells:
        for name, t in c["tenants"].items():
            s = t["slo"]
            out.append(
                f"| {c['query']} | {c['admission']} | {name} "
                f"| {t['policy']} | {t['denied']} | {t['preempted']} "
                f"| {t.get('deferred', 0)} "
                f"| {s['recovered']} | {s['mb_windows']:,.0f} "
                f"| {s['amortized_mb_windows']:,.0f} "
                f"| {c['shared_mem_saving']:.0%} |")
    return "\n".join(out)


def reconfig_markdown(grid: dict) -> str:
    """The reconfiguration-cost headline table: per (query, profile,
    policy) the price of churn under the grid's mechanism — steps taken,
    windows paused by a reconfiguration, total paused paper-seconds, and
    the state MB moved.  This is where a churn-happy policy (threshold's
    doubling ratchet) pays for its extra reconfigurations in downtime
    while justin's fewer steps win, and where ``handoff`` makes
    memory-only adjustments near-free."""
    mech = grid["meta"].get("reconfig_cost", "instant")
    out = [f"Reconfiguration cost (mechanism: `{mech}`)", "",
           "| query | profile | policy | steps | downtime windows | "
           "downtime s | moved MB |",
           "|" + "---|" * 7]
    for q in grid["meta"]["queries"]:
        for prof in grid["meta"]["profiles"]:
            for pol in grid["meta"]["policies"]:
                c = grid_cell(grid, pol, q, prof)
                if c is None:
                    continue
                s = c["slo"]
                out.append(
                    f"| {q} | {prof} | {pol} | {c['steps']} "
                    f"| {s['downtime_windows']} | {s['downtime_s']:,.0f} "
                    f"| {s['moved_mb']:,.0f} |")
    return "\n".join(out)


def grid_markdown(grid: dict) -> str:
    """Render the grid as GitHub-flavored markdown: the all-policies cell
    table, plus the ds2-vs-justin savings comparison when both ran."""
    parts = [cells_markdown(grid)]
    if grid["meta"].get("reconfig_cost", "instant") != "instant":
        parts.append(reconfig_markdown(grid))
    if grid.get("colocation"):
        parts.append(colocation_markdown(grid["colocation"]))
    rows = comparison_rows(grid)
    if rows:
        head = ("| query | profile | steps d/j | SLO viol d/j | "
                "catch-up d/j | CPU-slot-w d/j | MB-w d/j | "
                "CPU saving | MEM saving |")
        sep = "|" + "---|" * 9
        out = [head, sep]
        for r in rows:
            out.append(
                f"| {r['query']} | {r['profile']} "
                f"| {r['ds2_steps']}/{r['justin_steps']} "
                f"| {r['ds2_viol']}/{r['justin_viol']} "
                f"| {_fmt_catchup(r['ds2_catchup_s'])}"
                f"/{_fmt_catchup(r['justin_catchup_s'])} "
                f"| {r['ds2_cpu_w']}/{r['justin_cpu_w']} "
                f"| {r['ds2_mb_w']:,.0f}/{r['justin_mb_w']:,.0f} "
                f"| {r['cpu_w_saving']:.0%} | {r['mb_w_saving']:.0%} |")
        parts.append("\n".join(out))
    return "\n\n".join(parts)
