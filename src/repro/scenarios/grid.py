"""Policy × profile × query evaluation grid.

Runs {ds2, justin} × {rate profiles} × {queries} through ``run_scenario``
and reduces each episode to its SLO scorecard (``scenarios.metrics``),
then lays the results out as ds2-vs-justin comparison rows: steps to
converge, SLO-violation count, worst catch-up time, and the CPU/memory
resource-time integrals — the axes Daedalus/Phoebe-style evaluations
compare autoscalers on, and the ones the paper's "fewer total cluster
resources" claim lives on.

``benchmarks/nexmark_eval.py --grid`` is the CLI front end; the JSON it
writes feeds plots, and :func:`grid_markdown` renders the same data as a
README-ready table.
"""
from __future__ import annotations

from repro.data.nexmark import QUERIES
from repro.scenarios.metrics import DEFAULT_SLACK, slo_report
from repro.scenarios.runner import run_scenario

POLICIES = ("ds2", "justin")
PROFILES = ("constant", "ramp", "spike", "diurnal", "sinusoid", "step")


def run_grid(queries=None, profiles=None, policies=POLICIES, *,
             windows: int = 8, seed: int = 3, max_level: int = 2,
             slack: float = DEFAULT_SLACK, verbose: bool = True) -> dict:
    """Run the full grid; returns ``{"cells": [...], "meta": {...}}`` where
    each cell is one (policy, query, profile) episode's summary + SLO
    scorecard."""
    queries = list(queries or QUERIES)
    profiles = list(profiles or PROFILES)
    cells = []
    for qname in queries:
        for prof in profiles:
            for policy in policies:
                res = run_scenario(policy, qname, prof, windows=windows,
                                   seed=seed, max_level=max_level)
                rep = slo_report(res.history, slack)
                cell = {"policy": policy, "query": qname, "profile": prof,
                        "steps": res.steps,
                        "final_cpu": res.final.cpu_cores,
                        "final_mem": res.final.memory_mb,
                        "slo": rep.to_dict()}
                cells.append(cell)
                if verbose:
                    cu = rep.catch_up_s
                    print(f"{qname:4s} {prof:8s} {policy:6s} "
                          f"steps={res.steps} viol={rep.violations} "
                          f"catchup={'-' if cu is None else f'{cu:.0f}s'} "
                          f"cpu_w={rep.cpu_slot_windows} "
                          f"mb_w={rep.mb_windows:,.0f}", flush=True)
    return {"cells": cells,
            "meta": {"queries": queries, "profiles": profiles,
                     "policies": list(policies), "windows": windows,
                     "seed": seed, "max_level": max_level, "slack": slack}}


def _cell(grid: dict, policy: str, query: str, profile: str) -> dict | None:
    for c in grid["cells"]:
        if (c["policy"], c["query"], c["profile"]) == (policy, query,
                                                       profile):
            return c
    return None


def comparison_rows(grid: dict) -> list[dict]:
    """One row per (query, profile): ds2 vs justin on every SLO axis, plus
    the resource-integral savings justin achieved."""
    rows = []
    for q in grid["meta"]["queries"]:
        for prof in grid["meta"]["profiles"]:
            d = _cell(grid, "ds2", q, prof)
            j = _cell(grid, "justin", q, prof)
            if d is None or j is None:
                continue
            row = {"query": q, "profile": prof}
            for tag, c in (("ds2", d), ("justin", j)):
                row[f"{tag}_steps"] = c["steps"]
                row[f"{tag}_viol"] = c["slo"]["violations"]
                row[f"{tag}_catchup_s"] = c["slo"]["catch_up_s"]
                row[f"{tag}_cpu_w"] = c["slo"]["cpu_slot_windows"]
                row[f"{tag}_mb_w"] = c["slo"]["mb_windows"]
            row["cpu_w_saving"] = 1 - row["justin_cpu_w"] \
                / max(row["ds2_cpu_w"], 1)
            row["mb_w_saving"] = 1 - row["justin_mb_w"] \
                / max(row["ds2_mb_w"], 1e-9)
            rows.append(row)
    return rows


def grid_markdown(grid: dict) -> str:
    """Render the comparison as a GitHub-flavored markdown table."""
    rows = comparison_rows(grid)
    head = ("| query | profile | steps d/j | SLO viol d/j | "
            "catch-up d/j | CPU-slot-w d/j | MB-w d/j | "
            "CPU saving | MEM saving |")
    sep = "|" + "---|" * 9
    out = [head, sep]

    def cu(v):
        return "-" if v is None else f"{v:.0f}s"

    for r in rows:
        out.append(
            f"| {r['query']} | {r['profile']} "
            f"| {r['ds2_steps']}/{r['justin_steps']} "
            f"| {r['ds2_viol']}/{r['justin_viol']} "
            f"| {cu(r['ds2_catchup_s'])}/{cu(r['justin_catchup_s'])} "
            f"| {r['ds2_cpu_w']}/{r['justin_cpu_w']} "
            f"| {r['ds2_mb_w']:,.0f}/{r['justin_mb_w']:,.0f} "
            f"| {r['cpu_w_saving']:.0%} | {r['mb_w_saving']:.0%} |")
    return "\n".join(out)
