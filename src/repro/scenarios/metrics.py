"""SLO metrics over controller histories.

The controller's ``HistoryRow`` series (one row per decision window) is the
raw signal Fig. 5 plots; this module reduces it to the quality-of-service
numbers the dynamic-autoscaling literature (Daedalus, Phoebe) compares
policies on:

* **violation windows** — windows whose achieved source rate fell below
  ``slack * target`` (the paper's 97% convergence criterion, applied per
  window instead of only at the end);
* **catch-up time** — after a violation onset (a spike, fault, or cold
  start), how long until the first window back above the slack line;
* **p95 backlog** — tail of the queued-event backlog series, the
  user-visible latency proxy;
* **resource-time integrals** — CPU-slot-windows and MB-windows, the
  "total cluster resources spent" axis on which Justin's hybrid scaling
  claims to beat DS2's CPU-only packages; on shared-TM clusters the
  amortized-MB integral prices each window at the tenant's attribution
  (base_mb split across co-residents) instead of a private fleet's quote;
* **admission outcomes** — denied windows (requests the cluster rejected)
  and preempted windows (forced memory give-backs suffered under
  ``admission="preemption"``);
* **reconfiguration cost** — downtime windows (windows whose
  reconfiguration paused the job), total paused paper-seconds, and the
  moved-MB integral (state that physically travelled), priced by the
  migration runtime (``repro.migration``); all zero under the default
  ``instant`` mechanism.

Everything is computed from plain ``HistoryRow`` lists, so the same
functions serve single-episode scenarios, co-located cluster runs, and the
policy×profile evaluation grid.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field

DEFAULT_SLACK = 0.97      # the paper's "supports the target rate" criterion


def violation_windows(history: list, slack: float = DEFAULT_SLACK
                      ) -> list[int]:
    """Indices of windows where achieved_rate < slack * target."""
    return [i for i, h in enumerate(history)
            if h.achieved_rate < slack * h.target]


@dataclass(frozen=True)
class CatchUp:
    """One violation episode: onset window through first recovered window.
    ``recovered_window is None`` means the episode never caught back up
    within the history (``duration_s`` then spans to the history's end)."""
    onset_window: int
    recovered_window: int | None
    duration_s: float

    @property
    def recovered(self) -> bool:
        return self.recovered_window is not None


def catch_up_episodes(history: list, slack: float = DEFAULT_SLACK,
                      after_t: float = 0.0) -> list[CatchUp]:
    """Group consecutive violation windows into episodes and measure each
    episode's catch-up time: sim-seconds from the first violating window's
    timestamp to the first subsequent window back above ``slack*target``.

    ``after_t`` restricts to episodes whose onset is at or after that time
    (e.g. pass the spike's t0 to exclude the cold-start transient).
    """
    bad = set(violation_windows(history, slack))
    episodes: list[CatchUp] = []
    i = 0
    while i < len(history):
        if i not in bad:
            i += 1
            continue
        if history[i].t < after_t:
            # an episode whose onset precedes after_t is excluded whole —
            # its tail must not re-enter as a fresh (truncated) episode
            while i < len(history) and i in bad:
                i += 1
            continue
        onset = i
        while i < len(history) and i in bad:
            i += 1
        if i < len(history):
            episodes.append(CatchUp(onset, i, history[i].t
                                    - history[onset].t))
        else:
            # still violating at the history's end: the violation persisted
            # through the last window, so the open-ended duration extends
            # one window past it — an episode spanning k windows never
            # scores better than a recovered episode spanning k windows
            episodes.append(CatchUp(onset, None, history[-1].t
                                    - history[onset].t
                                    + _mean_window_s(history)))
    return episodes


def _mean_window_s(history: list) -> float:
    """Mean decision-window spacing of a history (row timestamps are
    window-end times).  A single row carries no spacing information —
    its ``t`` is the episode's absolute start offset, NOT a window span,
    and returning it inflated a 1-window open-ended violation's catch-up
    to its onset time — so the spacing degrades to 0 rather than
    guessing."""
    if len(history) > 1:
        return (history[-1].t - history[0].t) / (len(history) - 1)
    return 0.0


def catch_up_time_s(history: list, slack: float = DEFAULT_SLACK,
                    after_t: float = 0.0) -> float | None:
    """Worst-case catch-up time across violation episodes (sim-seconds);
    ``None`` when the history has no violations after ``after_t``.  An
    episode still violating at the history's end counts with its open-ended
    duration — a policy that never recovers must not score better than one
    that recovers slowly."""
    eps = catch_up_episodes(history, slack, after_t)
    return max(e.duration_s for e in eps) if eps else None


def p95_backlog(history: list) -> float:
    """95th percentile of the queued-event backlog series (linear
    interpolation, no numpy dependency for a 3-line quantile)."""
    xs = sorted(h.backlog for h in history)
    if not xs:
        return 0.0
    pos = 0.95 * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (pos - lo) * (xs[hi] - xs[lo])


def resource_integrals(history: list) -> tuple[int, float]:
    """(CPU-slot-windows, MB-windows): resources held, summed over decision
    windows.  One row == one window, so the sums are discrete integrals of
    the Fig. 5 resource curves — the efficiency axis on which hybrid
    scaling's "fewer total cluster resources" claim is settled."""
    return (sum(h.cpu_cores for h in history),
            sum(h.memory_mb for h in history))


def amortized_mb_windows(history: list) -> float:
    """Amortized-MB-windows: the memory integral under shared-TM
    attribution (each window's ``amortized_mb`` — the tenant's managed
    grants plus its slot-proportional share of co-resident TMs' base
    memory).  Falls back to the private ``memory_mb`` for windows without
    an attribution (single-tenant histories, scalar-footprint clusters),
    so private and shared runs stay directly comparable."""
    return sum(h.memory_mb if getattr(h, "amortized_mb", None) is None
               else h.amortized_mb for h in history)


def reconfig_cost_totals(history: list) -> tuple[int, float, float]:
    """(downtime windows, total downtime paper-s, moved-MB integral):
    the reconfiguration-cost axes of a history.  A *downtime window* is a
    window whose reconfiguration paused the job (``reconfig_downtime``
    > 0) — churn-happy policies accumulate them even when each pause is
    short; the moved-MB integral is the total state that physically
    travelled.  All zero for histories run without a migration runtime
    (or under the ``instant`` mechanism)."""
    down = [getattr(h, "reconfig_downtime", 0.0) for h in history]
    return (sum(1 for d in down if d > 0), sum(down),
            sum(getattr(h, "moved_mb", 0.0) for h in history))


@dataclass(frozen=True)
class SLOReport:
    """Per-episode SLO scorecard; ``slo_report`` builds it."""
    windows: int
    violations: int                  # count of violating windows
    violation_windows: tuple         # their indices
    catch_up_s: float | None         # worst episode, None if no violations
    recovered: bool                  # above the slack line at the end
    p95_backlog: float
    cpu_slot_windows: int
    mb_windows: float
    amortized_mb_windows: float      # shared-TM attribution integral
                                     # (== mb_windows on private placements)
    denied_windows: int              # admission rejections (co-location)
    preempted_windows: int           # forced memory give-backs suffered
    downtime_windows: int            # windows whose reconfiguration paused
                                     # the job (migration runtime)
    downtime_s: float                # total paused paper-seconds
    moved_mb: float                  # state-moved integral across windows
    slack: float
    violations_by_reason: dict = field(default_factory=dict)
                                     # violating windows grouped by their
                                     # HistoryRow.reason (obs.provenance
                                     # enum): was the SLO missed while
                                     # steady, denied, deferred, shrunk,
                                     # or mid-reconfiguration?

    def to_dict(self) -> dict:
        d = asdict(self)
        d["violation_windows"] = list(d["violation_windows"])
        return d


def slo_report(history: list, slack: float = DEFAULT_SLACK,
               after_t: float = 0.0) -> SLOReport:
    """The full scorecard for one controller history."""
    bad = violation_windows(history, slack)
    cpu_w, mb_w = resource_integrals(history)
    down_w, down_s, moved = reconfig_cost_totals(history)
    by_reason: dict[str, int] = {}
    for i in bad:
        r = getattr(history[i], "reason", "steady")
        by_reason[r] = by_reason.get(r, 0) + 1
    last = history[-1] if history else None
    return SLOReport(
        windows=len(history),
        violations=len(bad),
        violation_windows=tuple(bad),
        catch_up_s=catch_up_time_s(history, slack, after_t),
        recovered=(last is not None
                   and last.achieved_rate >= slack * last.target),
        p95_backlog=p95_backlog(history),
        cpu_slot_windows=cpu_w,
        mb_windows=mb_w,
        amortized_mb_windows=amortized_mb_windows(history),
        denied_windows=sum(1 for h in history if h.denied),
        preempted_windows=sum(1 for h in history
                              if getattr(h, "preempted", False)),
        downtime_windows=down_w,
        downtime_s=down_s,
        moved_mb=moved,
        slack=slack,
        violations_by_reason={k: by_reason[k] for k in sorted(by_reason)})
