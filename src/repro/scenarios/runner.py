"""``run_scenario`` — one policy, one query, one rate profile, optional
faults; returns the controller history plus scenario bookkeeping.

The profile's time axis is engine sim-seconds (``ControllerConfig`` maps one
decision window to ``decision_window_s x sim_time_scale`` of them, 12 by
default), so a scenario spanning W windows should shape its profile over
roughly ``W x 12`` seconds — ``scenario_horizon_s`` computes that.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

from repro.core.controller import (AutoScaler, ControllerConfig, HistoryRow)
from repro.core.justin import JustinParams
from repro.core.policy import make_policy
from repro.data.nexmark import QUERIES, TARGET_RATES
from repro.scenarios.faults import FaultSchedule
from repro.scenarios.profiles import Profile, make_profile
from repro.streaming.engine import StreamEngine


def scenario_horizon_s(cfg: ControllerConfig, windows: int) -> float:
    """Sim-seconds spanned by ``windows`` decision windows (excluding
    stabilization periods, which don't sample the profile)."""
    return windows * cfg.decision_window_s * cfg.sim_time_scale


@dataclass
class ScenarioResult:
    policy: str
    query: str
    history: list                    # HistoryRow per decision window
    faults_fired: list = field(default_factory=list)

    @property
    def steps(self) -> int:
        return self.history[-1].step if self.history else 0

    @property
    def final(self) -> HistoryRow:
        return self.history[-1]

    def recovered(self, slack: float = 0.97) -> bool:
        """Did the last window achieve its (time-varying) target?"""
        last = self.final
        return last.achieved_rate >= slack * last.target

    def slo(self, slack: float = 0.97, after_t: float = 0.0):
        """SLO scorecard for this episode (see ``scenarios.metrics``)."""
        from repro.scenarios.metrics import slo_report
        return slo_report(self.history, slack, after_t)

    def summary(self) -> dict:
        last = self.final
        return {"policy": self.policy, "query": self.query,
                "steps": self.steps, "windows": len(self.history),
                "achieved_rate": last.achieved_rate, "target": last.target,
                "cpu_cores": last.cpu_cores, "memory_mb": last.memory_mb,
                "config": dict(last.config),
                "faults_fired": len(self.faults_fired),
                "recovered": self.recovered()}


def run_scenario(policy: str, query: str, profile: Profile | str,
                 *, faults: FaultSchedule | list | None = None,
                 windows: int = 8, seed: int = 3, max_level: int = 2,
                 cfg: ControllerConfig | None = None,
                 warm: bool = True,
                 reconfig_cost="instant",
                 tracer=None, tenant: str = "") -> ScenarioResult:
    """Drive ``policy`` (any registered name — see
    ``repro.core.policy.available_policies()``) on Nexmark ``query`` under
    a time-varying ``profile`` (a :class:`Profile` or a named shape from
    ``make_profile``) with optional fault injection.

    Returns the full controller history: what Fig. 5 plots, but over a
    dynamic workload.  ``cfg`` is a template: its ``policy`` field is
    overridden from the ``policy`` argument.  ``reconfig_cost`` (a
    mechanism name or :class:`repro.migration.CostModel`) prices every
    reconfiguration as paused downtime; the default ``"instant"`` keeps
    reconfiguration free, as the golden traces pin.
    """
    cfg = cfg or ControllerConfig(policy=policy,
                                  justin=JustinParams(max_level=max_level))
    if cfg.policy != policy:
        cfg = dataclasses.replace(cfg, policy=policy)
    if isinstance(profile, str):
        profile = make_profile(profile, TARGET_RATES[query],
                               scenario_horizon_s(cfg, windows))
    if isinstance(faults, (list, tuple)):
        faults = FaultSchedule(list(faults))

    flow = QUERIES[query]()
    engine = StreamEngine(flow, seed=seed, warm=warm)
    from repro.migration import CostModel, MigrationRuntime
    cost_model = reconfig_cost if isinstance(reconfig_cost, CostModel) \
        else CostModel(mechanism=reconfig_cost)
    migration = None if cost_model.mechanism == "instant" \
        else MigrationRuntime(cost_model)
    scaler = AutoScaler(engine, profile(0.0), cfg,
                        policy=make_policy(policy, cfg),
                        migration=migration, tracer=tracer)
    if tenant:
        scaler.tenant = tenant
    fired: list = []

    def hook(eng, w):
        if faults is not None:
            fired.extend(faults.apply_due(eng, eng.now))

    scaler.run(max_windows=windows, target_profile=profile,
               window_hook=hook)
    return ScenarioResult(policy=policy, query=query,
                          history=scaler.history, faults_fired=fired)
