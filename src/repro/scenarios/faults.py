"""Fault & straggler injection schedules.

Each fault names an engine hook and a firing time (sim-seconds).  The
schedule applies everything due at decision-window boundaries — the
granularity at which the controller can observe the damage, matching how
the paper's testbed loses whole TaskManagers between observations.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.streaming.engine import StreamEngine


@dataclass(frozen=True)
class KillTask:
    """Lose a task's state and queue at time ``t`` (TM failure)."""
    t: float
    op: str
    idx: int = 0

    def apply(self, engine: StreamEngine) -> None:
        engine.kill_task(self.op, self.idx)


@dataclass(frozen=True)
class SetStraggler:
    """Slow one task by ``factor`` at time ``t``; a finite ``duration_s``
    schedules the automatic recovery (factor back to 1)."""
    t: float
    op: str
    idx: int = 0
    factor: float = 10.0
    duration_s: float | None = None

    def apply(self, engine: StreamEngine) -> None:
        engine.set_straggler(self.op, self.idx, self.factor)

    def recovery(self) -> "SetStraggler | None":
        if self.duration_s is None:
            return None
        return SetStraggler(self.t + self.duration_s, self.op, self.idx, 1.0)


@dataclass
class FaultSchedule:
    """Ordered fault list; ``apply_due(engine, now)`` fires everything with
    ``t <= now`` once and returns what fired (recoveries auto-enqueued)."""
    faults: list = field(default_factory=list)

    def __post_init__(self):
        pending = list(self.faults)
        for f in self.faults:
            rec = getattr(f, "recovery", lambda: None)()
            if rec is not None:
                pending.append(rec)
        self._pending = sorted(pending, key=lambda f: f.t)

    def apply_due(self, engine: StreamEngine, now: float) -> list:
        fired = []
        while self._pending and self._pending[0].t <= now:
            f = self._pending.pop(0)
            f.apply(engine)
            fired.append(f)
        return fired

    @property
    def pending(self) -> list:
        return list(self._pending)


def parse_fault(spec: str) -> KillTask | SetStraggler:
    """CLI shorthand: ``kill:T:op[:idx]`` or
    ``straggle:T:op[:idx[:factor[:duration]]]``."""
    parts = spec.split(":")
    kind, t, op = parts[0], float(parts[1]), parts[2]
    idx = int(parts[3]) if len(parts) > 3 else 0
    if kind == "kill":
        return KillTask(t, op, idx)
    if kind == "straggle":
        factor = float(parts[4]) if len(parts) > 4 else 10.0
        duration = float(parts[5]) if len(parts) > 5 else None
        return SetStraggler(t, op, idx, factor, duration)
    raise ValueError(f"unknown fault kind {kind!r} in {spec!r}")
