"""Downtime pricing for a planned reconfiguration.

Flink-style reconfiguration is a savepoint/stop/restore cycle: the job
pauses, state is written out and read back, and the paused seconds turn
into backlog the new configuration must drain.  Three mechanisms:

* ``instant``  — the pre-PR-5 simulator behaviour: reconfiguration is
  free.  A strict no-op (zero downtime, zero moved MB), kept as the
  default so the golden traces stay byte-identical.
* ``savepoint`` — full snapshot + restore.  Downtime is a fixed
  stop/redeploy overhead plus the WHOLE state footprint over the
  savepoint throughput: every reconfiguration pays for all state, moved
  or not — which is what makes churn-happy policies (threshold's
  doubling ratchet) pay for their extra steps.
* ``handoff``  — incremental LSM-level transfer (the engine's existing
  snapshot -> hash-partition -> ``bulk_load`` path: sorted runs move as
  runs, untouched tasks keep their stores).  Downtime is an epoch-barrier
  alignment plus only the MB that actually travels — so a memory-only
  adjustment (backend resize in place, no task relocated) is near-free
  while a parallelism change still pays for the re-shuffle.

Throughputs and fixed overheads are in paper-seconds (the controller's
``decision_window_s`` clock); the runtime converts to engine ticks via
``sim_time_scale`` like every other §5 duration.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.migration.planner import MigrationPlan

MECHANISMS = ("instant", "savepoint", "handoff")


@dataclass(frozen=True)
class ReconfigCost:
    """What one reconfiguration costs: paused paper-seconds and the MB of
    state that physically travelled (the budget arbiter's currency)."""
    mechanism: str
    downtime_s: float
    moved_mb: float                 # state that travelled
    total_mb: float                 # full footprint at the reconfig point

    @property
    def free(self) -> bool:
        return self.downtime_s <= 0.0


@dataclass(frozen=True)
class CostModel:
    """Calibrated mechanism parameters (paper-seconds / MB-per-second).

    Defaults follow the testbed the paper describes: a savepoint cycle
    redeploys pods (~30 s) and restores through object storage
    (~64 MB/s), while an incremental handoff only aligns an epoch
    barrier (~2 s) and streams runs TM-to-TM (~512 MB/s).
    """
    mechanism: str = "instant"
    savepoint_mb_per_s: float = 64.0
    handoff_mb_per_s: float = 512.0
    restart_s: float = 30.0          # stop + redeploy + restore fixed cost
    barrier_s: float = 2.0           # epoch-barrier alignment (handoff)

    def __post_init__(self) -> None:
        if self.mechanism not in MECHANISMS:
            raise ValueError(
                f"unknown reconfiguration mechanism {self.mechanism!r} "
                f"(have: {', '.join(MECHANISMS)})")

    def price(self, plan: MigrationPlan) -> ReconfigCost:
        """Downtime + moved MB for one planned reconfiguration."""
        if self.mechanism == "instant":
            return ReconfigCost("instant", 0.0, 0.0, plan.total_mb)
        if self.mechanism == "savepoint":
            total = plan.total_mb
            return ReconfigCost(
                "savepoint",
                self.restart_s + total / self.savepoint_mb_per_s,
                total, total)
        moved = plan.transfer_mb
        return ReconfigCost(
            "handoff",
            self.barrier_s + moved / self.handoff_mb_per_s,
            moved, plan.total_mb)
