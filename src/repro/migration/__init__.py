"""Reconfiguration runtime: state-migration planning, downtime pricing,
and the paused-window mechanics that make a reconfiguration a *priced,
observable* event instead of a free function call.

See :mod:`repro.migration.planner` (key-range handoff plans),
:mod:`repro.migration.costs` (the instant/savepoint/handoff cost model)
and :mod:`repro.migration.runtime` (the controller-side driver).
"""
from repro.migration.costs import (MECHANISMS, CostModel, ReconfigCost)
from repro.migration.planner import (KEYSPACE, Handoff, MigrationPlan,
                                     plan_migration)
from repro.migration.runtime import (MigrationRuntime, ReconfigEvent,
                                     engine_store_stats)

__all__ = [
    "KEYSPACE", "Handoff", "MigrationPlan", "plan_migration",
    "MECHANISMS", "CostModel", "ReconfigCost",
    "MigrationRuntime", "ReconfigEvent", "engine_store_stats",
]
