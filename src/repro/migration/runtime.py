"""Controller-side migration driver: plan, price, pause.

``AutoScaler`` owns an optional :class:`MigrationRuntime`.  When present,
every enacted reconfiguration is planned against the episode's private
placements (old config vs proposed config, packed exactly as admission
quotes pack them), priced by the runtime's :class:`CostModel`, and the
resulting downtime is converted into PAUSED engine time
(``StreamEngine.run_paused``): sources keep producing — input accrues as
queued backlog until backpressure blocks them — while no operator
processes, so the catch-up shows up in the existing SLO metrics with no
new machinery.  The price lands on the decision window's ``HistoryRow``
(``reconfig_downtime`` / ``moved_mb``) and each event is kept for
reporting.

Payloads are measured from the live stores
(:func:`engine_store_stats`): what moves is the state that EXISTS at the
reconfig point, not the managed grant a freshly scaled-up config merely
promises.

On a shared-TM cluster the fleet-level repack cost (what the admission
budget gates — see ``Cluster.quote_migration``) is a different view of
the same reconfiguration: the arbiter prices the *cluster's* re-shape,
this runtime prices the *episode's* downtime.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.placement import bin_pack, default_tm_spec
from repro.migration.costs import CostModel, ReconfigCost
from repro.migration.planner import MigrationPlan, plan_migration


def engine_store_stats(engine, tenant: str = ""
                       ) -> dict[tuple[str, str, int], float]:
    """Measured state MB per live task: ``(tenant, op, index) -> MB`` at
    the paper's logical entry size — the payload a migration actually
    carries, as opposed to the managed grant."""
    out: dict[tuple[str, str, int], float] = {}
    for name, tasks in engine.tasks.items():
        for i, tr in enumerate(tasks):
            if tr.state is not None:
                out[(tenant, name, i)] = tr.state.state_mb
    return out


@dataclass
class ReconfigEvent:
    """One priced reconfiguration, for reporting."""
    t: float
    tenant: str
    old_config: dict
    new_config: dict
    cost: ReconfigCost
    tasks_moved: int


class MigrationRuntime:
    """Prices an episode's reconfigurations and records them.

    Construct from a mechanism name (``"instant"``/``"savepoint"``/
    ``"handoff"``) or a full :class:`CostModel`.  One runtime belongs to
    one episode (events accumulate per tenant); the cost model itself is
    immutable and may be shared.
    """

    def __init__(self, model: CostModel | str = "instant"):
        if isinstance(model, str):
            model = CostModel(mechanism=model)
        self.model = model
        self.events: list[ReconfigEvent] = []

    @property
    def mechanism(self) -> str:
        return self.model.mechanism

    def plan(self, scaler, old_config: dict,
             new_config: dict) -> MigrationPlan:
        """The handoff plan old -> new under the episode's private
        placement (same packing the admission quotes use), with payloads
        measured from the live stores."""
        spec = default_tm_spec(scaler.cfg.base_mem_mb)
        # the controller's own request builder, so the plan packs exactly
        # what admission quotes pack (resources_config coupling, source
        # exclusion, tenant tag)
        old_reqs = scaler.task_requests(old_config)
        new_reqs = scaler.task_requests(new_config)
        stats = engine_store_stats(scaler.engine, tenant=scaler.tenant)
        return plan_migration(bin_pack(old_reqs, spec),
                              bin_pack(new_reqs, spec), stats)

    def charge(self, scaler, old_config: dict,
               new_config: dict) -> ReconfigCost:
        """Plan + price one reconfiguration and record the event."""
        plan = self.plan(scaler, old_config, new_config)
        cost = self.model.price(plan)
        self.events.append(ReconfigEvent(
            t=scaler.engine.now, tenant=scaler.tenant,
            old_config=dict(old_config), new_config=dict(new_config),
            cost=cost, tasks_moved=plan.tasks_moved))
        scaler.tracer.record(
            "migration.charge", "migration", scaler.engine.now,
            scaler.engine.now, tenant=scaler.tenant,
            args={"mechanism": self.mechanism,
                  "downtime_s": cost.downtime_s,
                  "moved_mb": cost.moved_mb,
                  "tasks_moved": plan.tasks_moved})
        return cost

    def totals(self) -> dict:
        """Aggregate event totals (reporting)."""
        return {"reconfigs": len(self.events),
                "downtime_s": sum(e.cost.downtime_s for e in self.events),
                "moved_mb": sum(e.cost.moved_mb for e in self.events),
                "tasks_moved": sum(e.tasks_moved for e in self.events)}
