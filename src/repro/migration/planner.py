"""State-migration planning: who hands which key range to whom.

A reconfiguration C^{t-1} -> C^t re-shapes a running placement; the state
that makes streaming reconfiguration *expensive* (the savepoint/stop/
restore cycle the paper's "fewer reconfiguration steps" headline prices
implicitly) has to travel with it.  :func:`plan_migration` turns an
(old placement, new placement) pair into an explicit per-task handoff
plan:

* every task of the new placement receives exactly ONE contiguous slice
  of its operator's hash keyspace — the ownership model behind the
  engine's ``hash_partition`` + lexsort re-partitioning path.  Per
  ``(tenant, op)`` the slices tile ``[0, KEYSPACE)`` exactly once (no
  gaps, no overlaps) — the invariant the property tests pin;
* a task present in both placements whose TaskManager changed is a
  **move**: it drags its managed state across TMs.  The plan's
  :meth:`MigrationPlan.migration_cost` reproduces
  :func:`repro.core.placement.repack`'s ``MigrationCost`` exactly
  (same rule, same grant MB) — the reconciliation invariant;
* an operator whose parallelism changed is **re-partitioned**: every one
  of its new tasks receives its key range re-shuffled from the old
  tasks' stores (the engine's snapshot -> hash-partition -> bulk-load
  path), so the whole operator's state is in flight even though
  ``repack`` (which prices only TM changes of surviving tasks) does not
  charge the newly spawned tasks.

Two MB figures ride on each handoff: ``mb`` is the *managed grant* of the
new placement's task request (what ``repack`` prices — reconciliation),
``payload_mb`` is the *actual* state behind it when the caller supplies
``store_stats`` (measured MB per old task, e.g. from the live LSM
stores) — what a downtime model should price, because a freshly doubled
grant does not double the bytes that move.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.placement import MigrationCost, Placement

# The modeled hash keyspace.  Any power of two works; 2^63 keeps range
# arithmetic in exact ints and leaves headroom over int64 event keys.
KEYSPACE = 1 << 63


def placement_assignment(pl: Placement) -> dict[tuple[str, str, int], int]:
    """Task identity -> TM index for ANY placement (``SharedPlacement``
    has this as a method; private ``Placement``s get it here)."""
    return {t.key: i for i, tm in enumerate(pl.tms) for t in tm.tasks}


@dataclass(frozen=True)
class Handoff:
    """One task's share of a reconfiguration: the key range it owns under
    the new placement, where that state comes from, and what it weighs."""
    task: tuple[str, str, int]       # (tenant, op, index)
    src_tm: int | None               # None == task did not exist before
    dst_tm: int
    key_range: tuple[int, int]       # [lo, hi) slice of the op keyspace
    mb: float                        # managed grant (reconciles with repack)
    payload_mb: float                # measured state MB (falls back to mb)
    tm_moved: bool                   # in both placements, TM changed
    repartitioned: bool              # op parallelism changed: state arrives
                                     # re-shuffled from the old tasks

    @property
    def moves_state(self) -> bool:
        """Does any state physically travel for this handoff?"""
        return self.tm_moved or self.repartitioned


@dataclass
class MigrationPlan:
    """The full handoff list for one reconfiguration, with the three MB
    aggregates the cost mechanisms price: everything (savepoint), only
    what travels (handoff), and the repack-reconcilable move subset."""
    handoffs: list[Handoff] = field(default_factory=list)

    def migration_cost(self) -> MigrationCost:
        """Exactly ``repack``'s rule: tasks present in both placements
        whose TM changed, priced at the NEW placement's grant MB."""
        moved = [h for h in self.handoffs if h.tm_moved]
        return MigrationCost(len(moved), sum(h.mb for h in moved))

    @property
    def tasks_moved(self) -> int:
        return self.migration_cost().tasks_moved

    @property
    def total_mb(self) -> float:
        """Full state footprint under the plan (savepoint writes and
        restores everything, moved or not)."""
        return sum(h.payload_mb for h in self.handoffs)

    @property
    def transfer_mb(self) -> float:
        """State that physically travels: TM moves plus re-partitioned
        operators (what an incremental handoff transfers)."""
        return sum(h.payload_mb for h in self.handoffs if h.moves_state)

    def by_op(self) -> dict[tuple[str, str], list[Handoff]]:
        out: dict[tuple[str, str], list[Handoff]] = {}
        for h in self.handoffs:
            out.setdefault((h.task[0], h.task[1]), []).append(h)
        return out


def _op_ranges(n: int) -> list[tuple[int, int]]:
    """``n`` contiguous slices tiling [0, KEYSPACE) exactly once."""
    return [(i * KEYSPACE // n, (i + 1) * KEYSPACE // n) for i in range(n)]


def plan_migration(old_placement: Placement, new_placement: Placement,
                   store_stats: dict[tuple[str, str, int], float]
                   | None = None) -> MigrationPlan:
    """Plan the state handoffs that take ``old_placement`` to
    ``new_placement``.

    ``store_stats`` optionally maps OLD task identity -> measured state MB
    (e.g. :func:`repro.migration.runtime.engine_store_stats`); handoffs
    then carry the actual payload instead of the managed grant.  A
    re-partitioned operator's old payload is split evenly across its new
    tasks (hash partitioning is uniform in expectation).
    """
    old_at = placement_assignment(old_placement)
    new_at = placement_assignment(new_placement)
    # stats *provided* (even empty — a fully stateless job) means payloads
    # are measured: a task without a store carries 0 MB.  Only stats=None
    # (pure placement-level planning) falls back to the managed grants.
    measured = store_stats is not None
    stats = store_stats or {}

    # group the new placement's tasks per (tenant, op)
    tasks_by_op: dict[tuple[str, str], list] = {}
    for tm in new_placement.tms:
        for t in tm.tasks:
            tasks_by_op.setdefault((t.tenant, t.op), []).append(t)
    old_p: dict[tuple[str, str], int] = {}
    old_payload: dict[tuple[str, str], float] = {}
    for (tenant, op, idx), _tm in old_at.items():
        old_p[(tenant, op)] = old_p.get((tenant, op), 0) + 1
        old_payload[(tenant, op)] = old_payload.get((tenant, op), 0.0) \
            + stats.get((tenant, op, idx), 0.0)

    plan = MigrationPlan()
    for (tenant, op), tasks in tasks_by_op.items():
        seen = {t.index for t in tasks}
        if len(seen) != len(tasks):
            raise ValueError(f"duplicate task index in {tenant!r}/{op!r}")
        tasks = sorted(tasks, key=lambda t: t.index)
        ranges = _op_ranges(len(tasks))
        repart = old_p.get((tenant, op), len(tasks)) != len(tasks)
        for t, rng in zip(tasks, ranges):
            src = old_at.get(t.key)
            dst = new_at[t.key]
            moved = src is not None and src != dst
            if repart:
                # the op's whole state is re-shuffled; this task's share
                # of the old payload arrives hash-partitioned
                payload = old_payload.get((tenant, op), 0.0) / len(tasks) \
                    if measured else t.memory_mb
            else:
                payload = stats.get(t.key, 0.0) if measured \
                    else t.memory_mb
            plan.handoffs.append(Handoff(
                task=t.key, src_tm=src, dst_tm=dst, key_range=rng,
                mb=t.memory_mb, payload_mb=payload,
                tm_moved=moved, repartitioned=repart))
    return plan
