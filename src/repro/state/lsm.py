"""LSM-tree state backend — the RocksDB analogue Justin's policy observes.

Structure mirrors §3 of the paper:

* **MemTable** — a sorted-run write buffer (vector-friendly replacement for
  RocksDB's skip list; same asymptotics at our granularity).  Writes land
  here; when full it is flushed to level 0.
* **Block cache** — set-associative read cache with CLOCK replacement.  Its
  hit rate is Justin's θ metric.
* **Levels** — sorted SSTable runs with size-tiered compaction (fanout x per
  level).  A read that misses memtable+cache probes levels top-down; every
  level probed adds the slow-tier penalty to the access-latency metric τ.

Byte accounting uses the paper's *logical* entry size (1000 B values, as in
the §3 microbenchmarks) while physical storage keeps ``value_words`` int32
words per entry, so cache-capacity ratios match the paper exactly at 1/64th
the RAM (see DESIGN.md §3 "hardware adaptation").

The batched sorted-run probe is the compute hot spot; its TPU Pallas kernel
lives in ``repro/kernels/sorted_probe`` (this CPU implementation is the
oracle and uses the same algorithm).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

LOGICAL_ENTRY_BYTES = 1_000          # paper §3: 1000 B events
MEMTABLE_GRANULARITY_MB = 64         # first-level SSTable size (paper §3)
CACHE_OVERHEAD = 2.5                 # block granularity + index/filter share
                                     # (RocksDB caches blocks, not entries)


@dataclass
class LSMMetrics:
    reads: int = 0
    writes: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    memtable_hits: int = 0
    level_probes: int = 0            # SSTable lookups (slow tier)
    flushes: int = 0
    compactions: int = 0
    access_latency_total_ms: float = 0.0

    def snapshot(self) -> dict:
        return dict(self.__dict__)

    def reset(self) -> None:
        for k in self.__dict__:
            setattr(self, k, 0 if not k.startswith("access") else 0.0)

    @property
    def cache_hit_rate(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 1.0

    @property
    def avg_access_latency_ms(self) -> float:
        tot = self.reads + self.writes
        return self.access_latency_total_ms / tot if tot else 0.0


@dataclass(frozen=True)
class LatencyModel:
    """Calibrated per-access costs (ms).  The slow tier models SSD/host-DRAM
    fetches (a cold SSTable block read on the paper's testbed is ~0.5 ms
    including read amplification); write costs amortize WAL + flush +
    compaction work, which the store *charges as it actually happens*, so
    memtable size shapes write performance the way §3 observes."""
    memtable_ms: float = 0.002
    cache_ms: float = 0.002
    level_ms: float = 0.5            # per level probed on a miss
    bloom_ms: float = 0.001          # bloom check for an absent key
    bloom_fp: float = 0.01           # bloom false-positive rate
    meta_ratio: float = 8.0          # data entries per filter/index-block
                                     # cache-entry-equivalent: metadata
                                     # (blooms + index blocks) competes for
                                     # block cache at ~1/8 the footprint
    meta_read_frac: float = 0.5      # cost of a filter-block disk read,
                                     # as a fraction of a data-block read
    write_ms: float = 0.07           # WAL append share
    flush_ms: float = 0.14           # per entry flushed to L0
    compact_ms: float = 0.05         # per entry rewritten in a merge
    flush_fixed_ms: float = 150.0    # write-stall per flush (small memtables
                                     # flush more often -> §3 (1;128) dip)


class LSMStore:
    """Vectorized LSM over int64 keys -> fixed-width int32 value vectors."""

    def __init__(self, memory_mb: float, *, value_words: int = 4,
                 fanout: int = 8, latency: LatencyModel | None = None,
                 entry_bytes: int = LOGICAL_ENTRY_BYTES, seed: int = 0):
        self.value_words = value_words
        self.entry_bytes = entry_bytes            # logical entry size
        self._wscale = entry_bytes / LOGICAL_ENTRY_BYTES  # IO-cost scaling
        self.latency = latency or LatencyModel()
        self.metrics = LSMMetrics()
        self.compact_filter = None                # optional keys->keep mask
        self._configure_memory(memory_mb)
        self.levels: list[tuple[np.ndarray, np.ndarray]] = []
        self.fanout = fanout
        self._empty()

    # -- memory layout (paper §3: memtable <= 64 MB, >= half to cache, pow2) --
    def _configure_memory(self, memory_mb: float) -> None:
        self.memory_mb = float(memory_mb)
        mem_budget = memory_mb * 1024 * 1024
        memtable_b = MEMTABLE_GRANULARITY_MB * 1024 * 1024
        while memtable_b >= mem_budget / 2:    # cache gets MORE than half
            memtable_b //= 2                   # (paper §3: 128 -> 32+96)
        cache_b = mem_budget - memtable_b
        self.memtable_cap = max(64, int(memtable_b // self.entry_bytes))
        n_cache = max(64, int(cache_b // (self.entry_bytes
                                          * CACHE_OVERHEAD)))
        self.cache_ways = 8
        self.cache_sets = max(8, n_cache // self.cache_ways)

    def _empty(self) -> None:
        self.mem_keys = np.empty(self.memtable_cap, np.int64)
        self.mem_vals = np.empty((self.memtable_cap, self.value_words),
                                 np.int32)
        self.mem_n = 0
        self.cache_keys = np.full((self.cache_sets, self.cache_ways), -1,
                                  np.int64)
        self.cache_vals = np.zeros(
            (self.cache_sets, self.cache_ways, self.value_words), np.int32)
        self.cache_ref = np.zeros((self.cache_sets, self.cache_ways), np.int8)
        self.cache_hand = np.zeros(self.cache_sets, np.int32)

    # ------------------------------------------------------------------ util
    @property
    def entry_count(self) -> int:
        return self.mem_n + sum(len(k) for k, _ in self.levels)

    def resize(self, memory_mb: float) -> None:
        """Vertical rescale: rebuild memtable/cache under the new budget,
        spilling the old memtable into level 0 (a Flink-style reconfig)."""
        keys, vals = self.mem_keys[:self.mem_n], self.mem_vals[:self.mem_n]
        if self.mem_n:
            self._push_run(keys.copy(), vals.copy())
        self._configure_memory(memory_mb)
        self._empty()

    def items(self) -> tuple[np.ndarray, np.ndarray]:
        """All live (key, value) pairs — used for state re-partitioning."""
        ks = [self.mem_keys[:self.mem_n]] + [k for k, _ in self.levels]
        vs = [self.mem_vals[:self.mem_n]] + [v for _, v in self.levels]
        if not ks:
            return (np.empty(0, np.int64),
                    np.empty((0, self.value_words), np.int32))
        keys = np.concatenate(ks)
        vals = np.concatenate(vs)
        # newest first; keep first occurrence of each key
        uniq, idx = np.unique(keys, return_index=True)
        return uniq, vals[idx]

    # ------------------------------------------------------------- write path
    def put_batch(self, keys: np.ndarray, vals: np.ndarray) -> None:
        n = len(keys)
        self.metrics.writes += n
        self.metrics.access_latency_total_ms += \
            n * self.latency.write_ms * self._wscale
        off = 0
        while off < n:
            room = self.memtable_cap - self.mem_n
            take = min(room, n - off)
            sl = slice(off, off + take)
            self.mem_keys[self.mem_n:self.mem_n + take] = keys[sl]
            self.mem_vals[self.mem_n:self.mem_n + take] = vals[sl]
            self.mem_n += take
            off += take
            if self.mem_n >= self.memtable_cap:
                self._flush()
        # write-through invalidate/update of cached copies
        self._cache_update(keys, vals)

    def _flush(self) -> None:
        if self.mem_n == 0:
            return
        keys = self.mem_keys[:self.mem_n]
        vals = self.mem_vals[:self.mem_n]
        # last write wins within the buffer
        order = np.argsort(keys[::-1], kind="stable")
        rk, rv = keys[::-1][order], vals[::-1][order]
        uniq, first = np.unique(rk, return_index=True)
        if self.compact_filter is not None and len(uniq):
            keep = self.compact_filter(uniq)
            uniq, first = uniq[keep], first[keep]
        self._push_run(uniq, rv[first])
        self.mem_n = 0
        self.metrics.flushes += 1
        self.metrics.access_latency_total_ms += \
            (len(uniq) * self.latency.flush_ms
             + self.latency.flush_fixed_ms) * self._wscale

    def _push_run(self, keys: np.ndarray, vals: np.ndarray) -> None:
        self.levels.insert(0, (keys, vals))
        # size-tiered compaction: merge while a level outgrows fanout^i
        base = max(self.memtable_cap, 1)
        i = 0
        while i < len(self.levels) - 1:
            if len(self.levels[i][0]) >= base * (self.fanout ** i):
                self._merge_levels(i)
                self.metrics.compactions += 1
            else:
                i += 1

    def _merge_levels(self, i: int) -> None:
        k1, v1 = self.levels[i]          # newer
        k2, v2 = self.levels[i + 1]      # older
        keys = np.concatenate([k1, k2])
        vals = np.concatenate([v1, v2])
        uniq, idx = np.unique(keys, return_index=True)  # newer first => wins
        if self.compact_filter is not None and len(uniq):
            keep = self.compact_filter(uniq)
            uniq, idx = uniq[keep], idx[keep]
        self.levels[i + 1] = (uniq, vals[idx])
        del self.levels[i]
        self.metrics.access_latency_total_ms += \
            len(keys) * self.latency.compact_ms * self._wscale

    # -------------------------------------------------------------- read path
    def get_batch(self, keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Returns (values [n, V], found mask [n]) and updates θ/τ metrics."""
        n = len(keys)
        self.metrics.reads += n
        out = np.zeros((n, self.value_words), np.int32)
        found = np.zeros(n, bool)
        lat = 0.0

        # 1. memtable (newest data wins: last occurrence among duplicates)
        if self.mem_n:
            mk = self.mem_keys[:self.mem_n]
            srt = np.argsort(mk, kind="stable")
            pos = np.searchsorted(mk[srt], keys, side="right") - 1
            pos_c = np.clip(pos, 0, self.mem_n - 1)
            hit = (pos >= 0) & (mk[srt][pos_c] == keys)
            if hit.any():
                out[hit] = self.mem_vals[srt[pos_c[hit]]]
                found |= hit
                self.metrics.memtable_hits += int(hit.sum())
        lat += n * self.latency.memtable_ms

        # 2. block cache
        todo = ~found
        if todo.any():
            tk = keys[todo]
            sets = self._sets(tk)
            match = self.cache_keys[sets] == tk[:, None]        # [m, ways]
            hit = match.any(axis=1)
            way = match.argmax(axis=1)
            vals = self.cache_vals[sets, way]
            self.cache_ref[sets[hit], way[hit]] = 1
            sub = np.where(todo)[0]
            out[sub[hit]] = vals[hit]
            found[sub[hit]] = True
            self.metrics.cache_hits += int(hit.sum())
            self.metrics.cache_misses += int((~hit).sum())
            lat += len(tk) * self.latency.cache_ms

            # 3. levels (slow tier) for cache misses.  Bloom filters guard
            # each SSTable: absent keys cost a filter check (plus the
            # false-positive rate of real probes) instead of a full read.
            rem = sub[~hit]
            if len(rem):
                probe_keys = keys[rem]
                got = np.zeros(len(rem), bool)
                gvals = np.zeros((len(rem), self.value_words), np.int32)
                probes = 0.0
                blooms = 0
                for (lk, lv) in self.levels:
                    live = ~got
                    if not live.any():
                        break
                    pos = np.searchsorted(lk, probe_keys[live])
                    pos_c = np.clip(pos, 0, len(lk) - 1) if len(lk) else pos
                    h = (lk[pos_c] == probe_keys[live]) if len(lk) else \
                        np.zeros(int(live.sum()), bool)
                    n_live = int(live.sum())
                    n_hit = int(h.sum())
                    # present keys pass the bloom filter and read the block;
                    # absent keys mostly stop at the filter — but the filter/
                    # index blocks themselves need block-cache residency:
                    # with a small cache a share of filter checks also hits
                    # the slow tier (RocksDB filter-block eviction)
                    meta_ws = max(1.0, len(lk) / self.latency.meta_ratio)
                    meta_cover = min(1.0, self.cache_capacity / meta_ws)
                    probes += n_hit + self.latency.bloom_fp * (n_live - n_hit)
                    probes += (1.0 - meta_cover) \
                        * self.latency.meta_read_frac * n_live
                    blooms += n_live
                    li = np.where(live)[0]
                    gvals[li[h]] = lv[pos_c[h]]
                    got[li[h]] = True
                out[rem[got]] = gvals[got]
                found[rem[got]] = True
                self.metrics.level_probes += int(probes)
                lat += (probes * self.latency.level_ms
                        + blooms * self.latency.bloom_ms)
                # admit fetched entries into the cache
                if got.any():
                    self._cache_update(probe_keys[got], gvals[got])

        self.metrics.access_latency_total_ms += lat
        return out, found

    # ----------------------------------------------------------------- cache
    def _sets(self, keys: np.ndarray) -> np.ndarray:
        h = keys.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
        h ^= h >> np.uint64(29)
        return ((h >> np.uint64(1)).astype(np.int64) % self.cache_sets)

    def _cache_update(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Insert/overwrite entries (CLOCK eviction within each set)."""
        if len(keys) == 0:
            return
        # dedupe (last wins) to avoid write conflicts in the vectorized scatter
        uniq, idx = np.unique(keys[::-1], return_index=True)
        keys = uniq
        vals = vals[::-1][idx]
        sets = self._sets(keys)
        match = self.cache_keys[sets] == keys[:, None]
        hit = match.any(axis=1)
        way = match.argmax(axis=1)
        self.cache_vals[sets[hit], way[hit]] = vals[hit]
        self.cache_ref[sets[hit], way[hit]] = 1
        # misses: CLOCK — evict first way with ref=0, clearing refs as we pass
        for s, k, v in zip(sets[~hit], keys[~hit], vals[~hit]):
            hand = self.cache_hand[s]
            for _ in range(2 * self.cache_ways):
                if self.cache_ref[s, hand] == 0:
                    break
                self.cache_ref[s, hand] = 0
                hand = (hand + 1) % self.cache_ways
            self.cache_keys[s, hand] = k
            self.cache_vals[s, hand] = v
            self.cache_ref[s, hand] = 1
            self.cache_hand[s] = (hand + 1) % self.cache_ways

    @property
    def cache_capacity(self) -> int:
        return self.cache_sets * self.cache_ways

    def prewarm_cache(self, keys: np.ndarray, vals: np.ndarray,
                      rng: np.random.Generator | None = None) -> None:
        """Fill the cache to capacity with a uniform sample of the live
        entries — steady-state emulation so short observation windows see
        the equilibrium hit rate rather than a cold-start transient."""
        if len(keys) == 0:
            return
        cap = self.cache_capacity
        if len(keys) > cap:
            rng = rng or np.random.default_rng(0)
            idx = rng.choice(len(keys), cap, replace=False)
            keys, vals = keys[idx], vals[idx]
        self._cache_update(keys, vals)
        self.metrics.reset()

    # ------------------------------------------------------------- snapshots
    def snapshot(self) -> dict:
        """Epoch-barrier snapshot (Flink-checkpoint analogue)."""
        keys, vals = self.items()
        return {"keys": keys, "vals": vals, "memory_mb": self.memory_mb,
                "value_words": self.value_words}

    @classmethod
    def restore(cls, snap: dict, *, memory_mb: float | None = None,
                **kw) -> "LSMStore":
        store = cls(memory_mb if memory_mb is not None else snap["memory_mb"],
                    value_words=snap["value_words"], **kw)
        if len(snap["keys"]):
            store._push_run(np.asarray(snap["keys"], np.int64),
                            np.asarray(snap["vals"], np.int32))
        return store
