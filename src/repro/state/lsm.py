"""Columnar delta LSM state backend — the RocksDB analogue Justin observes.

Structure mirrors §3 of the paper, rebuilt around the DBSP/gnitz Z-set
design (SNIPPETS.md §1): keys, weights and payloads live in separate
arrays (SoA), every write is an algebraic *delta*, and duplicate
resolution / compaction are batched weight-summation + annihilation
passes instead of per-put argsorts.

* **MemTable** — an append-only stack of sorted-unique delta runs over a
  consolidated base.  ``put_batch`` appends one delta run per batch
  (keys, per-key occurrence weights, newest payloads); nothing else is
  touched on the write path.  When the stack reaches ``MEMTABLE_RUNS``
  runs it is *consolidated*: one stable sort over the concatenated runs,
  a segment weight-sum per unique key (the ``window_agg`` kernel's job on
  TPU), newest payload wins, then an O(n) scatter-merge into the base.
  This amortizes the O(memtable) work the old store paid on (almost)
  every put to once per ``MEMTABLE_RUNS`` batches.
* **Block cache** — set-associative CLOCK cache, unchanged and still
  bit-identical to the sequential reference scan (its hit rate is
  Justin's θ metric).
* **Levels** — sorted-unique (keys, weights, payloads) runs with
  size-tiered compaction.  Merges are O(n) two-pointer-style scatter
  merges: duplicate keys *add weights* (delta addition), the newer
  payload wins, and compaction-filter drops are *annihilations* (tracked
  in ``annihilated``).  Probes are batched sorted-run ranks — the
  ``sorted_probe`` kernel's job on TPU.

Every kernel dispatch point has a numpy reference path that is the
oracle for CPU-only CI; ``kernel_impl="pallas"`` routes probes and
segment sums through ``repro.kernels`` (interpret mode off-TPU).  Weight
sums on the pallas path ride the float32 MXU — exact below 2^24, far
above any per-flush occurrence count.

Byte accounting uses the paper's *logical* entry size (1000 B values, as
in the §3 microbenchmarks) while physical storage keeps ``value_words``
int32 words per entry, so cache-capacity ratios match the paper exactly
at 1/64th the RAM (see DESIGN.md §3 "hardware adaptation").

Decision-identity invariants (pinned by ``tests/test_engine_fastpath.py``,
``tests/test_lsm_differential.py`` against the frozen
``repro.state.legacy.LegacyLSMStore``, and the golden traces):

* reads see newest-write-wins values, identical to the old maintained
  view (runs are probed newest-first);
* every metric charge is structure-independent and unchanged: flat
  memtable latency per read, flush cadence on the *raw* write count,
  flush/compaction charges on deduped run lengths, θ/τ duplicate-probe
  accounting exactly as documented on ``get_batch``;
* CLOCK cache state stays bit-for-bit equal to the sequential scan;
* ``items()``/``snapshot()`` return frozen arrays (consolidation always
  allocates; nothing mutates a published array in place).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

LOGICAL_ENTRY_BYTES = 1_000          # paper §3: 1000 B events
MEMTABLE_GRANULARITY_MB = 64         # first-level SSTable size (paper §3)
CACHE_OVERHEAD = 2.5                 # block granularity + index/filter share
                                     # (RocksDB caches blocks, not entries)
MEMTABLE_RUNS = 8                    # delta runs absorbed before a
                                     # consolidation pass

DEFAULT_KERNEL_IMPL = "numpy"        # "numpy" (oracle) | "pallas"

# CLOCK-scan lookup tables for the 8-way cache: ref bits of one set pack
# into a byte, so "first zero way at/after the hand" and "unpack ref byte
# to the [W] int8 row" become O(1) table gathers per set.
_CLOCK_POW2 = (1 << np.arange(8)).astype(np.uint8)
_CLOCK_UNPACK = ((np.arange(256)[:, None] >> np.arange(8)) & 1).astype(np.int8)
_CLOCK_FIRST_ZERO = np.where(np.arange(256) == 255, 8,
                             np.argmin(_CLOCK_UNPACK, axis=1)).astype(np.int64)


def set_kernel_impl(name: str) -> None:
    """Default probe/segment-sum backend for newly built stores."""
    global DEFAULT_KERNEL_IMPL
    if name not in ("numpy", "pallas"):
        raise ValueError(f"unknown kernel impl {name!r}")
    DEFAULT_KERNEL_IMPL = name


def stable_argsort_keys(a: np.ndarray) -> np.ndarray:
    """Stable argsort for int64 key arrays.  numpy's stable kind only
    radix-sorts dtypes up to 16 bits, so non-negative keys below 2^32 are
    sorted in two 16-bit radix passes (LSB first) — several times faster
    than the int64 mergesort on large arrays, with an identical
    permutation (LSB->MSB radix is stable at every pass).  Anything out
    of range falls back to the mergesort."""
    n = len(a)
    if n < 4096:
        return np.argsort(a, kind="stable")
    if int(a.min()) < 0 or int(a.max()) >= (1 << 32):
        return np.argsort(a, kind="stable")
    lo = (a & 0xFFFF).astype(np.uint16)
    hi = (a >> 16).astype(np.uint16)
    o1 = np.argsort(lo, kind="stable")
    o2 = np.argsort(hi[o1], kind="stable")
    return o1[o2]


def merge_delta_runs(k1: np.ndarray, w1: np.ndarray, v1: np.ndarray,
                     k2: np.ndarray, w2: np.ndarray, v2: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Merge two sorted-unique delta runs: weights of duplicate keys ADD
    (Z-set delta addition), side-1 (newer) payloads win.  O(n) scatter
    instead of re-sorting the concatenation.  Inputs are never mutated,
    so published runs stay frozen."""
    if not len(k1):
        return k2, w2, v2
    if not len(k2):
        return k1, w1, v1
    pos = np.searchsorted(k1, k2)
    dup = (k1[np.minimum(pos, len(k1) - 1)] == k2) & (pos < len(k1))
    if dup.any():
        w1 = w1.copy()
        w1[pos[dup]] += w2[dup]       # k2 unique => conflict-free scatter
        keep = ~dup
        pos = pos[keep]
        k2, w2, v2 = k2[keep], w2[keep], v2[keep]
    out_k = np.empty(len(k1) + len(k2), k1.dtype)
    out_w = np.empty(len(out_k), w1.dtype)
    out_v = np.empty((len(out_k),) + v1.shape[1:], v1.dtype)
    # both interleave maps fall out of the one searchsorted above: the
    # surviving k2 sit strictly between k1 entries, so the k2 slot is its
    # rank plus its insert position, and the k1 slot shifts by the count
    # of k2 inserted at or before it (a bincount running sum — no further
    # log-n probes)
    i2 = np.arange(len(k2)) + pos
    cum = np.cumsum(np.bincount(pos, minlength=len(k1) + 1))
    i1 = np.arange(len(k1)) + cum[:len(k1)]
    out_k[i1], out_w[i1], out_v[i1] = k1, w1, v1
    out_k[i2], out_w[i2], out_v[i2] = k2, w2, v2
    return out_k, out_w, out_v


@dataclass
class LSMMetrics:
    reads: int = 0
    writes: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    memtable_hits: int = 0
    level_probes: int = 0            # SSTable lookups (slow tier)
    flushes: int = 0
    compactions: int = 0
    access_latency_total_ms: float = 0.0

    def snapshot(self) -> dict:
        return dict(self.__dict__)

    def counters(self) -> tuple:
        """O(1) hot-path view for the engine's per-task charge marks
        (no dict allocation): (reads, writes, cache_hits, cache_misses,
        level_probes, access_latency_total_ms)."""
        return (self.reads, self.writes, self.cache_hits, self.cache_misses,
                self.level_probes, self.access_latency_total_ms)

    def maintenance(self) -> tuple[int, int]:
        """(flushes, compactions) — the background-work counters
        ``counters()`` deliberately omits from the hot-path view; the
        observability layer reads them for per-window LSM spans."""
        return (self.flushes, self.compactions)

    def reset(self) -> None:
        for k in self.__dict__:
            setattr(self, k, 0 if not k.startswith("access") else 0.0)

    @property
    def cache_hit_rate(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 1.0

    @property
    def avg_access_latency_ms(self) -> float:
        tot = self.reads + self.writes
        return self.access_latency_total_ms / tot if tot else 0.0


@dataclass(frozen=True)
class LatencyModel:
    """Calibrated per-access costs (ms).  The slow tier models SSD/host-DRAM
    fetches (a cold SSTable block read on the paper's testbed is ~0.5 ms
    including read amplification); write costs amortize WAL + flush +
    compaction work, which the store *charges as it actually happens*, so
    memtable size shapes write performance the way §3 observes."""
    memtable_ms: float = 0.002
    cache_ms: float = 0.002
    level_ms: float = 0.5            # per level probed on a miss
    bloom_ms: float = 0.001          # bloom check for an absent key
    bloom_fp: float = 0.01           # bloom false-positive rate
    meta_ratio: float = 8.0          # data entries per filter/index-block
                                     # cache-entry-equivalent: metadata
                                     # (blooms + index blocks) competes for
                                     # block cache at ~1/8 the footprint
    meta_read_frac: float = 0.5      # cost of a filter-block disk read,
                                     # as a fraction of a data-block read
    write_ms: float = 0.07           # WAL append share
    flush_ms: float = 0.14           # per entry flushed to L0
    compact_ms: float = 0.05         # per entry rewritten in a merge
    flush_fixed_ms: float = 150.0    # write-stall per flush (small memtables
                                     # flush more often -> §3 (1;128) dip)


class LSMStore:
    """Columnar delta LSM over int64 keys -> fixed-width int32 payloads,
    with per-key int64 weights (write-occurrence counts)."""

    def __init__(self, memory_mb: float, *, value_words: int = 4,
                 fanout: int = 8, latency: LatencyModel | None = None,
                 entry_bytes: int = LOGICAL_ENTRY_BYTES, seed: int = 0,
                 kernel_impl: str | None = None):
        self.value_words = value_words
        self.entry_bytes = entry_bytes            # logical entry size
        self._wscale = entry_bytes / LOGICAL_ENTRY_BYTES  # IO-cost scaling
        self.latency = latency or LatencyModel()
        self.metrics = LSMMetrics()
        self.compact_filter = None                # optional keys->keep mask
        self.kernel_impl = kernel_impl or DEFAULT_KERNEL_IMPL
        if self.kernel_impl not in ("numpy", "pallas"):
            raise ValueError(f"unknown kernel impl {self.kernel_impl!r}")
        self.annihilated = 0          # weight dropped by compaction filters
        self._configure_memory(memory_mb)
        # sorted-unique (keys, weights, vals) runs, newest first
        self.levels: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self.fanout = fanout
        self._empty()

    # -- memory layout (paper §3: memtable <= 64 MB, >= half to cache, pow2) --
    def _configure_memory(self, memory_mb: float) -> None:
        self.memory_mb = float(memory_mb)
        mem_budget = memory_mb * 1024 * 1024
        memtable_b = MEMTABLE_GRANULARITY_MB * 1024 * 1024
        while memtable_b >= mem_budget / 2:    # cache gets MORE than half
            memtable_b //= 2                   # (paper §3: 128 -> 32+96)
        cache_b = mem_budget - memtable_b
        self.memtable_cap = max(64, int(memtable_b // self.entry_bytes))
        n_cache = max(64, int(cache_b // (self.entry_bytes
                                          * CACHE_OVERHEAD)))
        self.cache_ways = 8
        self.cache_sets = max(8, n_cache // self.cache_ways)

    def _empty(self) -> None:
        self.mem_n = 0                # RAW write count (flush cadence key)
        self._runs: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        # collapsed delta tiers, newest first, geometrically merged so the
        # total consolidation work stays O(n log n) over a memtable epoch
        # (a single base would re-merge its whole length every
        # MEMTABLE_RUNS batches — quadratic)
        self._tiers: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self.cache_keys = np.full((self.cache_sets, self.cache_ways), -1,
                                  np.int64)
        self.cache_vals = np.zeros(
            (self.cache_sets, self.cache_ways, self.value_words), np.int32)
        self.cache_ref = np.zeros((self.cache_sets, self.cache_ways), np.int8)
        self.cache_hand = np.zeros(self.cache_sets, np.int32)
        self._cache_virgin = True        # enables the closed-form first fill
        self._mbt = None                 # batched memtable probe cache

    # ------------------------------------------------------------------ util
    @property
    def entry_count(self) -> int:
        return self.mem_n + sum(len(k) for k, _, _ in self.levels)

    @property
    def state_mb(self) -> float:
        """Logical state footprint — what migration planning prices."""
        return self.entry_count * self.entry_bytes / 2**20

    def total_weight(self) -> int:
        """Live delta weight across memtable + levels (diagnostic)."""
        w = sum(int(r[1].sum()) for r in self._runs)
        w += sum(int(t[1].sum()) for t in self._tiers)
        return w + sum(int(lw.sum()) for _, lw, _ in self.levels)

    def resize(self, memory_mb: float) -> None:
        """Vertical rescale: rebuild memtable/cache under the new budget,
        spilling the old memtable into level 0 (a Flink-style reconfig).
        Spills the consolidated deduped runs (levels must hold sorted-unique
        runs for the batched probes)."""
        if self.mem_n:
            self._push_run(*self._memtable_merged())
        self._configure_memory(memory_mb)
        self._empty()

    def items(self) -> tuple[np.ndarray, np.ndarray]:
        """All live (key, value) pairs — used for state re-partitioning.
        Memtable wins over levels; newest write wins within the memtable —
        exactly what ``get_batch`` returns."""
        k, _, v = self._items_weighted()
        return k, v

    def _items_weighted(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        # one N-way collapse over every live run, ordered oldest -> newest
        # (levels bottom-up, then tiers bottom-up, then delta runs in
        # arrival order) so the stable sort keeps the newest payload last
        # in each key group — cheaper than a pairwise merge cascade.
        sources = [s for s in (self.levels[::-1] + self._tiers[::-1]
                               + self._runs) if len(s[0])]
        acc = self._collapse(sources)
        if len(sources) == 1:
            # single live source: _collapse passes the run's arrays
            # through untouched, so hand the caller copies — items() and
            # snapshot() are public, and a caller mutating (or keeping)
            # these across a put_batch must not corrupt the live run
            acc = tuple(a.copy() for a in acc)
        return acc

    # ---------------------------------------------------------- kernel hooks
    def _probe_run(self, run_keys: np.ndarray, queries: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Batched sorted-run rank: (clipped position, hit mask).  Positions
        are only meaningful where ``hit`` — there they index the match."""
        if self.kernel_impl == "pallas":
            from jax.experimental import enable_x64

            from repro.kernels.sorted_probe.ops import probe
            with enable_x64():       # int64 keys must not truncate to int32
                pos, hit = probe(run_keys, queries, impl="pallas",
                                 interpret=True)
            pos = np.minimum(np.asarray(pos).astype(np.int64),
                             max(len(run_keys) - 1, 0))
            return pos, np.asarray(hit)
        pos = np.searchsorted(run_keys, queries)
        pos_c = np.minimum(pos, len(run_keys) - 1)
        hit = (run_keys[pos_c] == queries) & (pos < len(run_keys))
        return pos_c, hit

    def _segment_sum(self, sorted_w: np.ndarray, starts: np.ndarray,
                     first_mask: np.ndarray) -> np.ndarray:
        """Per-unique-key weight sum over key-sorted deltas — the
        consolidation reduction (``window_agg`` kernel on TPU)."""
        if self.kernel_impl == "pallas":
            from repro.kernels.window_agg.ops import aggregate
            gids = (np.cumsum(first_mask) - 1).astype(np.int32)
            sums, _ = aggregate(gids, sorted_w.astype(np.float32)[:, None],
                                int(len(starts)), impl="pallas",
                                interpret=True)
            return np.asarray(sums)[:, 0].astype(np.int64)
        return np.add.reduceat(sorted_w, starts)

    # ------------------------------------------------------------- write path
    @staticmethod
    def _dedup_newest(keys: np.ndarray, vals: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Sorted unique keys with the newest (last-written) value each."""
        rk = keys[::-1]
        uq, first = np.unique(rk, return_index=True)
        return uq, vals[::-1][first]

    @staticmethod
    def _delta_of(keys: np.ndarray, vals: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """One write batch as a delta run: sorted unique keys, per-key
        occurrence weight, newest payload."""
        rk = keys[::-1]
        uq, first, cnt = np.unique(rk, return_index=True, return_counts=True)
        return uq, cnt.astype(np.int64), vals[::-1][first]

    def put_batch(self, keys: np.ndarray, vals: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Apply one write batch; returns its delta decomposition
        (sorted unique keys, occurrence weights, newest payloads) so a
        caller probing a monotone transform of the same key batch can
        reuse the sort via ``get_batch``'s ``uhint`` (DBSP idiom: sort a
        batch once, feed every operator from the same arrangement)."""
        n = len(keys)
        self.metrics.writes += n
        self.metrics.access_latency_total_ms += \
            n * self.latency.write_ms * self._wscale
        uq, w, uv = self._delta_of(keys, vals)   # shared by runs + cache
        if n <= self.memtable_cap - self.mem_n:  # fast path: fits in room
            self.mem_n += n
            self._append_delta(uq, w, uv)
            if self.mem_n >= self.memtable_cap:
                self._flush()
        else:                                    # crosses flush boundaries
            off = 0
            while off < n:
                room = self.memtable_cap - self.mem_n
                take = min(room, n - off)
                sl = slice(off, off + take)
                self.mem_n += take
                off += take
                self._append_delta(*self._delta_of(keys[sl], vals[sl]))
                if self.mem_n >= self.memtable_cap:
                    self._flush()
        # write-through invalidate/update of cached copies
        self._cache_apply(uq, uv)
        return uq, w, uv

    def _append_delta(self, uq: np.ndarray, w: np.ndarray, uv: np.ndarray
                      ) -> None:
        self._runs.append((uq, w, uv))
        if len(self._runs) >= MEMTABLE_RUNS:
            self._consolidate()

    def _collapse(self, sources: list[tuple[np.ndarray, np.ndarray,
                                            np.ndarray]]
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """N-way collapse of delta runs ordered OLDEST -> NEWEST: one stable
        sort over the concatenation, segment weight-sum per unique key
        (``window_agg`` on TPU), newest payload wins (last in each key
        group under the stable sort)."""
        sources = [s for s in sources if len(s[0])]
        if not sources:
            return (np.empty(0, np.int64), np.empty(0, np.int64),
                    np.empty((0, self.value_words), np.int32))
        if len(sources) == 1:
            return sources[0]
        keys = np.concatenate([r[0] for r in sources])
        wts = np.concatenate([r[1] for r in sources])
        vals = np.concatenate([r[2] for r in sources])
        order = stable_argsort_keys(keys)            # ties stay oldest->newest
        sk = keys[order]
        first = np.empty(len(sk), bool)
        first[0] = True
        np.not_equal(sk[1:], sk[:-1], out=first[1:])
        starts = np.flatnonzero(first)
        out_w = self._segment_sum(wts[order], starts, first)
        last = np.empty(len(starts), np.int64)
        last[:-1] = starts[1:] - 1
        last[-1] = len(sk) - 1
        return sk[starts], out_w, vals[order[last]]

    def _consolidate(self) -> None:
        """Collapse the delta-run stack into one tier, then geometrically
        merge tiers (a tier absorbs its neighbor once it has grown to at
        least half its size) — amortized O(n log n) per memtable epoch."""
        if not self._runs:
            return
        self._tiers.insert(0, self._collapse(self._runs))
        self._runs = []
        while (len(self._tiers) > 1
               and 2 * len(self._tiers[0][0]) >= len(self._tiers[1][0])):
            newer = self._tiers.pop(0)
            self._tiers[0] = merge_delta_runs(*newer, *self._tiers[0])

    def _memtable_merged(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Full memtable content: sorted unique keys, summed weights, newest
        payloads.  Commits the pending consolidation."""
        if self._runs or len(self._tiers) > 1:
            merged = self._collapse(self._tiers[::-1] + self._runs)
            self._runs = []
            self._tiers = [merged]
        return self._tiers[0] if self._tiers else (
            np.empty(0, np.int64), np.empty(0, np.int64),
            np.empty((0, self.value_words), np.int32))

    def bulk_load(self, keys: np.ndarray, vals: np.ndarray,
                  weights: np.ndarray | None = None) -> None:
        """Pre-population fast path: dedupe (newest wins, like ``_flush``)
        and install everything as one sorted run, bypassing the memtable and
        its flush/compaction churn.  No latency is charged and no metrics are
        touched — callers reset metrics after warming anyway.  The live
        entry set is identical to an equivalent ``put_batch`` sequence.
        ``weights`` (for already-deduped input) preserves delta weights
        across snapshot/restore; without it each occurrence weighs 1."""
        if len(keys) == 0:
            return
        if weights is not None:
            self.levels.insert(0, (keys, np.asarray(weights, np.int64), vals))
            return
        self.levels.insert(0, self._delta_of(keys, vals))

    def install_run(self, keys: np.ndarray, vals: np.ndarray,
                    weights: np.ndarray | None = None) -> None:
        """Engine state-install entry point: ``keys`` already key-sorted
        (the re-partitioning path pre-sorts), installed as one run with
        size-tiered compaction applied."""
        if weights is None:
            weights = np.ones(len(keys), np.int64)
        self._push_run(keys, weights, vals)

    def _flush(self) -> None:
        if self.mem_n == 0:
            return
        uniq, wts, fvals = self._memtable_merged()
        if self.compact_filter is not None and len(uniq):
            keep = self.compact_filter(uniq)
            if not keep.all():
                self.annihilated += int(wts[~keep].sum())
                uniq, wts, fvals = uniq[keep], wts[keep], fvals[keep]
        self._push_run(uniq, wts, fvals)
        self.mem_n = 0
        self._runs = []
        self._tiers = []
        self.metrics.flushes += 1
        self.metrics.access_latency_total_ms += \
            (len(uniq) * self.latency.flush_ms
             + self.latency.flush_fixed_ms) * self._wscale

    def _push_run(self, keys: np.ndarray, weights: np.ndarray,
                  vals: np.ndarray) -> None:
        self.levels.insert(0, (keys, weights, vals))
        # size-tiered compaction: merge while a level outgrows fanout^i
        base = max(self.memtable_cap, 1)
        i = 0
        while i < len(self.levels) - 1:
            if len(self.levels[i][0]) >= base * (self.fanout ** i):
                self._merge_levels(i)
                self.metrics.compactions += 1
            else:
                i += 1

    def _merge_levels(self, i: int) -> None:
        k1, w1, v1 = self.levels[i]          # newer
        k2, w2, v2 = self.levels[i + 1]      # older
        n_in = len(k1) + len(k2)
        uniq, wts, vals = merge_delta_runs(k1, w1, v1, k2, w2, v2)
        if self.compact_filter is not None and len(uniq):
            keep = self.compact_filter(uniq)
            if not keep.all():
                self.annihilated += int(wts[~keep].sum())
                uniq, wts, vals = uniq[keep], wts[keep], vals[keep]
        self.levels[i + 1] = (uniq, wts, vals)
        del self.levels[i]
        self.metrics.access_latency_total_ms += \
            n_in * self.latency.compact_ms * self._wscale

    # -------------------------------------------------------------- read path
    def get_batch(self, keys: np.ndarray,
                  uhint: tuple[np.ndarray, np.ndarray] | None = None
                  ) -> tuple[np.ndarray, np.ndarray]:
        """Returns (values [n, V], found mask [n]) and updates θ/τ metrics.

        ``uhint`` is an optional precomputed ``(unique_keys, counts)`` for
        ``keys`` — callers that just wrote a batch whose sort order matches
        (e.g. the same events keyed for the opposite join side) pass the
        ``put_batch`` decomposition through a monotone shift and skip the
        sort here; the inverse map is recovered with one searchsorted.
        The hint MUST equal ``np.unique(keys, return_counts=True)`` —
        results and metric charges are then bit-identical to the unhinted
        call.

        Duplicate-probe accounting (unchanged from the fast-path engine):
        the block cache is probed once per *unique* key; duplicate
        occurrences of a resolved key are charged as hits on the
        just-admitted block, duplicates of absent keys re-walk the bloom
        filters.  Per-call metric equality on arbitrary batches vs the
        chunked seed is NOT claimed — golden-trace decision equality is."""
        n = len(keys)
        self.metrics.reads += n
        lat = 0.0
        # every tier below works on unique keys: all occurrences of a key
        # resolve identically, so probe once and scatter through ``inv`` at
        # the end — occurrence-level metric charges recovered via ``cnts``
        if uhint is None:
            uq, inv, cnts = np.unique(keys, return_inverse=True,
                                      return_counts=True)
        else:
            uq, cnts = uhint
            inv = np.searchsorted(uq, keys)
        uvals = np.zeros((len(uq), self.value_words), np.int32)
        ufound = np.zeros(len(uq), bool)

        # 1. memtable: probe delta runs newest-first, then the tiers — the
        # first run containing a key holds its newest payload.  One
        # source-major searchsorted covers every run at once (see
        # _mem_concat); the per-run loop remains as the fallback for the
        # pallas kernel dispatch and out-of-range keys.  Both find the same
        # key set with the same newest payload, so θ/τ charges agree.
        if self.mem_n:
            T = None
            if self.kernel_impl != "pallas":
                T, offs, srcs = self._mem_concat()
            fast = False
            if T is not None and len(T) and len(uq):
                # stored keys are in [0, 2^45) (else _mem_concat bailed),
                # but QUERY keys arrive unchecked: a query outside that
                # range would land in another source's band after packing
                # and false-hit its keys, so such batches (and empty
                # query sets) take the per-run fallback below
                lim = np.int64(1) << self._MEM_SHIFT
                fast = bool(int(uq[0]) >= 0 and int(uq[-1]) < lim)
            if fast:
                R = len(srcs)
                assert R < (1 << 18)   # source ids share the 63-45 headroom
                nu = len(uq)
                qq = ((np.arange(R, dtype=np.int64)[:, None]
                       << self._MEM_SHIFT) + uq[None, :]).ravel()
                pos = np.searchsorted(T, qq)
                np.minimum(pos, len(T) - 1, out=pos)
                hit = (T[pos] == qq).reshape(R, nu)[::-1]  # newest first
                si = hit.argmax(axis=0)
                fnd = hit[si, np.arange(nu)]
                fidx = np.flatnonzero(fnd)
                if len(fidx):
                    src = R - 1 - si[fidx]          # undo the flip
                    ufound[fidx] = True
                    self.metrics.memtable_hits += int(cnts[fidx].sum())
                    posm = pos.reshape(R, nu)
                    for i in np.flatnonzero(np.bincount(src, minlength=R)):
                        sel = fidx[src == i]
                        uvals[sel] = srcs[i][2][posm[i, sel] - offs[i]]
            else:
                mem_hits = 0
                pending = None               # None => every key outstanding
                for rk, _w, rv in self._mem_probe_order():
                    if not len(rk):
                        continue
                    if pending is None:
                        tk = uq
                    else:
                        if not len(pending):
                            break
                        tk = uq[pending]
                    pos, hit = self._probe_run(rk, tk)
                    hidx = np.flatnonzero(hit)
                    if len(hidx):
                        idx = hidx if pending is None else pending[hidx]
                        uvals[idx] = rv[pos[hidx]]
                        ufound[idx] = True
                        mem_hits += int(cnts[idx].sum())   # per-occurrence
                    pending = np.flatnonzero(~hit) if pending is None \
                        else pending[~hit]
                self.metrics.memtable_hits += mem_hits
        lat += n * self.latency.memtable_ms

        # 2. block cache — probed once per *unique* key (see docstring).
        if not ufound.all():
            sub = np.flatnonzero(~ufound)
            uk = uq[sub]
            n_todo = n - int(cnts[ufound].sum())   # unfound occurrences
            sets = self._sets(uk)
            match = self.cache_keys[sets] == uk[:, None]        # [u, ways]
            # argmax-then-gather: one reduction pass instead of any+argmax
            # (axis-wise ``any`` costs a full second pass; an all-False row
            # argmaxes to way 0 where the gather reads False)
            way = match.argmax(axis=1)
            hit = match[np.arange(len(uk)), way]
            hi = np.flatnonzero(hit)
            sh, wh = sets[hi], way[hi]
            ckvals = np.zeros((len(uk), self.value_words), np.int32)
            ckvals[hi] = self.cache_vals[sh, wh]
            ckfound = hit           # safe alias: ~hit is consumed (rem)
                                    # before ckfound's only mutation below
            self.cache_ref[sh, wh] = 1
            self.metrics.cache_hits += len(hi)
            self.metrics.cache_misses += len(uk) - len(hi)
            lat += len(uk) * self.latency.cache_ms

            # 3. levels (slow tier) for cache misses.  Bloom filters guard
            # each SSTable: absent keys cost a filter check (plus the
            # false-positive rate of real probes) instead of a full read.
            rem = np.where(~hit)[0]
            if len(rem):
                probe_keys = uk[rem]
                got = np.zeros(len(rem), bool)
                gvals = np.zeros((len(rem), self.value_words), np.int32)
                probes = 0.0
                blooms = 0
                for (lk, _lw, lv) in self.levels:
                    lidx = np.flatnonzero(~got)
                    n_live = len(lidx)
                    if not n_live:
                        break
                    if len(lk):
                        pos, h = self._probe_run(lk, probe_keys[lidx])
                    else:
                        h = np.zeros(n_live, bool)
                        pos = h
                    n_hit = int(h.sum())
                    # present keys pass the bloom filter and read the block;
                    # absent keys mostly stop at the filter — but the filter/
                    # index blocks themselves need block-cache residency:
                    # with a small cache a share of filter checks also hits
                    # the slow tier (RocksDB filter-block eviction)
                    meta_ws = max(1.0, len(lk) / self.latency.meta_ratio)
                    meta_cover = min(1.0, self.cache_capacity / meta_ws)
                    probes += n_hit + self.latency.bloom_fp * (n_live - n_hit)
                    probes += (1.0 - meta_cover) \
                        * self.latency.meta_read_frac * n_live
                    blooms += n_live
                    if n_hit:
                        hh = np.flatnonzero(h)
                        tgt = lidx[hh]
                        gvals[tgt] = lv[pos[hh]]
                        got[tgt] = True
                ckvals[rem[got]] = gvals[got]
                ckfound[rem[got]] = True
                self.metrics.level_probes += int(probes)
                lat += (probes * self.latency.level_ms
                        + blooms * self.latency.bloom_ms)
                # admit fetched entries into the cache (probe_keys is
                # sorted-unique, so the deduping _cache_update is skipped)
                if got.any():
                    self._cache_apply(probe_keys[got], gvals[got],
                                      fresh=True)

            uvals[sub] = ckvals
            ufound[sub] = ckfound
            n_dup = n_todo - len(uk)
            if n_dup:
                res_dups = int((cnts[sub][ckfound] - 1).sum())
                unres_dups = n_dup - res_dups
                # resolved duplicates hit the (possibly just-admitted) block
                self.metrics.cache_hits += res_dups
                self.metrics.cache_misses += unres_dups
                lat += n_dup * self.latency.cache_ms
                if unres_dups:
                    probes = 0.0
                    for (lk, _lw, _lv) in self.levels:
                        meta_ws = max(1.0, len(lk) / self.latency.meta_ratio)
                        meta_cover = min(1.0, self.cache_capacity / meta_ws)
                        probes += (self.latency.bloom_fp + (1.0 - meta_cover)
                                   * self.latency.meta_read_frac) * unres_dups
                    self.metrics.level_probes += int(probes)
                    lat += (probes * self.latency.level_ms + unres_dups
                            * len(self.levels) * self.latency.bloom_ms)

        self.metrics.access_latency_total_ms += lat
        return uvals[inv], ufound[inv]

    def _mem_probe_order(self):
        """Memtable runs in read-priority order: newest delta run first,
        then the collapsed tiers (themselves newest-first)."""
        for i in range(len(self._runs) - 1, -1, -1):
            yield self._runs[i]
        yield from self._tiers

    _MEM_SHIFT = np.int64(45)            # source-major probe prefix width

    def _mem_concat(self):
        """Source-major concat of every memtable source, oldest first:
        ``(i << 45) | key`` per source i keeps the concat globally sorted,
        so ONE searchsorted probes all runs and tiers at once (the per-run
        loop pays ~10 numpy dispatches per source).  Priority is the
        prefix: the highest hitting source is the newest.  Cached across
        gets; a single appended run extends the concat incrementally.
        Returns None (=> per-run fallback) for keys outside [0, 2^45)."""
        srcs = self._tiers[::-1] + self._runs
        ids = tuple(id(s[0]) for s in srcs)
        c = self._mbt
        if c is not None and c[0] == ids:
            return c[1], c[2], c[3]
        lim = np.int64(1) << self._MEM_SHIFT
        n_src = len(srcs)
        assert n_src < (1 << 18)         # source ids must fit 63-45 bits
        if c is not None and len(ids) == len(c[0]) + 1 \
                and c[0] == ids[:-1]:
            rk = srcs[-1][0]             # one new run appended at the end
            if len(rk) and (rk[0] < 0 or rk[-1] >= lim):
                self._mbt = None
                return None, None, None
            nprev = len(c[0])
            assert nprev < n_src         # its band is the next source id
            T = np.concatenate(
                [c[1], (np.int64(nprev) << self._MEM_SHIFT) + rk])
            offs = c[2] + [len(c[1])]
        else:
            parts = []
            for i, (rk, _w, _v) in enumerate(srcs):
                if len(rk) and (rk[0] < 0 or rk[-1] >= lim):
                    self._mbt = None
                    return None, None, None
                parts.append((np.int64(i) << self._MEM_SHIFT) + rk)
            T = np.concatenate(parts) if parts else np.empty(0, np.int64)
            offs, o = [], 0
            for p in parts:
                offs.append(o)
                o += len(p)
        self._mbt = (ids, T, offs, srcs)
        return T, offs, srcs

    # ----------------------------------------------------------------- cache
    def _sets(self, keys: np.ndarray) -> np.ndarray:
        h = keys.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
        h ^= h >> np.uint64(29)
        return ((h >> np.uint64(1)).astype(np.int64) % self.cache_sets)

    def _cache_update(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Insert/overwrite entries (CLOCK eviction within each set)."""
        if len(keys) == 0:
            return
        # dedupe (last wins) to avoid write conflicts in the vectorized scatter
        self._cache_apply(*self._dedup_newest(keys, vals))

    def _cache_apply(self, keys: np.ndarray, vals: np.ndarray,
                     fresh: bool = False) -> None:
        """``_cache_update`` for already-deduped sorted (keys, vals).

        ``fresh=True`` asserts no key is currently cached (the level-read
        admission path: those keys just missed the probe in the same
        ``get_batch`` call), skipping the pointless hit scan."""
        if len(keys) == 0:
            return
        sets = self._sets(keys)
        if self._cache_virgin:           # first fill: no hits possible and
            self._cache_virgin = False   # every CLOCK scan lands instantly
            self._clock_fill_virgin(sets, keys, vals)
            return
        if fresh:
            ms, mk, mv = sets, keys, vals
        else:
            match = self.cache_keys[sets] == keys[:, None]
            way = match.argmax(axis=1)      # see get_batch: fused any+argmax
            hit = match[np.arange(len(keys)), way]
            hi = np.flatnonzero(hit)
            sh, wh = sets[hi], way[hi]
            self.cache_vals[sh, wh] = vals[hi]
            self.cache_ref[sh, wh] = 1
            # misses: CLOCK — evict first way with ref=0, clearing refs as
            # we pass.  Vectorized across sets: misses are grouped by set
            # (stable, so ascending-key insertion order is preserved) and
            # inserted in rounds — round r does every set's r-th pending
            # insert at once.  Bit-for-bit equivalent to the sequential
            # per-entry CLOCK scan.
            miss = np.flatnonzero(~hit)
            if not len(miss):
                return
            ms, mk, mv = sets[miss], keys[miss], vals[miss]
        # radix-sortable set indices (see _clock_fill_virgin)
        ss = ms.astype(np.uint16) if self.cache_sets <= (1 << 16) else ms
        order = np.argsort(ss, kind="stable")
        ms, mk, mv = ms[order], mk[order], mv[order]
        if len(ms) == 1 or (ms[1:] != ms[:-1]).all():
            self._clock_insert(ms, mk, mv)   # all sets distinct: one round
            return
        if self.cache_ways == 8:
            self._clock_insert_multi(ms, mk, mv)
            return
        rank = np.arange(len(ms)) - np.searchsorted(ms, ms, side="left")
        for r in range(int(rank.max()) + 1):
            sel = rank == r
            self._clock_insert(ms[sel], mk[sel], mv[sel])

    def _clock_fill_virgin(self, sets: np.ndarray, keys: np.ndarray,
                           vals: np.ndarray) -> None:
        """Closed-form CLOCK state after inserting into an all-empty cache.

        Starting from hand=0/ref=0, the r-th insert into a set provably goes
        to way ``r % W`` (a full pass clears every ref, so the wrapped scan
        again stops immediately), leaving hand = count % W and ref = 1
        exactly for the ways of the last incomplete pass (all ways when the
        count divides evenly).  Bit-identical to the sequential scan, with
        no per-round work.
        """
        # set indices come from ``_sets`` (mod cache_sets), but arrive here
        # as a bare parameter: pin the range the uint16 radix cast needs
        assert int(sets.min()) >= 0 and int(sets.max()) < self.cache_sets
        # numpy's stable argsort radix-sorts <=16-bit ints (13x faster than
        # the int64 mergesort); set indices usually fit
        ss = sets.astype(np.uint16) if self.cache_sets <= (1 << 16) else sets
        order = np.argsort(ss, kind="stable")     # key-ascending within set
        self._fill_virgin_sorted(sets[order], keys[order], vals[order])

    def _fill_virgin_sorted(self, s: np.ndarray, k: np.ndarray,
                            v: np.ndarray) -> None:
        """_clock_fill_virgin body for input already sorted by (set, key)."""
        W = self.cache_ways
        n = len(s)
        change = np.flatnonzero(np.r_[True, s[1:] != s[:-1]])
        cnt = np.diff(np.r_[change, n])
        rank = np.arange(n) - np.repeat(change, cnt)
        way = (rank % W).astype(np.int32)
        # winners per (set, way) are exactly the last min(count, W) entries
        # of each set group (their ways are distinct by construction)
        mask = (np.repeat(change + cnt, cnt) - np.arange(n)) <= W
        self.cache_keys[s[mask], way[mask]] = k[mask]
        self.cache_vals[s[mask], way[mask]] = v[mask]
        us = s[change]
        m = (cnt % W).astype(np.int32)
        self.cache_hand[us] = m
        self.cache_ref[us] = ((np.arange(W)[None, :] < m[:, None])
                              | (m[:, None] == 0)).astype(np.int8)

    def _clock_insert(self, s: np.ndarray, k: np.ndarray, v: np.ndarray
                      ) -> None:
        """One CLOCK insertion per (distinct) set in ``s``, vectorized.

        Per set: scan ways from the hand, clearing ref bits as we pass,
        until a way with ref=0 is found (if all refs were set, the full
        pass clears them and the original hand position is the victim).
        """
        W = self.cache_ways
        hand = self.cache_hand[s]
        if not self.cache_ref[s, hand].any():
            # every hand already points at a ref=0 victim: no scan, no
            # ref clearing — insert at the hand and advance it
            self.cache_keys[s, hand] = k
            self.cache_vals[s, hand] = v
            self.cache_ref[s, hand] = 1
            self.cache_hand[s] = (hand + 1) % W
            return
        if W == 8:
            # pack each set's ref row into a byte; the scan (find first
            # zero from the hand, clearing passed refs) becomes rotate +
            # two table lookups — no [m, W] index matrices
            bits = (self.cache_ref[s].astype(np.uint8) @ _CLOCK_POW2
                    ).astype(np.uint16)
            h = hand.astype(np.uint16)
            rot_bits = ((bits >> h) | (bits << (8 - h))) & np.uint16(0xFF)
            j = _CLOCK_FIRST_ZERO[rot_bits]
            has0 = j < 8
            j = np.where(has0, j, 0)
            slot = ((hand + j) % W).astype(np.int32)
            # cleared prefix in the rotated frame, rotated back
            pre = np.where(has0, (1 << j) - 1, 0xFF).astype(np.uint16)
            mask = ((pre << h) | (pre >> (8 - h))) & np.uint16(0xFF)
            new_bits = (bits & ~mask) | (1 << slot)
            self.cache_ref[s] = _CLOCK_UNPACK[new_bits & 0xFF]
            self.cache_keys[s, slot] = k
            self.cache_vals[s, slot] = v
            self.cache_hand[s] = (slot + 1) % W
            return
        rot = (hand[:, None] + np.arange(W, dtype=np.int32)) % W
        refs = self.cache_ref[s[:, None], rot]                  # [m, W]
        zero = refs == 0
        has0 = zero.any(axis=1)
        j = np.where(has0, zero.argmax(axis=1), 0)
        # clear refs the hand passed over (all W ways when none were zero)
        clear = np.arange(W)[None, :] < j[:, None]
        clear[~has0] = True
        rows = np.broadcast_to(s[:, None], rot.shape)
        self.cache_ref[rows[clear], rot[clear]] = 0
        slot = rot[np.arange(len(s)), j]
        self.cache_keys[s, slot] = k
        self.cache_vals[s, slot] = v
        self.cache_ref[s, slot] = 1
        self.cache_hand[s] = (slot + 1) % W

    def _clock_insert_multi(self, ms: np.ndarray, mk: np.ndarray,
                            mv: np.ndarray) -> None:
        """Sequential CLOCK insertions with repeated sets, W == 8 only.

        ``ms`` is sorted by set (stable, so per-set insertion order is the
        arrival order).  Equivalent to the round loop over ``_clock_insert``
        but the packed ref byte and hand live in local arrays across rounds
        — the cache arrays are read once and written once, instead of a
        gather/scatter per round.
        """
        W = self.cache_ways
        n = len(ms)
        change = np.flatnonzero(np.r_[True, ms[1:] != ms[:-1]])
        us = ms[change]
        cnt = np.diff(np.r_[change, n])
        bits = (self.cache_ref[us].astype(np.uint8) @ _CLOCK_POW2
                ).astype(np.uint16)
        hand = self.cache_hand[us].astype(np.uint16)
        slots = np.empty(n, np.int64)
        for r in range(int(cnt.max())):
            act = np.flatnonzero(cnt > r)
            b, h = bits[act], hand[act]
            rot = ((b >> h) | (b << (8 - h))) & np.uint16(0xFF)
            j = _CLOCK_FIRST_ZERO[rot]
            has0 = j < 8
            j = np.where(has0, j, 0)
            slot = (h + j) % W
            pre = np.where(has0, (1 << j) - 1, 0xFF).astype(np.uint16)
            mask = ((pre << h) | (pre >> (8 - h))) & np.uint16(0xFF)
            bits[act] = ((b & ~mask) | (1 << slot)) & np.uint16(0xFF)
            hand[act] = (slot + 1) % W
            slots[change[act] + r] = slot
        self.cache_ref[us] = _CLOCK_UNPACK[bits & 0xFF]
        self.cache_hand[us] = hand.astype(np.int32)
        lin = ms * W + slots
        if (cnt > W).any():
            # > W inserts into one set can revisit a slot; keep the last
            # write per (set, way) so the scatter below is conflict-free
            order = np.argsort(lin, kind="stable")
            ll = lin[order]
            keep = order[np.flatnonzero(np.r_[ll[1:] != ll[:-1], True])]
            lin, mk, mv = lin[keep], mk[keep], mv[keep]
        self.cache_keys.reshape(-1)[lin] = mk
        self.cache_vals.reshape(-1, self.cache_vals.shape[-1])[lin] = mv

    @property
    def cache_capacity(self) -> int:
        return self.cache_sets * self.cache_ways

    def prewarm_cache(self, keys: np.ndarray, vals: np.ndarray,
                      rng: np.random.Generator | None = None) -> None:
        """Fill the cache to capacity with a uniform sample of the live
        entries — steady-state emulation so short observation windows see
        the equilibrium hit rate rather than a cold-start transient."""
        if len(keys) == 0:
            return
        cap = self.cache_capacity
        if len(keys) > cap:
            rng = rng or np.random.default_rng(0)
            idx = rng.choice(len(keys), cap, replace=False)
            keys, vals = keys[idx], vals[idx]
        # A fresh cache takes the closed-form virgin fill, whose first step
        # re-sorts the (key-sorted) batch by set.  Fuse both sorts into ONE
        # argsort of (set << 47) | key — same final (set, key) order, one
        # mergesort cheaper per prewarm.  Duplicate keys collide in the
        # packed word exactly when they collide as keys (same key => same
        # set), so the dedup fallback check carries over.
        if (self._cache_virgin and len(keys) > 1
                and self.cache_sets <= (1 << 15)
                and int(keys.min()) >= 0 and int(keys.max()) < (1 << 47)):
            sets = self._sets(keys)
            comb = (sets << np.int64(47)) | keys
            order = np.argsort(comb, kind="stable")
            ck = comb[order]
            if not (ck[1:] == ck[:-1]).any():
                self._cache_virgin = False
                self._fill_virgin_sorted(sets[order], keys[order],
                                         vals[order])
                self.metrics.reset()
                return
        # store-derived keys are unique, so sorting alone reproduces
        # _cache_update's dedup ordering; fall back to the deduping path
        # if a caller hands us duplicates
        order = stable_argsort_keys(keys)
        sk = keys[order]
        if len(sk) > 1 and (sk[1:] == sk[:-1]).any():
            self._cache_update(keys, vals)
        else:
            self._cache_apply(sk, vals[order])
        self.metrics.reset()

    # ------------------------------------------------------------- snapshots
    def snapshot(self) -> dict:
        """Epoch-barrier snapshot (Flink-checkpoint analogue).  Carries the
        delta weights so a restore preserves the Z-set, not just the
        last-write-wins view."""
        keys, weights, vals = self._items_weighted()
        return {"keys": keys, "vals": vals, "weights": weights,
                "memory_mb": self.memory_mb, "value_words": self.value_words}

    @classmethod
    def restore(cls, snap: dict, *, memory_mb: float | None = None,
                **kw) -> "LSMStore":
        store = cls(memory_mb if memory_mb is not None else snap["memory_mb"],
                    value_words=snap["value_words"], **kw)
        if len(snap["keys"]):
            w = snap.get("weights")
            store._push_run(np.asarray(snap["keys"], np.int64),
                            np.ones(len(snap["keys"]), np.int64) if w is None
                            else np.asarray(w, np.int64),
                            np.asarray(snap["vals"], np.int32))
        return store


# ------------------------------------------------------------- store factory
# The engine/operators build state through here so benchmarks and the
# differential harness can swap the frozen pre-columnar store
# (repro.state.legacy) in-process and compare like for like.
_ACTIVE_STORE_IMPL = "columnar"


def set_store_impl(name: str) -> None:
    global _ACTIVE_STORE_IMPL
    if name not in ("columnar", "legacy"):
        raise ValueError(f"unknown store impl {name!r}")
    _ACTIVE_STORE_IMPL = name


def get_store_impl() -> str:
    return _ACTIVE_STORE_IMPL


def store_class(name: str | None = None):
    if (name or _ACTIVE_STORE_IMPL) == "columnar":
        return LSMStore
    from repro.state.legacy import LegacyLSMStore
    return LegacyLSMStore


def make_store(memory_mb: float, **kw) -> "LSMStore":
    return store_class()(memory_mb, **kw)
