"""Frozen pre-columnar LSM store — the differential-testing oracle.

This is the PR 1-6 ``LSMStore`` (row-oriented memtable view, per-put dedup
argsort, np.insert spills), preserved verbatim as ``LegacyLSMStore`` when
``repro.state.lsm`` was rebuilt around the columnar delta representation.
It exists for two consumers and must NOT be optimized or "fixed":

* ``tests/test_lsm_differential.py`` drives random op sequences through
  this store, the columnar store and a dict model, asserting identical
  observable state (values, items, metrics, bit-identical CLOCK cache);
* ``benchmarks/run.py lsm`` runs both implementations in one process and
  commits the speedup ratio to ``BENCH_lsm.json`` (machine-independent
  regression gate).

Select it engine-wide with ``repro.state.lsm.set_store_impl("legacy")``.
Shared pieces (metrics, latency model, sizing constants) are imported from
``repro.state.lsm`` so the two implementations are compared under one
accounting model.
"""
from __future__ import annotations

import numpy as np

from repro.state.lsm import (CACHE_OVERHEAD, LOGICAL_ENTRY_BYTES,
                             MEMTABLE_GRANULARITY_MB, LatencyModel,
                             LSMMetrics)


def _merge_sorted_unique(k1: np.ndarray, v1: np.ndarray,
                         k2: np.ndarray, v2: np.ndarray
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Merge two sorted-unique (keys, vals) arrays; k1 wins duplicates.
    O(n) scatter instead of re-sorting the concatenation."""
    pos = np.searchsorted(k1, k2)
    if len(k1):
        dup = (k1[np.minimum(pos, len(k1) - 1)] == k2) & (pos < len(k1))
        if dup.any():
            k2, v2 = k2[~dup], v2[~dup]
    out_k = np.empty(len(k1) + len(k2), k1.dtype)
    out_v = np.empty((len(out_k),) + v1.shape[1:], v1.dtype)
    i1 = np.arange(len(k1)) + np.searchsorted(k2, k1, side="left")
    i2 = np.arange(len(k2)) + np.searchsorted(k1, k2, side="right")
    out_k[i1] = k1
    out_v[i1] = v1
    out_k[i2] = k2
    out_v[i2] = v2
    return out_k, out_v


class LegacyLSMStore:
    """Vectorized LSM over int64 keys -> fixed-width int32 value vectors."""

    def __init__(self, memory_mb: float, *, value_words: int = 4,
                 fanout: int = 8, latency: LatencyModel | None = None,
                 entry_bytes: int = LOGICAL_ENTRY_BYTES, seed: int = 0):
        self.value_words = value_words
        self.entry_bytes = entry_bytes            # logical entry size
        self._wscale = entry_bytes / LOGICAL_ENTRY_BYTES  # IO-cost scaling
        self.latency = latency or LatencyModel()
        self.metrics = LSMMetrics()
        self.compact_filter = None                # optional keys->keep mask
        self._configure_memory(memory_mb)
        self.levels: list[tuple[np.ndarray, np.ndarray]] = []
        self.fanout = fanout
        self._empty()

    # -- memory layout (paper §3: memtable <= 64 MB, >= half to cache, pow2) --
    def _configure_memory(self, memory_mb: float) -> None:
        self.memory_mb = float(memory_mb)
        mem_budget = memory_mb * 1024 * 1024
        memtable_b = MEMTABLE_GRANULARITY_MB * 1024 * 1024
        while memtable_b >= mem_budget / 2:    # cache gets MORE than half
            memtable_b //= 2                   # (paper §3: 128 -> 32+96)
        cache_b = mem_budget - memtable_b
        self.memtable_cap = max(64, int(memtable_b // self.entry_bytes))
        n_cache = max(64, int(cache_b // (self.entry_bytes
                                          * CACHE_OVERHEAD)))
        self.cache_ways = 8
        self.cache_sets = max(8, n_cache // self.cache_ways)

    def _empty(self) -> None:
        self.mem_keys = np.empty(self.memtable_cap, np.int64)
        self.mem_vals = np.empty((self.memtable_cap, self.value_words),
                                 np.int32)
        self.mem_n = 0
        # sorted newest-wins view of the memtable, maintained incrementally
        # on writes so the read path never re-sorts the write buffer.  A
        # small sorted delta absorbs writes (cheap re-sort of a few K) and
        # is merged into the base only when it fills, bounding the O(view)
        # np.insert shuffle to once per `_delta_cap` written keys.
        self._view_keys = np.empty(0, np.int64)
        self._view_vals = np.empty((0, self.value_words), np.int32)
        self._delta_keys = np.empty(0, np.int64)
        self._delta_vals = np.empty((0, self.value_words), np.int32)
        self._delta_cap = max(2048, self.memtable_cap // 16)
        self.cache_keys = np.full((self.cache_sets, self.cache_ways), -1,
                                  np.int64)
        self.cache_vals = np.zeros(
            (self.cache_sets, self.cache_ways, self.value_words), np.int32)
        self.cache_ref = np.zeros((self.cache_sets, self.cache_ways), np.int8)
        self.cache_hand = np.zeros(self.cache_sets, np.int32)
        self._cache_virgin = True        # enables the closed-form first fill

    # ------------------------------------------------------------------ util
    @property
    def entry_count(self) -> int:
        return self.mem_n + sum(len(k) for k, _ in self.levels)

    @property
    def state_mb(self) -> float:
        """Logical state footprint — what migration planning prices."""
        return self.entry_count * self.entry_bytes / 2**20

    def install_run(self, keys: np.ndarray, vals: np.ndarray,
                    weights=None) -> None:
        """Engine state-install entry point.  Weights are ignored: this
        store predates the delta representation and keys carry no weight."""
        self._push_run(keys, vals)

    def resize(self, memory_mb: float) -> None:
        """Vertical rescale: rebuild memtable/cache under the new budget,
        spilling the old memtable into level 0 (a Flink-style reconfig).
        Spills the sorted deduped view (the raw write log is unsorted, and
        levels must hold sorted runs for ``searchsorted`` probes)."""
        if self.mem_n:
            self._push_run(*self._view_merged())
        self._configure_memory(memory_mb)
        self._empty()

    def items(self) -> tuple[np.ndarray, np.ndarray]:
        """All live (key, value) pairs — used for state re-partitioning.

        The memtable wins over levels, and the NEWEST write wins among
        duplicates within the memtable log — exactly what ``get_batch``
        returns, so a mid-memtable snapshot (re-partitioning, warm-state
        install) carries the same values a read would see.  (The seed
        resolved in-log duplicates to the OLDEST write, leaving snapshots
        stale for hot keys; fixed here, goldens regenerated — see
        docs/golden-traces.md.)  Built from the maintained sorted
        newest-wins view + sorted 2-way merges instead of one big sort."""
        acc = None
        if self.mem_n:
            vk, vv = self._view_merged()
            acc = (vk, vv)
        for k, v in self.levels:
            if not len(k):
                continue
            acc = (k, v) if acc is None else \
                _merge_sorted_unique(acc[0], acc[1], k, v)
        if acc is None:
            return (np.empty(0, np.int64),
                    np.empty((0, self.value_words), np.int32))
        if acc[0] is self._view_keys:
            # mem-only result: don't alias the live view, which the write
            # path mutates in place (snapshots must stay frozen)
            return acc[0].copy(), acc[1].copy()
        return acc

    # ------------------------------------------------------------- write path
    @staticmethod
    def _dedup_newest(keys: np.ndarray, vals: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Sorted unique keys with the newest (last-written) value each."""
        rk = keys[::-1]
        uq, first = np.unique(rk, return_index=True)
        return uq, vals[::-1][first]

    def put_batch(self, keys: np.ndarray, vals: np.ndarray) -> None:
        n = len(keys)
        self.metrics.writes += n
        self.metrics.access_latency_total_ms += \
            n * self.latency.write_ms * self._wscale
        uq, uv = self._dedup_newest(keys, vals)  # shared by view + cache
        if n <= self.memtable_cap - self.mem_n:  # fast path: fits in room
            self.mem_keys[self.mem_n:self.mem_n + n] = keys
            self.mem_vals[self.mem_n:self.mem_n + n] = vals
            self.mem_n += n
            self._mem_merge(uq, uv)
            if self.mem_n >= self.memtable_cap:
                self._flush()
        else:                                    # crosses flush boundaries
            off = 0
            while off < n:
                room = self.memtable_cap - self.mem_n
                take = min(room, n - off)
                sl = slice(off, off + take)
                self.mem_keys[self.mem_n:self.mem_n + take] = keys[sl]
                self.mem_vals[self.mem_n:self.mem_n + take] = vals[sl]
                self.mem_n += take
                off += take
                self._mem_merge(*self._dedup_newest(keys[sl], vals[sl]))
                if self.mem_n >= self.memtable_cap:
                    self._flush()
        # write-through invalidate/update of cached copies
        self._cache_apply(uq, uv)

    def _mem_merge(self, uq: np.ndarray, cv: np.ndarray) -> None:
        """Merge deduped sorted (keys, newest vals) into the memtable view
        (into the delta buffer; spilled to the base view when it fills).
        Both sides are sorted-unique, so this is an O(n) merge with the
        incoming write winning duplicates."""
        if len(self._delta_keys):
            uq, cv = _merge_sorted_unique(uq, cv,
                                          self._delta_keys, self._delta_vals)
        self._delta_keys, self._delta_vals = uq, cv
        if len(uq) >= self._delta_cap:
            self._spill_delta()

    def _spill_delta(self) -> None:
        uq, cv = self._delta_keys, self._delta_vals
        if not len(uq):
            return
        self._delta_keys = np.empty(0, np.int64)
        self._delta_vals = np.empty((0, self.value_words), np.int32)
        vk = self._view_keys
        pos = np.searchsorted(vk, uq)
        if len(vk):
            exists = vk[np.minimum(pos, len(vk) - 1)] == uq
            exists &= pos < len(vk)
        else:
            exists = np.zeros(len(uq), bool)
        if exists.any():
            self._view_vals[pos[exists]] = cv[exists]
        ins = ~exists
        if ins.any():
            self._view_keys = np.insert(vk, pos[ins], uq[ins])
            self._view_vals = np.insert(self._view_vals, pos[ins], cv[ins],
                                        axis=0)

    def _view_merged(self) -> tuple[np.ndarray, np.ndarray]:
        """Full memtable content: sorted unique keys, newest value each."""
        if not len(self._delta_keys):
            return self._view_keys, self._view_vals
        return self._dedup_newest(          # delta appended last => wins
            np.concatenate([self._view_keys, self._delta_keys]),
            np.concatenate([self._view_vals, self._delta_vals]))

    def bulk_load(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Pre-population fast path: dedupe (newest wins, like ``_flush``)
        and install everything as one sorted run, bypassing the memtable and
        its flush/compaction churn.  No latency is charged and no metrics are
        touched — callers reset metrics after warming anyway.  The live
        entry set is identical to an equivalent ``put_batch`` sequence."""
        if len(keys) == 0:
            return
        rk, rv = keys[::-1], vals[::-1]
        uniq, first = np.unique(rk, return_index=True)
        self.levels.insert(0, (uniq, rv[first]))

    def _flush(self) -> None:
        if self.mem_n == 0:
            return
        # the sorted view IS the deduped (last-write-wins) buffer content
        uniq, fvals = self._view_merged()
        if self.compact_filter is not None and len(uniq):
            keep = self.compact_filter(uniq)
            uniq, fvals = uniq[keep], fvals[keep]
        self._push_run(uniq, fvals)
        self.mem_n = 0
        self._view_keys = np.empty(0, np.int64)
        self._view_vals = np.empty((0, self.value_words), np.int32)
        self._delta_keys = np.empty(0, np.int64)
        self._delta_vals = np.empty((0, self.value_words), np.int32)
        self.metrics.flushes += 1
        self.metrics.access_latency_total_ms += \
            (len(uniq) * self.latency.flush_ms
             + self.latency.flush_fixed_ms) * self._wscale

    def _push_run(self, keys: np.ndarray, vals: np.ndarray) -> None:
        self.levels.insert(0, (keys, vals))
        # size-tiered compaction: merge while a level outgrows fanout^i
        base = max(self.memtable_cap, 1)
        i = 0
        while i < len(self.levels) - 1:
            if len(self.levels[i][0]) >= base * (self.fanout ** i):
                self._merge_levels(i)
                self.metrics.compactions += 1
            else:
                i += 1

    def _merge_levels(self, i: int) -> None:
        k1, v1 = self.levels[i]          # newer
        k2, v2 = self.levels[i + 1]      # older
        keys = np.concatenate([k1, k2])
        vals = np.concatenate([v1, v2])
        uniq, idx = np.unique(keys, return_index=True)  # newer first => wins
        if self.compact_filter is not None and len(uniq):
            keep = self.compact_filter(uniq)
            uniq, idx = uniq[keep], idx[keep]
        self.levels[i + 1] = (uniq, vals[idx])
        del self.levels[i]
        self.metrics.access_latency_total_ms += \
            len(keys) * self.latency.compact_ms * self._wscale

    # -------------------------------------------------------------- read path
    def get_batch(self, keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Returns (values [n, V], found mask [n]) and updates θ/τ metrics."""
        n = len(keys)
        self.metrics.reads += n
        out = np.zeros((n, self.value_words), np.int32)
        found = np.zeros(n, bool)
        lat = 0.0

        # 1. memtable (newest data wins; the sorted newest-wins view is
        # maintained on the write path, so reads are searchsorted probes
        # of the delta buffer — newest — then the base view)
        if self.mem_n:
            mem_hits = 0
            dk = self._delta_keys
            todo_mem = None
            if len(dk):
                pos = np.searchsorted(dk, keys)
                pos_c = np.minimum(pos, len(dk) - 1)
                hit = (dk[pos_c] == keys) & (pos < len(dk))
                if hit.any():
                    out[hit] = self._delta_vals[pos_c[hit]]
                    found |= hit
                    mem_hits += int(hit.sum())
                todo_mem = ~hit
            vk = self._view_keys
            if len(vk):
                if todo_mem is None:
                    tk, sub = keys, None
                else:
                    sub = np.where(todo_mem)[0]
                    tk = keys[sub]
                pos = np.searchsorted(vk, tk)
                pos_c = np.minimum(pos, len(vk) - 1)
                hit = (vk[pos_c] == tk) & (pos < len(vk))
                if hit.any():
                    idx = np.where(hit)[0] if sub is None else sub[hit]
                    out[idx] = self._view_vals[pos_c[hit]]
                    found[idx] = True
                    mem_hits += int(hit.sum())
            self.metrics.memtable_hits += mem_hits
        lat += n * self.latency.memtable_ms

        # 2. block cache — probed once per *unique* key: within one
        # vectorized call a key fetched from the slow tier is admitted to
        # the cache, so later occurrences of it hit the admitted block
        # (exactly what happened across the chunks of one tick before the
        # engine coalesced them).  Duplicates of *absent* keys re-walk the
        # bloom filters each occurrence, as each chunk's probe did.
        todo = ~found
        if todo.any():
            sub = np.where(todo)[0]
            uk, inv = np.unique(keys[sub], return_inverse=True)
            sets = self._sets(uk)
            match = self.cache_keys[sets] == uk[:, None]        # [u, ways]
            hit = match.any(axis=1)
            way = match.argmax(axis=1)
            uvals = np.zeros((len(uk), self.value_words), np.int32)
            uvals[hit] = self.cache_vals[sets[hit], way[hit]]
            ufound = hit.copy()
            self.cache_ref[sets[hit], way[hit]] = 1
            self.metrics.cache_hits += int(hit.sum())
            self.metrics.cache_misses += int((~hit).sum())
            lat += len(uk) * self.latency.cache_ms

            # 3. levels (slow tier) for cache misses.  Bloom filters guard
            # each SSTable: absent keys cost a filter check (plus the
            # false-positive rate of real probes) instead of a full read.
            rem = np.where(~hit)[0]
            if len(rem):
                probe_keys = uk[rem]
                got = np.zeros(len(rem), bool)
                gvals = np.zeros((len(rem), self.value_words), np.int32)
                probes = 0.0
                blooms = 0
                for (lk, lv) in self.levels:
                    live = ~got
                    if not live.any():
                        break
                    pos = np.searchsorted(lk, probe_keys[live])
                    pos_c = np.clip(pos, 0, len(lk) - 1) if len(lk) else pos
                    h = (lk[pos_c] == probe_keys[live]) if len(lk) else \
                        np.zeros(int(live.sum()), bool)
                    n_live = int(live.sum())
                    n_hit = int(h.sum())
                    # present keys pass the bloom filter and read the block;
                    # absent keys mostly stop at the filter — but the filter/
                    # index blocks themselves need block-cache residency:
                    # with a small cache a share of filter checks also hits
                    # the slow tier (RocksDB filter-block eviction)
                    meta_ws = max(1.0, len(lk) / self.latency.meta_ratio)
                    meta_cover = min(1.0, self.cache_capacity / meta_ws)
                    probes += n_hit + self.latency.bloom_fp * (n_live - n_hit)
                    probes += (1.0 - meta_cover) \
                        * self.latency.meta_read_frac * n_live
                    blooms += n_live
                    li = np.where(live)[0]
                    gvals[li[h]] = lv[pos_c[h]]
                    got[li[h]] = True
                uvals[rem[got]] = gvals[got]
                ufound[rem[got]] = True
                self.metrics.level_probes += int(probes)
                lat += (probes * self.latency.level_ms
                        + blooms * self.latency.bloom_ms)
                # admit fetched entries into the cache
                if got.any():
                    self._cache_update(probe_keys[got], gvals[got])

            out[sub] = uvals[inv]
            found[sub] = ufound[inv]
            n_dup = len(sub) - len(uk)
            if n_dup:
                counts = np.bincount(inv)
                res_dups = int((counts[ufound] - 1).sum())
                unres_dups = n_dup - res_dups
                # resolved duplicates hit the (possibly just-admitted) block
                self.metrics.cache_hits += res_dups
                self.metrics.cache_misses += unres_dups
                lat += n_dup * self.latency.cache_ms
                if unres_dups:
                    probes = 0.0
                    for (lk, _) in self.levels:
                        meta_ws = max(1.0, len(lk) / self.latency.meta_ratio)
                        meta_cover = min(1.0, self.cache_capacity / meta_ws)
                        probes += (self.latency.bloom_fp + (1.0 - meta_cover)
                                   * self.latency.meta_read_frac) * unres_dups
                    self.metrics.level_probes += int(probes)
                    lat += (probes * self.latency.level_ms + unres_dups
                            * len(self.levels) * self.latency.bloom_ms)

        self.metrics.access_latency_total_ms += lat
        return out, found

    # ----------------------------------------------------------------- cache
    def _sets(self, keys: np.ndarray) -> np.ndarray:
        h = keys.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
        h ^= h >> np.uint64(29)
        return ((h >> np.uint64(1)).astype(np.int64) % self.cache_sets)

    def _cache_update(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Insert/overwrite entries (CLOCK eviction within each set)."""
        if len(keys) == 0:
            return
        # dedupe (last wins) to avoid write conflicts in the vectorized scatter
        self._cache_apply(*self._dedup_newest(keys, vals))

    def _cache_apply(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """``_cache_update`` for already-deduped sorted (keys, vals)."""
        if len(keys) == 0:
            return
        sets = self._sets(keys)
        if self._cache_virgin:           # first fill: no hits possible and
            self._cache_virgin = False   # every CLOCK scan lands instantly
            self._clock_fill_virgin(sets, keys, vals)
            return
        match = self.cache_keys[sets] == keys[:, None]
        hit = match.any(axis=1)
        way = match.argmax(axis=1)
        self.cache_vals[sets[hit], way[hit]] = vals[hit]
        self.cache_ref[sets[hit], way[hit]] = 1
        # misses: CLOCK — evict first way with ref=0, clearing refs as we
        # pass.  Vectorized across sets: misses are grouped by set (stable,
        # so ascending-key insertion order is preserved) and inserted in
        # rounds — round r does every set's r-th pending insert at once.
        # Bit-for-bit equivalent to the sequential per-entry CLOCK scan.
        if hit.all():
            return
        ms, mk, mv = sets[~hit], keys[~hit], vals[~hit]
        order = np.argsort(ms, kind="stable")
        ms, mk, mv = ms[order], mk[order], mv[order]
        rank = np.arange(len(ms)) - np.searchsorted(ms, ms, side="left")
        for r in range(int(rank.max()) + 1):
            sel = rank == r
            self._clock_insert(ms[sel], mk[sel], mv[sel])

    def _clock_fill_virgin(self, sets: np.ndarray, keys: np.ndarray,
                           vals: np.ndarray) -> None:
        """Closed-form CLOCK state after inserting into an all-empty cache.

        Starting from hand=0/ref=0, the r-th insert into a set provably goes
        to way ``r % W`` (a full pass clears every ref, so the wrapped scan
        again stops immediately), leaving hand = count % W and ref = 1
        exactly for the ways of the last incomplete pass (all ways when the
        count divides evenly).  Bit-identical to the sequential scan, with
        no per-round work.
        """
        W = self.cache_ways
        order = np.argsort(sets, kind="stable")   # key-ascending within set
        s, k, v = sets[order], keys[order], vals[order]
        n = len(s)
        change = np.flatnonzero(np.r_[True, s[1:] != s[:-1]])
        cnt = np.diff(np.r_[change, n])
        rank = np.arange(n) - np.repeat(change, cnt)
        way = (rank % W).astype(np.int32)
        # winners per (set, way) are exactly the last min(count, W) entries
        # of each set group (their ways are distinct by construction)
        mask = (np.repeat(change + cnt, cnt) - np.arange(n)) <= W
        self.cache_keys[s[mask], way[mask]] = k[mask]
        self.cache_vals[s[mask], way[mask]] = v[mask]
        us = s[change]
        m = (cnt % W).astype(np.int32)
        self.cache_hand[us] = m
        self.cache_ref[us] = ((np.arange(W)[None, :] < m[:, None])
                              | (m[:, None] == 0)).astype(np.int8)

    def _clock_insert(self, s: np.ndarray, k: np.ndarray, v: np.ndarray
                      ) -> None:
        """One CLOCK insertion per (distinct) set in ``s``, vectorized.

        Per set: scan ways from the hand, clearing ref bits as we pass,
        until a way with ref=0 is found (if all refs were set, the full
        pass clears them and the original hand position is the victim).
        """
        W = self.cache_ways
        rot = (self.cache_hand[s][:, None] + np.arange(W, dtype=np.int32)) % W
        refs = self.cache_ref[s[:, None], rot]                  # [m, W]
        zero = refs == 0
        has0 = zero.any(axis=1)
        j = np.where(has0, zero.argmax(axis=1), 0)
        # clear refs the hand passed over (all W ways when none were zero)
        clear = np.arange(W)[None, :] < j[:, None]
        clear[~has0] = True
        rows = np.broadcast_to(s[:, None], rot.shape)
        self.cache_ref[rows[clear], rot[clear]] = 0
        slot = rot[np.arange(len(s)), j]
        self.cache_keys[s, slot] = k
        self.cache_vals[s, slot] = v
        self.cache_ref[s, slot] = 1
        self.cache_hand[s] = (slot + 1) % W

    @property
    def cache_capacity(self) -> int:
        return self.cache_sets * self.cache_ways

    def prewarm_cache(self, keys: np.ndarray, vals: np.ndarray,
                      rng: np.random.Generator | None = None) -> None:
        """Fill the cache to capacity with a uniform sample of the live
        entries — steady-state emulation so short observation windows see
        the equilibrium hit rate rather than a cold-start transient."""
        if len(keys) == 0:
            return
        cap = self.cache_capacity
        if len(keys) > cap:
            rng = rng or np.random.default_rng(0)
            idx = rng.choice(len(keys), cap, replace=False)
            keys, vals = keys[idx], vals[idx]
        # store-derived keys are unique, so sorting alone reproduces
        # _cache_update's dedup ordering; fall back to the deduping path
        # if a caller hands us duplicates
        order = np.argsort(keys)
        sk = keys[order]
        if len(sk) > 1 and (sk[1:] == sk[:-1]).any():
            self._cache_update(keys, vals)
        else:
            self._cache_apply(sk, vals[order])
        self.metrics.reset()

    # ------------------------------------------------------------- snapshots
    def snapshot(self) -> dict:
        """Epoch-barrier snapshot (Flink-checkpoint analogue)."""
        keys, vals = self.items()
        return {"keys": keys, "vals": vals, "memory_mb": self.memory_mb,
                "value_words": self.value_words}

    @classmethod
    def restore(cls, snap: dict, *, memory_mb: float | None = None,
                **kw) -> "LegacyLSMStore":
        store = cls(memory_mb if memory_mb is not None else snap["memory_mb"],
                    value_words=snap["value_words"], **kw)
        if len(snap["keys"]):
            store._push_run(np.asarray(snap["keys"], np.int64),
                            np.asarray(snap["vals"], np.int32))
        return store
