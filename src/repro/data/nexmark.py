"""Nexmark benchmark (Tucker et al.) — generator + the six queries of the
paper's evaluation (q1, q2, q3, q5, q8, q11), built on the streaming engine.

Event kinds: 0 = Person, 1 = Auction, 2 = Bid (proportions 1:3:46, the
standard Nexmark mix).  Keyspaces are sized so the state profile of each
query matches §5: q3's incremental-join state stays small (~8 MB), q5's
window state ~10 MB, while q8/q11 have working sets far beyond one memory
level (the memory-pressured operators where hybrid scaling pays off).
"""
from __future__ import annotations

import numpy as np

from repro.streaming.events import EventBatch, PAYLOAD_WORDS
from repro.streaming.graph import Dataflow
from repro.streaming.operators import (FilterOp, JoinOp, MapOp,
                                       SessionWindowOp, SinkOp, SourceOp,
                                       WindowAggOp)

PERSON, AUCTION, BID = 0, 1, 2

N_USERS = 1_000_000
N_ACTIVE_USERS = 600_000  # concurrently-active bidders (q11 working set)
N_AUCTIONS = 10_000
N_SELLERS = 8_000         # q3 join keyspace (small state, ~8 MB — §5)
N_SELLERS_Q8 = 600_000    # q8 window-join keyspace (memory-pressured)


HOT_FRACTION = 0.8        # share of key draws hitting the hot set
HOT_SET = 6               # hot set = keyspace / HOT_SET


def _skewed(rng: np.random.Generator, n: int, keyspace: int) -> np.ndarray:
    """Hot-set skew (Nexmark's generator is skewed): 80% of draws hit the
    hottest keyspace/6 keys.  This gives the saturating θ(memory) curve the
    paper's q8/q11 traces show (large first-scale-up gain, small second)."""
    hot = rng.random(n) < HOT_FRACTION
    keys = np.empty(n, np.int64)
    keys[hot] = rng.integers(0, max(1, keyspace // HOT_SET), hot.sum())
    keys[~hot] = rng.integers(0, keyspace, (~hot).sum())
    return keys


class NexmarkGen:
    """Deterministic event generator with the standard 1:3:46 mix."""

    def __init__(self, seed: int = 7, mix=(1, 3, 46),
                 sellers: int = N_SELLERS, users: int = N_ACTIVE_USERS,
                 skew: bool = True):
        self.rng = np.random.default_rng(seed)
        w = np.array(mix, np.float64)
        self.mix = w / w.sum()
        self.sellers = sellers
        self.users = users
        self.skew = skew

    def _draw(self, n: int, keyspace: int) -> np.ndarray:
        if self.skew:
            return _skewed(self.rng, n, keyspace)
        return self.rng.integers(0, keyspace, n)

    def __call__(self, n: int, now_s: float) -> EventBatch:
        if n <= 0:
            return EventBatch.empty()
        kind = self.rng.choice(3, size=n, p=self.mix).astype(np.int8)
        key = np.empty(n, np.int64)
        p, a, b = kind == PERSON, kind == AUCTION, kind == BID
        key[p] = self._draw(int(p.sum()), self.sellers)
        key[a] = self._draw(int(a.sum()), self.sellers)       # seller id
        key[b] = self._draw(int(b.sum()), self.users)         # bidder id
        value = self.rng.integers(0, 10_000, (n, PAYLOAD_WORDS),
                                  dtype=np.int64).astype(np.int32)
        value[a, 2] = self.rng.integers(0, N_AUCTIONS, a.sum())
        value[b, 2] = self.rng.integers(0, N_AUCTIONS, b.sum())  # auction id
        ts = np.full(n, now_s, np.float64)
        return EventBatch(key, value, ts, kind)


class BidGen(NexmarkGen):
    """Bid-only stream (q1, q2, q5, q11)."""

    def __call__(self, n: int, now_s: float) -> EventBatch:
        b = super().__call__(n, now_s)
        b.kind[:] = BID
        b.key[:] = _skewed(self.rng, len(b), self.users)
        return b


# ---------------------------------------------------------------------------
# Queries
# ---------------------------------------------------------------------------

def _currency(batch: EventBatch) -> EventBatch:
    v = batch.value.copy()
    v[:, 0] = (v[:, 0].astype(np.int64) * 908 // 1000).astype(np.int32)
    return EventBatch(batch.key, v, batch.ts, batch.kind)


# q1/q2 run at 1/10th of the paper's 2.25M events/s with 10x the per-event
# CPU cost: identical busyness/parallelism dynamics (the engine really
# processes every event, and 2.25M ev/s exceeds this container's numpy
# throughput).  Final configurations are directly comparable to §5.
RATE_SCALE_STATELESS = 10


def q1() -> Dataflow:
    """Currency conversion: one stateless Map."""
    f = Dataflow("q1")
    f.chain(SourceOp("source", BidGen()),
            MapOp("currency_map", _currency,
                  cpu_cost_us=2.2 * RATE_SCALE_STATELESS),
            SinkOp("sink"))
    return f


def q2() -> Dataflow:
    """Bid filter on auction id."""
    f = Dataflow("q2")
    f.chain(SourceOp("source", BidGen()),
            FilterOp("bid_filter", lambda b: b.value[:, 2] % 123 == 0,
                     cpu_cost_us=2.0 * RATE_SCALE_STATELESS),
            SinkOp("sink"))
    return f


def q3() -> Dataflow:
    """Incremental (unbounded) join of persons and auctions + two filters.
    Join state converges to a small set (~N_SELLERS entries)."""
    f = Dataflow("q3")
    f.chain(SourceOp("source", NexmarkGen()),
            FilterOp("person_filter",
                     lambda b: (b.kind != PERSON) | (b.value[:, 1] % 4 == 0),
                     cpu_cost_us=2.0),
            FilterOp("auction_filter",
                     lambda b: (b.kind != AUCTION) | (b.value[:, 1] % 3 == 0),
                     cpu_cost_us=2.0))
    join = JoinOp("incr_join", PERSON, AUCTION, window_s=None)
    join.cpu_cost_us = 3.0
    f.add(join, after="auction_filter")
    f.add(SinkOp("sink"), after="incr_join")
    return f


def q5() -> Dataflow:
    """Hot auctions: sliding-window count per auction (small state)."""
    f = Dataflow("q5")
    src = SourceOp("source", BidGen())
    key_by_auction = MapOp(
        "key_by_auction",
        lambda b: EventBatch(b.value[:, 2].astype(np.int64), b.value,
                             b.ts, b.kind),
        cpu_cost_us=1.0)
    agg = WindowAggOp("hot_auctions", size_s=10.0, slide_s=5.0)
    f.chain(src, key_by_auction, agg, SinkOp("sink"))
    return f


def q8() -> Dataflow:
    """Monitor new users: tumbling-window join of persons and auctions.
    Window-scoped keys make the working set large (memory-pressured)."""
    f = Dataflow("q8")
    # unskewed: q8's window-scoped join state churns every window, so its
    # working set is the full seller space — the paper's memory-pressured case
    src = SourceOp("source", NexmarkGen(mix=(10, 36, 0),
                                        sellers=N_SELLERS_Q8, skew=False))
    join = JoinOp("window_join", PERSON, AUCTION, window_s=30.0,
                  keyspace=N_SELLERS_Q8)
    join.cpu_cost_us = 3.0
    f.chain(src, join, SinkOp("sink"))
    return f


def q11() -> Dataflow:
    """User sessions: bids per user while active — update-heavy with a
    ~N_USERS working set (the paper's flagship hybrid-scaling case)."""
    f = Dataflow("q11")
    f.chain(SourceOp("source", BidGen()),
            SessionWindowOp("user_sessions", gap_s=30.0,
                            keyspace=N_ACTIVE_USERS),
            SinkOp("sink"))
    return f


QUERIES = {"q1": q1, "q2": q2, "q3": q3, "q5": q5, "q8": q8, "q11": q11}

# Queries whose working set exceeds one memory level (§5) — the cases where
# Justin's scale-up beats DS2's scale-out; scenario tests and the benchmark
# CLI use this to pick the memory-pressured workloads.
MEMORY_PRESSURED = frozenset({"q8", "q11"})

# Per-query target rates (events/s).  q1/q2 follow the paper's 2.25M scaled
# by RATE_SCALE_STATELESS (see above); the stateful targets are chosen so the
# final DS2 parallelism lands in the paper's reported range on this engine.
TARGET_RATES = {"q1": 2_250_000 // RATE_SCALE_STATELESS,
                "q2": 2_250_000 // RATE_SCALE_STATELESS,
                "q3": 400_000, "q5": 120_000, "q8": 60_000, "q11": 60_000}
