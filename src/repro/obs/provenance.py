"""Decision provenance: structured *why* records for the control loop.

Every :class:`~repro.core.policy.Proposal` carries an :class:`Explain` —
the signal values that crossed (or didn't cross) the policy's thresholds,
per operator, with the action the policy took on that operator — and
every admission verdict carries an :func:`explain_admission` payload.
``tools/trace_report.py`` renders these as "why did window N do X".

This module is deliberately PURE: no clocks, no RNG, no engine state —
only arithmetic over the metrics dicts the policies themselves read.
``core/policy.py`` (a golden-trace-critical module) *assigns* the return
values of these builders, so unlike ``obs.trace`` they must stay
sink-free under reprolint's T501 pass (no discarded-call escape hatch).

The per-window *reason* enum also lives here: ``HistoryRow.reason``
records why a window ended the way it did, so ``AutoScaler.summary()``
and the SLO scorecards can group violation windows by cause.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.ds2 import true_rate_per_task
from repro.core.justin import JustinParams, JustinState, _improved

# ------------------------------------------------------------------ reasons
# why a window ended the way it did (HistoryRow.reason); the fleet
# drivers upgrade denied -> deferred and steady -> shrunk in their
# end-of-window back-fill (the R304-blessed mutation point)
REASON_STEADY = "steady"              # no trigger
REASON_TRIGGERED = "triggered"        # triggered, but proposal == current
REASON_RECONFIGURED = "reconfigured"  # proposal admitted and enacted
REASON_DENIED = "denied"              # admission rejected the scale-up
REASON_DEFERRED = "deferred"          # denied on migration budget: queued
REASON_SHRUNK = "shrunk"              # preempted: forced memory give-back
REASONS = (REASON_STEADY, REASON_TRIGGERED, REASON_RECONFIGURED,
           REASON_DENIED, REASON_DEFERRED, REASON_SHRUNK)


def reason_counts(history) -> dict[str, int]:
    """``{reason: windows}`` over a history, sorted by reason name."""
    counts: dict[str, int] = {}
    for row in history:
        r = getattr(row, "reason", REASON_STEADY)
        counts[r] = counts.get(r, 0) + 1
    return {k: counts[k] for k in sorted(counts)}


# ------------------------------------------------------------------ explain
@dataclass(frozen=True)
class Explain:
    """Why a policy proposed what it proposed, with exact signal values.

    ``operators`` maps op name -> {"action": str, "signals": {...}}: the
    per-operator observation the action was computed from, in the same
    units the policy read them (rates in events/s, tau in ms, theta in
    [0, 1]).  ``thresholds`` holds the policy parameters the signals
    were compared against.
    """
    policy: str
    target: float
    thresholds: dict = field(default_factory=dict)
    operators: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"policy": self.policy, "target": self.target,
                "thresholds": dict(self.thresholds),
                "operators": {op: {"action": rec["action"],
                                   "signals": dict(rec["signals"])}
                              for op, rec in self.operators.items()}}


def _signals(m: dict) -> dict:
    """The observation subset every policy family reads."""
    return {"parallelism": m["parallelism"],
            "memory_level": m["memory_level"],
            "busyness": m["busyness"],
            "rate_in": m["rate_in"], "rate_out": m["rate_out"],
            "selectivity": m["selectivity"],
            "backlog": m["backlog"], "blocked": m["blocked"],
            "theta": m.get("theta"), "tau_ms": m.get("tau_ms")}


def explain_ds2(metrics: dict[str, dict], ds2_p: dict[str, int],
                target: float, cfg) -> Explain:
    """CPU-only DS2: per-operator true processing rate vs propagated
    target input rate decides the new parallelism."""
    ops = {}
    for name, m in metrics.items():
        p_new = ds2_p.get(name, m["parallelism"])
        sig = _signals(m)
        sig["true_rate_per_task"] = true_rate_per_task(m)
        sig["ds2_parallelism"] = p_new
        if p_new > m["parallelism"]:
            action = "scale_out"
        elif p_new < m["parallelism"]:
            action = "scale_in"
        else:
            action = "hold"
        ops[name] = {"action": action, "signals": sig}
    return Explain("ds2", target,
                   {"target_busyness": cfg.target_busyness,
                    "max_parallelism": cfg.max_parallelism}, ops)


def explain_justin(metrics: dict[str, dict], ds2_p: dict[str, int],
                   decisions: dict, state: JustinState, target: float,
                   params: JustinParams) -> Explain:
    """Algorithm 1 provenance: for each stateful operator, the theta/tau
    observations vs the pressure thresholds, the previous window's
    scale-up verdict, and which Algorithm-1 branch fired."""
    ops = {}
    for name, m in metrics.items():
        d = decisions[name]
        prev = state.prev_config.get(name)
        prev_m = state.prev_metrics.get(name, m)
        sig = _signals(m)
        sig["ds2_parallelism"] = ds2_p.get(name, m["parallelism"])
        if not m["stateful"]:
            action = "rescale" if d.parallelism != m["parallelism"] \
                else "hold"
            ops[name] = {"action": action, "signals": sig}
            continue
        prev_p = prev.parallelism if prev is not None else m["parallelism"]
        prev_lvl = (prev.memory_level if prev is not None
                    and prev.memory_level is not None
                    else (m["memory_level"] or 0))
        prev_up = prev.scaled_up if prev is not None else False
        sig["theta_prev"] = prev_m.get("theta")
        sig["tau_prev_ms"] = prev_m.get("tau_ms")
        sig["prev_scaled_up"] = prev_up
        sig["memory_level_prev"] = prev_lvl
        theta, tau = m.get("theta"), m.get("tau_ms")
        if sig["ds2_parallelism"] == prev_p:
            action = "hold"                          # line 6: sufficient
        elif prev_up:                                # line 7
            improved = _improved(theta, tau, sig["theta_prev"],
                                 sig["tau_prev_ms"], params.hysteresis)
            sig["improved"] = improved
            if d.scaled_up:
                action = "memory_scale_up_again"     # lines 8-12
            elif improved:
                action = "rescale"                   # improved, no headroom
            else:
                action = "rollback_memory"           # lines 13-14
        else:                                        # line 16
            pressure = ((theta is not None and theta < params.delta_theta)
                        or (tau is not None and tau > params.delta_tau_ms))
            sig["memory_pressure"] = pressure
            if d.scaled_up:
                action = "cancel_rescale_memory_up"  # lines 17-19
            else:
                action = "rescale" if not pressure else "rescale_at_max_level"
        ops[name] = {"action": action, "signals": sig}
    return Explain("justin", target,
                   {"delta_theta": params.delta_theta,
                    "delta_tau_ms": params.delta_tau_ms,
                    "max_level": params.max_level,
                    "hysteresis": params.hysteresis}, ops)


def explain_static(metrics: dict[str, dict], target: float) -> Explain:
    """Fixed allocation: every operator holds by construction."""
    return Explain("static", target, {},
                   {name: {"action": "hold", "signals": _signals(m)}
                    for name, m in metrics.items()})


def explain_threshold(flow, metrics: dict[str, dict], target: float,
                      cfg, scale_factor: float) -> Explain:
    """Dhalion-style symptom detection: which operators were hotter than
    ``busy_high`` (or, absent any, which was blamed as busiest)."""
    sources, sinks = set(flow.sources()), set(flow.sinks())
    scalable = [n for n in metrics
                if n not in sources and n not in sinks]
    hot = [n for n in scalable
           if metrics[n]["busyness"] > cfg.busy_high]
    blamed = []
    if not hot and scalable:
        blamed = [max(scalable, key=lambda n: metrics[n]["busyness"])]
    ops = {}
    for name, m in metrics.items():
        sig = _signals(m)
        sig["hot"] = name in hot
        if name in hot:
            action = "scale_out"
        elif name in blamed:
            action = "scale_out_blamed_busiest"
        else:
            action = "hold"
        ops[name] = {"action": action, "signals": sig}
    return Explain("threshold", target,
                   {"busy_high": cfg.busy_high,
                    "scale_factor": scale_factor,
                    "max_parallelism": cfg.max_parallelism}, ops)


def explain_admission(*, cpu_cur, mem_cur, cpu_new, mem_new, grows,
                      admitted, shared: bool) -> dict:
    """The admission verdict's provenance payload: what was quoted, did
    it grow the footprint, and did the arbiter let it through (None =
    no gate consulted: not growing, or no admission hook)."""
    return {"cpu_cur": cpu_cur, "mem_cur": mem_cur,
            "cpu_new": cpu_new, "mem_new": mem_new,
            "grows": grows, "admitted": admitted, "shared": shared}
