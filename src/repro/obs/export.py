"""Trace exporters: JSONL (the repo schema) and Chrome ``trace_event``.

JSONL layout (validated by ``tools/check_trace.py``): a header line
``{"kind": "repro-trace", "version": 1, ...meta}`` followed by one span
dict per line (see :meth:`repro.obs.trace.Span.to_dict`).

The Chrome exporter writes the ``trace_event`` JSON object format —
complete ("X") events with microsecond timestamps, one tid per tenant
with ``thread_name`` metadata — so an episode or a fleet window opens
directly in Perfetto / chrome://tracing as a timeline
(docs/observability.md walks through it).
"""
from __future__ import annotations

import json

TRACE_KIND = "repro-trace"
TRACE_VERSION = 1


def write_jsonl(spans, path: str, meta: dict | None = None) -> None:
    """Header line + one span per line."""
    header = {"kind": TRACE_KIND, "version": TRACE_VERSION}
    if meta:
        header.update(meta)
    with open(path, "w") as f:
        f.write(json.dumps(header) + "\n")
        for s in spans:
            f.write(json.dumps(s.to_dict()) + "\n")


def read_jsonl(path: str) -> tuple[dict, list[dict]]:
    """(header, span dicts) — raises ValueError on a non-trace file."""
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    if not lines:
        raise ValueError(f"{path}: empty trace")
    header = json.loads(lines[0])
    if header.get("kind") != TRACE_KIND:
        raise ValueError(f"{path}: not a {TRACE_KIND} file")
    return header, [json.loads(ln) for ln in lines[1:]]


def chrome_trace(spans, meta: dict | None = None) -> dict:
    """Spans -> Chrome ``trace_event`` object format.  Sim seconds map to
    trace microseconds; zero-length phase marks get a 1 us floor so they
    stay visible in Perfetto."""
    tenants = sorted({s.tenant for s in spans})
    tid = {t: i + 1 for i, t in enumerate(tenants)}
    events = [{"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
               "args": {"name": "repro control loop"}}]
    for t in tenants:
        events.append({"ph": "M", "pid": 1, "tid": tid[t],
                       "name": "thread_name",
                       "args": {"name": t or "episode"}})
    for s in spans:
        args = dict(s.args)
        if s.window is not None:
            args["window"] = s.window
        events.append({
            "ph": "X", "pid": 1, "tid": tid[s.tenant],
            "name": s.name, "cat": s.cat,
            "ts": s.t0 * 1e6,
            "dur": max((s.t1 - s.t0) * 1e6, 1.0),
            "args": args,
        })
    out = {"traceEvents": events, "displayTimeUnit": "ms",
           "otherData": {"kind": TRACE_KIND, "version": TRACE_VERSION}}
    if meta:
        out["otherData"].update(meta)
    return out


def write_chrome(spans, path: str, meta: dict | None = None) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(spans, meta), f, indent=1)
