"""Observability: deterministic tracing, decision provenance, metrics.

Three pieces (PR 10):

* :mod:`repro.obs.trace` — sim-time span tracer; ``NULL_TRACER`` is the
  O(1) disabled default every control-loop hook falls back to.
* :mod:`repro.obs.provenance` — ``Explain`` records (why a policy
  proposed what it proposed) and the ``HistoryRow.reason`` enum.
* :mod:`repro.obs.registry` — unified counters/gauges/histograms/timers
  behind one ``snapshot()``.

Exporters (JSONL + Chrome ``trace_event`` for Perfetto) live in
:mod:`repro.obs.export`.  Determinism contract: docs/observability.md.
"""
from repro.obs.export import (chrome_trace, read_jsonl, write_chrome,
                              write_jsonl)
from repro.obs.provenance import (REASONS, Explain, explain_admission,
                                  reason_counts)
from repro.obs.registry import NULL_REGISTRY, MetricsRegistry
from repro.obs.trace import CATS, NULL_TRACER, Span, Tracer

__all__ = [
    "CATS", "Explain", "MetricsRegistry", "NULL_REGISTRY", "NULL_TRACER",
    "REASONS", "Span", "Tracer", "chrome_trace", "explain_admission",
    "read_jsonl", "reason_counts", "write_chrome", "write_jsonl",
]
