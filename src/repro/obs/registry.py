"""Unified metrics registry: counters / gauges / histograms / timers.

One snapshot API over every number the repo used to scatter: the
per-store ``LSMMetrics`` totals, the engine's per-task cost EWMAs, and
the fleet drivers' audit totals (``absorb_engine`` / ``absorb_fleet``
pull them in).  Benchmarks time through :meth:`MetricsRegistry.timer`
instead of ad-hoc ``time.time()`` reads, so BENCH_*.json and traces
report from one clock path.

Disabled path is O(1): a disabled registry hands out one shared no-op
instrument, so instrumented code needs no ``if enabled`` guards.  Like
``obs.trace``, the wall clock lives only here (timers) — golden modules
never construct or read a registry (reprolint T501/R305 enforce it).
"""
from __future__ import annotations

import time


class Counter:
    """Monotone count."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins level."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Streaming count/total/min/max — enough for rates and spreads
    without holding samples."""
    __slots__ = ("count", "total", "vmin", "vmax")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = None
        self.vmax = None

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if self.vmin is None or v < self.vmin:
            self.vmin = v
        if self.vmax is None or v > self.vmax:
            self.vmax = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class Timer(Histogram):
    """A histogram of wall-clock laps, usable as a context manager:

        with reg.timer("episode") as t:
            run()
        print(t.s)          # last lap, seconds

    ``total`` accumulates across laps — the registry's one clock path.
    """
    __slots__ = ("_t0", "last_s")

    def __init__(self):
        super().__init__()
        self._t0 = None
        self.last_s = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.last_s = time.perf_counter() - self._t0
        self._t0 = None
        self.observe(self.last_s)
        return False

    @property
    def s(self) -> float:
        return self.last_s

    @property
    def us(self) -> float:
        return self.last_s * 1e6


class _Noop:
    """Shared do-nothing instrument a disabled registry hands out."""
    __slots__ = ()
    value = 0
    count = 0
    total = 0.0
    mean = 0.0
    s = 0.0
    us = 0.0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _Noop()


class MetricsRegistry:
    """Name -> instrument, with one ``snapshot()`` over everything."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._timers: dict[str, Timer] = {}

    def _get(self, table, name, ctor):
        if not self.enabled:
            return _NOOP
        inst = table.get(name)
        if inst is None:
            inst = table[name] = ctor()
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._histograms, name, Histogram)

    def timer(self, name: str) -> Timer:
        return self._get(self._timers, name, Timer)

    # -------------------------------------------------------------- absorb
    def absorb_engine(self, engine, prefix: str = "engine") -> None:
        """Pull the engine's observable totals behind the snapshot API:
        per-operator LSM store counters (reads/writes/hits/probes/
        flushes/compactions) summed over tasks, and the per-task cost
        EWMAs the charge model calibrates."""
        for name in sorted(engine.tasks):
            reads = writes = hits = misses = probes = 0
            flushes = compactions = 0
            stateful = False
            for i, tr in enumerate(engine.tasks[name]):
                if tr.cost_per_event is not None:
                    self.gauge(f"{prefix}.task.{name}.{i}.cost_per_event"
                               ).set(tr.cost_per_event)
                if tr.state is None:
                    continue
                stateful = True
                m = tr.state.metrics
                reads += m.reads
                writes += m.writes
                hits += m.cache_hits + m.memtable_hits
                misses += m.cache_misses
                probes += m.level_probes
                f, c = m.maintenance()
                flushes += f
                compactions += c
            if not stateful:
                continue
            g = f"{prefix}.lsm.{name}"
            self.gauge(f"{g}.reads").set(reads)
            self.gauge(f"{g}.writes").set(writes)
            self.gauge(f"{g}.hits").set(hits)
            self.gauge(f"{g}.misses").set(misses)
            self.gauge(f"{g}.level_probes").set(probes)
            self.gauge(f"{g}.flushes").set(flushes)
            self.gauge(f"{g}.compactions").set(compactions)

    def absorb_fleet(self, result, prefix: str = "fleet") -> None:
        """Fleet-driver audit totals (denials / deferrals / preemptions /
        policy steps / downtime) from a ``run_colocated`` result."""
        denied = deferred = preempted = steps = 0
        downtime = moved = 0.0
        for t in result.tenants:
            denied += len(t.denials)
            deferred += len(t.deferrals)
            preempted += len(t.preemptions)
            steps += t.scaler.steps
            for h in t.scaler.history:
                downtime += h.reconfig_downtime
                moved += h.moved_mb
        self.counter(f"{prefix}.tenants").inc(len(result.tenants))
        self.counter(f"{prefix}.denied_windows").inc(denied)
        self.counter(f"{prefix}.deferred_windows").inc(deferred)
        self.counter(f"{prefix}.preempted_windows").inc(preempted)
        self.counter(f"{prefix}.policy_steps").inc(steps)
        self.gauge(f"{prefix}.reconfig_downtime_s").set(downtime)
        self.gauge(f"{prefix}.moved_mb").set(moved)

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> dict:
        """Everything, JSON-ready, sorted by name."""
        return {
            "counters": {k: self._counters[k].value
                         for k in sorted(self._counters)},
            "gauges": {k: self._gauges[k].value
                       for k in sorted(self._gauges)},
            "histograms": {k: {"count": h.count, "total": h.total,
                               "min": h.vmin, "max": h.vmax,
                               "mean": h.mean}
                           for k, h in sorted(self._histograms.items())},
            "timers": {k: {"count": t.count, "total_s": t.total,
                           "min_s": t.vmin, "max_s": t.vmax,
                           "mean_s": t.mean}
                       for k, t in sorted(self._timers.items())},
        }


NULL_REGISTRY = MetricsRegistry(enabled=False)
