"""Deterministic span tracer for the control loop.

Every window phase — engine step, ``should_trigger``/``propose``/
``commit``, admission quote + arbitration, migration plan/charge/paused
catch-up, LSM flush/compact/probe totals — can record a :class:`Span`.
Spans are stamped with SIM time (``engine.now``) plus a monotone sequence
counter, never the wall clock, so a traced episode is a pure function of
(seed, inputs) and the four golden traces stay byte-identical with
tracing on or off (pinned by ``tests/test_obs.py``).

Determinism contract:

* ``record`` on a disabled tracer is a single attribute check (O(1));
  ``NULL_TRACER`` is the shared disabled instance the controller falls
  back to.
* ``record`` never reads engine RNG or mutates anything a decision
  reads; span ``args`` are copied into fresh dicts at record time.
* The one wall-clock read lives behind ``self_profile=True`` and flows
  ONLY into ``overhead_s`` (how much wall time tracing itself cost).
  reprolint's T501 obs scope proves statically that no value returned by
  this module reaches a golden-module decision: golden modules may call
  ``record`` only as a discarded expression statement
  (docs/static-analysis.md).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

# span categories — the schema contract tools/check_trace.py validates
# (duplicated there so the checker stays stdlib-only, check_bench style)
CATS = ("window", "engine", "policy", "admission", "migration", "lsm",
        "preempt")


@dataclass
class Span:
    """One traced phase: a ``[t0, t1]`` sim-time interval with a payload."""
    seq: int                    # monotone per-tracer record index
    name: str                   # phase, e.g. "policy.propose"
    cat: str                    # one of CATS
    t0: float                   # sim seconds (engine.now at phase start)
    t1: float                   # sim seconds (>= t0)
    tenant: str = ""            # "" for single-tenant episodes
    window: int | None = None   # decision-window index when known
    args: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"seq": self.seq, "name": self.name, "cat": self.cat,
                "t0": self.t0, "t1": self.t1, "tenant": self.tenant,
                "window": self.window, "args": self.args}


class Tracer:
    """Collects :class:`Span` records; disabled path is O(1).

    ``self_profile=True`` additionally measures the wall-clock overhead
    of tracing itself into ``overhead_s`` — the only ``time`` read in
    this module, and it never leaves the tracer.
    """

    def __init__(self, enabled: bool = True, self_profile: bool = False):
        self.enabled = enabled
        self.self_profile = self_profile
        self.spans: list[Span] = []
        self.overhead_s = 0.0
        self._seq = 0

    def record(self, name: str, cat: str, t0: float, t1: float,
             tenant: str = "", window: int | None = None,
             args: dict | None = None) -> None:
        """Record one span.  Golden modules call this as a bare statement
        only — the return value is always None and reprolint enforces the
        discarded-call discipline (T501 obs scope)."""
        if not self.enabled:
            return
        wall = time.perf_counter() if self.self_profile else None
        self.spans.append(Span(self._seq, name, cat, float(t0), float(t1),
                               tenant, window,
                               dict(args) if args else {}))
        self._seq += 1
        if wall is not None:
            self.overhead_s += time.perf_counter() - wall

    def clear(self) -> None:
        self.spans = []
        self._seq = 0
        self.overhead_s = 0.0

    def summary(self) -> dict[str, dict]:
        """Per-(tenant, cat, name) aggregate: span count and total sim
        duration.  The scalar/vectorized fleet drivers must produce
        identical summaries (tests/test_fleet.py)."""
        agg: dict[str, dict] = {}
        for s in self.spans:
            key = f"{s.tenant}|{s.cat}|{s.name}"
            a = agg.get(key)
            if a is None:
                agg[key] = {"count": 1, "sim_s": s.t1 - s.t0}
            else:
                a["count"] += 1
                a["sim_s"] += s.t1 - s.t0
        return agg


# shared disabled instance: `tracer or NULL_TRACER` keeps every call site
# unconditional while the disabled record stays a single attribute check
NULL_TRACER = Tracer(enabled=False)
