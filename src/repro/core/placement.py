"""Task-to-TaskManager placement: multidimensional bin packing (§4.3).

Each task needs (1 slot, m MB managed memory); each TM offers ``slots`` slots
and a managed-memory pool.  First-fit-decreasing on memory, spawning a new TM
whenever the packing fails — exactly the Kubernetes-Operator behaviour the
paper describes.  The resource accounting (CPU cores = used slots; memory =
TM base + managed) feeds the §5 comparison plots.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class TMSpec:
    slots: int = 4
    managed_pool_mb: float = 4 * 158.0        # default: 158 MB per slot (§5)
    base_mb: float = 2048.0 - 4 * 158.0       # heap/network/framework share


@dataclass
class TaskRequest:
    op: str
    index: int
    memory_mb: float


@dataclass
class TaskManager:
    spec: TMSpec
    tasks: list[TaskRequest] = field(default_factory=list)

    @property
    def used_slots(self) -> int:
        return len(self.tasks)

    @property
    def used_mem(self) -> float:
        return sum(t.memory_mb for t in self.tasks)

    def fits(self, req: TaskRequest) -> bool:
        return (self.used_slots < self.spec.slots
                and self.used_mem + req.memory_mb <= self.spec.managed_pool_mb)


@dataclass
class Placement:
    tms: list[TaskManager]

    @property
    def n_tms(self) -> int:
        return len(self.tms)

    @property
    def cpu_cores(self) -> int:
        return sum(tm.used_slots for tm in self.tms)

    @property
    def memory_mb(self) -> float:
        """Overall consumption (paper §5: heap + network + managed).  A
        spawned TM's heap/network share is reserved at pod creation and
        counts fully; managed memory counts per actual grant — which for DS2
        is the uniform per-slot reservation (Takeaway 1) and for Justin the
        heterogeneous task grants."""
        return sum(tm.spec.base_mb + tm.used_mem for tm in self.tms)


def bin_pack(requests: list[TaskRequest], spec: TMSpec = TMSpec(),
             existing: list[TaskManager] | None = None) -> Placement:
    """First-fit-decreasing on memory; spawn TMs on demand."""
    tms = existing or []
    for req in sorted(requests, key=lambda r: -r.memory_mb):
        for tm in tms:
            if tm.fits(req):
                tm.tasks.append(req)
                break
        else:
            tm = TaskManager(spec)
            if not tm.fits(req):
                raise ValueError(
                    f"task {req.op}[{req.index}] needs {req.memory_mb} MB "
                    f"> TM pool {spec.managed_pool_mb} MB")
            tm.tasks.append(req)
            tms.append(tm)
    return Placement(tms)


def placement_for_config(config: dict[str, tuple[int, int | None]],
                         *, base_mem_mb: float = 158.0,
                         exclude: set[str] | None = None,
                         spec: TMSpec | None = None) -> Placement:
    """Build the task list from a configuration C^t and pack it."""
    from repro.streaming.engine import level_mb
    exclude = exclude or set()
    spec = spec or TMSpec(managed_pool_mb=4 * base_mem_mb * 4,
                          base_mb=2048.0 - 4 * base_mem_mb)
    reqs = []
    for op, (p, lvl) in config.items():
        if op in exclude:
            continue
        for i in range(p):
            reqs.append(TaskRequest(op, i, level_mb(lvl, base_mem_mb)))
    return bin_pack(reqs, spec)
