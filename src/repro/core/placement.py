"""Task-to-TaskManager placement: multidimensional bin packing (§4.3).

Each task needs (1 slot, m MB managed memory); each TM offers ``slots`` slots
and a managed-memory pool.  First-fit-decreasing on memory, spawning a new TM
whenever the packing fails — exactly the Kubernetes-Operator behaviour the
paper describes.  The resource accounting (CPU cores = used slots; memory =
TM base + managed) feeds the §5 comparison plots.

Two packing regimes:

* :func:`placement_for_config` / :func:`bin_pack` — one tenant, a private
  TM fleet.  Every tenant pays the full ``base_mb`` of every TM it spawns.
* :func:`shared_pack` / :func:`repack` — a *cluster-level* packer: multiple
  tenants' tenant-tagged :class:`TaskRequest` lists packed into ONE TM
  fleet (:class:`SharedPlacement`).  Slots and managed MB are attributed
  to the tenant that uses them; each TM's ``base_mb`` (heap/network/
  framework share) is amortized across its co-resident tenants in
  proportion to the slots they occupy — which is exactly the §4.3
  resource-efficiency headline private fleets hide: N co-located tenants
  pay ~1 fleet's base memory, not N.  ``repack`` additionally accounts the
  migration cost of re-shaping a running placement (tasks moved × state
  MB), the §4.3 reconfiguration-cost axis.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.units import mem_fits


@dataclass(frozen=True)
class TMSpec:
    slots: int = 4
    managed_pool_mb: float = 4 * 158.0        # default: 158 MB per slot (§5)
    base_mb: float = 2048.0 - 4 * 158.0       # heap/network/framework share


def default_tm_spec(base_mem_mb: float = 158.0) -> TMSpec:
    """The TM shape ``placement_for_config`` quotes against (pool sized for
    one scale-up headroom per slot) — shared so the cluster-level packer
    prices TMs identically to the per-tenant quotes."""
    return TMSpec(managed_pool_mb=4 * base_mem_mb * 4,
                  base_mb=2048.0 - 4 * base_mem_mb)


@dataclass
class TaskRequest:
    op: str
    index: int
    memory_mb: float
    tenant: str = ""                          # cluster-level packing tag

    @property
    def key(self) -> tuple[str, str, int]:
        """Stable task identity across repacks."""
        return (self.tenant, self.op, self.index)


@dataclass
class TaskManager:
    spec: TMSpec
    tasks: list[TaskRequest] = field(default_factory=list)

    @property
    def used_slots(self) -> int:
        return len(self.tasks)

    @property
    def used_mem(self) -> float:
        return sum(t.memory_mb for t in self.tasks)

    def fits(self, req: TaskRequest) -> bool:
        # used_mem is a float sum: an epsilon-free <= here denies a task
        # that exactly fills the pool whenever the accumulated grants
        # drift a few ULPs high (the Cluster.fits phantom-denial class)
        return (self.used_slots < self.spec.slots
                and mem_fits(self.used_mem + req.memory_mb,
                             self.spec.managed_pool_mb))

    def tenant_slots(self, tenant: str) -> int:
        return sum(1 for t in self.tasks if t.tenant == tenant)

    def tenant_mem(self, tenant: str) -> float:
        return sum(t.memory_mb for t in self.tasks if t.tenant == tenant)


@dataclass
class Placement:
    tms: list[TaskManager]

    @property
    def n_tms(self) -> int:
        return len(self.tms)

    @property
    def cpu_cores(self) -> int:
        return sum(tm.used_slots for tm in self.tms)

    @property
    def memory_mb(self) -> float:
        """Overall consumption (paper §5: heap + network + managed).  A
        spawned TM's heap/network share is reserved at pod creation and
        counts fully; managed memory counts per actual grant — which for DS2
        is the uniform per-slot reservation (Takeaway 1) and for Justin the
        heterogeneous task grants."""
        return sum(tm.spec.base_mb + tm.used_mem for tm in self.tms)


@dataclass
class SharedPlacement(Placement):
    """One TM fleet holding several tenants' tasks, with per-tenant
    attribution: a tenant is charged its own slots and managed grants plus
    a slot-proportional share of each TM's ``base_mb`` it co-resides on.
    Attributions sum exactly to the fleet totals."""

    @property
    def tenants(self) -> list[str]:
        seen: dict[str, None] = {}
        for tm in self.tms:
            for t in tm.tasks:
                seen.setdefault(t.tenant)
        return list(seen)

    def tenant_cpu(self, tenant: str) -> int:
        return sum(tm.tenant_slots(tenant) for tm in self.tms)

    def tenant_memory_mb(self, tenant: str) -> float:
        out = 0.0
        for tm in self.tms:
            slots = tm.tenant_slots(tenant)
            if slots == 0:
                continue
            out += tm.tenant_mem(tenant) \
                + tm.spec.base_mb * slots / tm.used_slots
        return out

    def attribution(self) -> dict[str, tuple[int, float]]:
        """{tenant: (cpu slots, amortized memory MB)} for every tenant."""
        return {t: (self.tenant_cpu(t), self.tenant_memory_mb(t))
                for t in self.tenants}

    def assignment(self) -> dict[tuple[str, str, int], int]:
        """Task identity -> TM index (the repack/migration diff basis)."""
        return {t.key: i for i, tm in enumerate(self.tms) for t in tm.tasks}


@dataclass(frozen=True)
class MigrationCost:
    """Cost of re-shaping a running placement: every task whose TM changed
    drags its managed state with it (§4.3: reconfigurations move state)."""
    tasks_moved: int = 0
    state_mb: float = 0.0

    def __add__(self, other: "MigrationCost") -> "MigrationCost":
        return MigrationCost(self.tasks_moved + other.tasks_moved,
                             self.state_mb + other.state_mb)


def bin_pack(requests: list[TaskRequest], spec: TMSpec = TMSpec(),
             existing: list[TaskManager] | None = None) -> Placement:
    """First-fit-decreasing on memory; spawn TMs on demand."""
    tms = existing or []
    for req in sorted(requests, key=lambda r: -r.memory_mb):
        for tm in tms:
            if tm.fits(req):
                tm.tasks.append(req)
                break
        else:
            tm = TaskManager(spec)
            if not tm.fits(req):
                raise ValueError(
                    f"task {req.op}[{req.index}] needs {req.memory_mb} MB "
                    f"> TM pool {spec.managed_pool_mb} MB")
            tm.tasks.append(req)
            tms.append(tm)
    return Placement(tms)


def shared_pack(requests_by_tenant: dict[str, list[TaskRequest]],
                spec: TMSpec = TMSpec()) -> SharedPlacement:
    """Pack every tenant's tasks into ONE fleet.  Requests are re-tagged
    with their tenant key; the FFD sort is stable, so equal-memory tasks
    keep tenant-insertion order and the packing is deterministic."""
    reqs: list[TaskRequest] = []
    for tenant, rs in requests_by_tenant.items():
        for r in rs:
            reqs.append(TaskRequest(r.op, r.index, r.memory_mb, tenant))
    return SharedPlacement(bin_pack(reqs, spec).tms)


def repack(requests_by_tenant: dict[str, list[TaskRequest]],
           spec: TMSpec = TMSpec(),
           previous: SharedPlacement | None = None
           ) -> tuple[SharedPlacement, MigrationCost]:
    """Re-pack the whole fleet from scratch and price the re-shape against
    ``previous``: tasks present in both placements whose TM changed are
    migrations (count × their state MB).  Newly spawned tasks receive
    re-partitioned state through the engine's reconfigure path and are not
    double-charged here."""
    new = shared_pack(requests_by_tenant, spec)
    if previous is None:
        return new, MigrationCost()
    old_at = previous.assignment()
    moved, mb = 0, 0.0
    for key, tm_idx in new.assignment().items():
        was = old_at.get(key)
        if was is not None and was != tm_idx:
            moved += 1
            mb += next(t.memory_mb for t in new.tms[tm_idx].tasks
                       if t.key == key)
    return new, MigrationCost(moved, mb)


def placement_requests(config: dict[str, tuple[int, int | None]],
                       *, base_mem_mb: float = 158.0,
                       exclude: set[str] | None = None,
                       tenant: str = "") -> list[TaskRequest]:
    """The task list a configuration C^t asks the packer for."""
    from repro.streaming.engine import level_mb
    exclude = exclude or set()
    reqs = []
    for op, (p, lvl) in config.items():
        if op in exclude:
            continue
        for i in range(p):
            reqs.append(TaskRequest(op, i, level_mb(lvl, base_mem_mb),
                                    tenant))
    return reqs


def placement_for_config(config: dict[str, tuple[int, int | None]],
                         *, base_mem_mb: float = 158.0,
                         exclude: set[str] | None = None,
                         spec: TMSpec | None = None) -> Placement:
    """Build the task list from a configuration C^t and pack it."""
    spec = spec or default_tm_spec(base_mem_mb)
    return bin_pack(placement_requests(config, base_mem_mb=base_mem_mb,
                                       exclude=exclude), spec)
