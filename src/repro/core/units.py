"""Blessed resource-accounting comparisons: epsilon-disciplined MB math.

Memory footprints in this codebase are *accumulated* floats — amortized
shared-TM attributions, repack sums, per-window budget remainders — and
two mathematically equal footprints routinely differ by a few ULPs
depending on summation order.  PR 6's ``Cluster.fits`` bug (0.1 × 3 >
0.3 phantom-denying an identical re-reservation) is the canonical
failure.  Every budget comparison on MB quantities therefore goes
through ONE tolerance, defined here, so admission checks, invariant
asserts and packers can never disagree with each other:

* :func:`mem_fits` — "does ``used`` fit in ``budget``?" (``<=`` + eps);
* :func:`mem_exceeds` — "is ``a`` strictly more than ``b``?" (``>`` + eps,
  the admission-gating growth test);
* :func:`mem_close` — drift-tolerant equality (audit reconciliation).

``reprolint`` (tools/lint, rule F201) flags bare ``==``/``<=``/``<``
comparisons between MB-named quantities in accounting code; routing them
through these helpers (or an explicit ``_EPS`` term) is the blessed form.
"""
from __future__ import annotations

# One tolerance for every budget comparison.  1e-9 MB is ~1 byte — far
# below any real grant and far above accumulated float drift at fleet
# scale (thousands of ~1e3-MB terms drift by <1e-9 relative).
MB_EPS = 1e-9


def mem_fits(used_mb: float, budget_mb: float, *,
             eps: float = MB_EPS) -> bool:
    """Does a summed footprint fit a budget, tolerating summation drift?"""
    return used_mb <= budget_mb + eps


def mem_exceeds(a_mb: float, b_mb: float, *, eps: float = MB_EPS) -> bool:
    """Is ``a_mb`` strictly larger than ``b_mb`` beyond float drift?  The
    admission-gating test: a footprint only *grows* when it grows by more
    than an epsilon, so a drifted re-quote of an identical placement is
    never treated as a scale-up."""
    return a_mb > b_mb + eps


def mem_close(a_mb: float, b_mb: float, *, eps: float = MB_EPS) -> bool:
    """Drift-tolerant equality between two MB quantities (audit
    reconciliation between incremental counters and full sums)."""
    return abs(a_mb - b_mb) <= eps
