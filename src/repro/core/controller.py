"""Reconfiguration controller: the auto-scaler loop (§5 protocol).

Runs the engine in decision windows; on a trigger computes DS2 (and, in
"justin" mode, Algorithm 1 over it), enacts the new configuration via the
engine (state re-partition / backend resize) and the bin-packing placement,
then waits a stabilization period.  History rows capture what Fig. 5 plots:
achieved rate, CPU cores, memory MB, per step — plus the per-window backlog
and admission outcomes the SLO/cluster layers consume.

Co-location support: an ``AutoScaler`` may be constructed with an
``admission`` hook, consulted whenever a proposed reconfiguration would
*grow* the episode's resource footprint (more CPU slots or more memory than
the current placement).  A denied request leaves the configuration — and, in
"justin" mode, the Algorithm-1 decision history — untouched, so the trigger
persists and the same request is re-made at the next window boundary.
Scale-downs (Justin giving memory back, DS2 scaling in) are never gated:
they free shared-cluster capacity.  ``run`` with no hook is byte-identical
to the single-tenant loop the golden traces pin.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.ds2 import ds2_parallelism, should_trigger
from repro.core.justin import (JustinParams, JustinState, OperatorDecision,
                               commit, justin_policy)
from repro.core.placement import TMSpec, placement_for_config
from repro.streaming.engine import StreamEngine


@dataclass(frozen=True)
class ControllerConfig:
    policy: str = "justin"                 # "justin" | "ds2"
    decision_window_s: float = 120.0
    stabilization_s: float = 60.0
    busy_high: float = 0.8
    target_busyness: float = 0.8
    max_parallelism: int = 64
    max_reconfigs: int = 8
    justin: JustinParams = field(default_factory=JustinParams)
    base_mem_mb: float = 158.0
    sim_time_scale: float = 0.1            # 1 sim tick = 10 paper-seconds


@dataclass
class HistoryRow:
    t: float
    step: int
    achieved_rate: float
    cpu_cores: int
    memory_mb: float
    config: dict
    triggered: bool
    target: float = 0.0               # the (possibly time-varying) target
    backlog: int = 0                  # queued events across all tasks
    denied: bool = False              # admission hook rejected this window's
                                      # scale-up request (retried next window)


class AutoScaler:
    def __init__(self, engine: StreamEngine, target_rate: float,
                 cfg: ControllerConfig = ControllerConfig(),
                 *, admission=None):
        self.engine = engine
        self.flow = engine.flow
        self.target = target_rate
        self.cfg = cfg
        self.jstate = JustinState()
        self.history: list[HistoryRow] = []
        self.steps = 0
        # optional ``admission(scaler, new_config, cpu, mem) -> bool``:
        # consulted before enacting a configuration that grows the resource
        # footprint (the cluster co-location arbitration point)
        self.admission = admission

    # ------------------------------------------------------------------ core
    def _window_s(self) -> float:
        return self.cfg.decision_window_s * self.cfg.sim_time_scale

    def _propose(self, metrics: dict[str, dict]
                 ) -> tuple[dict[str, tuple[int, int | None]],
                            dict[str, OperatorDecision] | None]:
        """Compute the policy's proposed C^t WITHOUT committing Justin's
        decision history — commit must wait until the proposal is admitted
        (a denied request never happened, as far as Algorithm 1 is
        concerned)."""
        ds2_p = ds2_parallelism(self.flow, metrics, self.target,
                                target_busyness=self.cfg.target_busyness,
                                max_parallelism=self.cfg.max_parallelism)
        if self.cfg.policy == "ds2":
            # DS2 couples memory to slots: every task keeps the base grant
            # whether stateful or not (the engine maps stateless ops to ⊥)
            return {op: (p, 0) for op, p in ds2_p.items()}, None
        decisions = justin_policy(self.flow, metrics, ds2_p, self.jstate,
                                  self.cfg.justin)
        return {op: (d.parallelism, d.memory_level)
                for op, d in decisions.items()}, decisions

    def decide(self, metrics: dict[str, dict]) -> dict[str, tuple[int, int | None]]:
        """Propose-and-commit in one call — the single-tenant convenience.
        NOT admission-aware: it commits Justin's decision history
        unconditionally, so co-located drivers must go through
        ``step_window`` (which defers the commit until the proposal is
        admitted)."""
        config, decisions = self._propose(metrics)
        if decisions is not None:
            commit(self.jstate, decisions, metrics)
        return config

    def resources(self, config: dict | None = None) -> tuple[int, float]:
        """(CPU slots, memory MB) the placement needs for ``config`` —
        the *current* flow configuration when not given, or a proposed C^t
        (the admission hook's pre-enactment quote)."""
        config = config if config is not None else self.flow.config()
        if self.cfg.policy == "ds2":
            # one-size-fits-all: every slot keeps the base managed grant
            # whether its task uses it or not (Takeaway 1)
            config = {op: (p, 0) for op, (p, lvl) in config.items()}
        pl = placement_for_config(config, base_mem_mb=self.cfg.base_mem_mb,
                                  exclude=set(self.flow.sources()))
        return pl.cpu_cores, pl.memory_mb

    def step_window(self, w: int = 0, *, target_profile=None,
                    window_hook=None) -> bool:
        """Run ONE decision window: observe, maybe trigger, maybe
        reconfigure.  Returns True when the window saw no trigger (the
        single-tenant loop's convergence signal).  The co-location driver
        calls this directly to interleave N episodes in lockstep."""
        if target_profile is not None:
            self.target = float(target_profile(self.engine.now))
        if window_hook is not None:
            window_hook(self.engine, w)
        self.engine.run(self._window_s(), self.target)
        metrics = self.engine.collect()
        src = sum(metrics[s]["rate_out"] for s in self.flow.sources())
        trig = (self.steps < self.cfg.max_reconfigs
                and should_trigger(self.flow, metrics, self.target,
                                   busy_high=self.cfg.busy_high))
        cpu, mem = self.resources()
        row = HistoryRow(
            t=self.engine.now, step=self.steps, achieved_rate=src,
            cpu_cores=cpu, memory_mb=mem,
            config=self.flow.config(), triggered=trig,
            target=self.target,
            backlog=sum(m["backlog"] for m in metrics.values()))
        self.history.append(row)
        if not trig:
            return True
        new_config, decisions = self._propose(metrics)
        if new_config != self.flow.config():
            cpu_new, mem_new = self.resources(new_config)
            grows = cpu_new > cpu or mem_new > mem
            if grows and self.admission is not None \
                    and not self.admission(self, new_config,
                                           cpu_new, mem_new):
                row.denied = True
                return False        # retry at the next window boundary
        if decisions is not None:
            commit(self.jstate, decisions, metrics)
        if new_config != self.flow.config():
            self.steps += 1
            self.engine.reconfigure(new_config)
            # stabilization: run and discard one short window
            self.engine.run(self.cfg.stabilization_s
                            * self.cfg.sim_time_scale, self.target)
            self.engine.collect()
        return False

    def run(self, *, max_windows: int | None = None,
            target_profile=None, window_hook=None) -> list[HistoryRow]:
        """Run until converged (no trigger) or max_reconfigs spent.

        ``target_profile``: optional callable ``r(engine.now) -> events/s``
        sampled at each window boundary (the scenario subsystem's rate
        profiles).  With a profile the loop never declares convergence —
        the workload may move again — so it runs all ``max_windows``.
        ``window_hook``: optional callable ``(engine, window_idx)`` fired
        before each window (fault injection point).
        """
        windows = max_windows or (self.cfg.max_reconfigs + 4)
        for w in range(windows):
            quiet = self.step_window(w, target_profile=target_profile,
                                     window_hook=window_hook)
            if quiet and w > 0 and target_profile is None:
                break       # converged after at least one observation
        return self.history

    # ------------------------------------------------------------- reporting
    def summary(self) -> dict:
        last = self.history[-1]
        return {"policy": self.cfg.policy, "steps": self.steps,
                "achieved_rate": last.achieved_rate, "target": self.target,
                "cpu_cores": last.cpu_cores, "memory_mb": last.memory_mb,
                "config": {op: pc for op, pc in last.config.items()},
                "windows": len(self.history)}
