"""Reconfiguration controller: the auto-scaler loop (§5 protocol).

Runs the engine in decision windows; on a policy trigger asks the
:class:`~repro.core.policy.ScalingPolicy` for a proposed configuration,
enacts it via the engine (state re-partition / backend resize) and the
bin-packing placement, then waits a stabilization period.  History rows
capture what Fig. 5 plots: achieved rate, CPU cores, memory MB, per step —
plus the per-window backlog and admission outcomes the SLO/cluster layers
consume.

The controller is policy-agnostic: ``ControllerConfig.policy`` is a
registry name resolved through :func:`repro.core.policy.make_policy`
(``ds2``, ``justin``, ``static``, ``threshold``, or anything registered
with ``@register_policy``), and a pre-built policy instance may be passed
directly.  Everything policy-specific — DS2's uniform memory packages,
Justin's Algorithm-1 decision history and its deferred commit, a threshold
scaler's symptom detection — lives behind the policy protocol.

Co-location support: an ``AutoScaler`` may be constructed with an
``admission`` hook, consulted whenever a proposed reconfiguration would
*grow* the episode's resource footprint (more CPU slots or more memory than
the current placement).  A denied request leaves the configuration — and
the policy's decision history, because ``commit`` is only called on
admission — untouched, so the trigger persists and the same request is
re-made at the next window boundary.  Scale-downs (Justin giving memory
back, DS2 scaling in) are never gated: they free shared-cluster capacity.
``run`` with no hook is byte-identical to the single-tenant loop the
golden traces pin.

Admission-aware placement v2: with a shared-TM ``cluster`` attached (the
co-location driver sets ``scaler.cluster`` / ``scaler.tenant``), admission
quotes go through ``resources(config, cluster=...)`` — the tenant's
amortized attribution under the cluster-level packing rather than a
private fleet's footprint — and ``shrink_memory()`` is the preemption
entry point: the arbiter forces a one-level memory give-back (via the
policy's ``propose_shrink``) to make a higher-priority tenant's request
fit.

Reconfiguration cost: an optional ``migration``
(:class:`repro.migration.MigrationRuntime`) prices every enacted
reconfiguration — a key-range handoff plan over the old/new placements,
priced as ``savepoint`` (downtime ∝ total state MB) or ``handoff``
(downtime ∝ moved MB) — and converts the price into PAUSED engine time
before stabilization: sources keep producing (backlog accrues) while
nothing processes.  The window's ``HistoryRow`` records the price
(``reconfig_downtime`` / ``moved_mb``).  Without a runtime, or under the
default ``instant`` mechanism, enactment is byte-identical to the
pre-migration loop the golden traces pin.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.justin import JustinParams
from repro.core.placement import (TaskRequest, placement_for_config,
                                  placement_requests)
from repro.core.policy import ScalingPolicy, make_policy
from repro.core.units import mem_exceeds
from repro.obs.provenance import (REASON_DENIED, REASON_RECONFIGURED,
                                  REASON_STEADY, REASON_TRIGGERED,
                                  explain_admission, reason_counts)
from repro.obs.trace import NULL_TRACER
from repro.streaming.engine import StreamEngine


@dataclass(frozen=True)
class ControllerConfig:
    policy: str = "justin"                 # registry name; see
                                           # repro.core.policy
    decision_window_s: float = 120.0
    stabilization_s: float = 60.0
    busy_high: float = 0.8
    target_busyness: float = 0.8
    max_parallelism: int = 64
    max_reconfigs: int = 8
    justin: JustinParams = field(default_factory=JustinParams)
    base_mem_mb: float = 158.0
    sim_time_scale: float = 0.1            # 1 sim tick = 10 paper-seconds


@dataclass
class HistoryRow:
    t: float
    step: int
    achieved_rate: float
    cpu_cores: int
    memory_mb: float
    config: dict
    triggered: bool
    target: float = 0.0               # the (possibly time-varying) target
    backlog: int = 0                  # queued events across all tasks
    denied: bool = False              # admission hook rejected this window's
                                      # scale-up request (retried next window)
    preempted: bool = False           # a higher-priority tenant forced a
                                      # memory give-back this window
    amortized_mb: float | None = None  # shared-TM attribution (base_mb
                                       # amortized across co-residents);
                                       # None == private placement quote
    reconfig_downtime: float = 0.0    # paused paper-seconds this window's
                                      # reconfiguration cost (migration
                                      # runtime; 0 under "instant")
    moved_mb: float = 0.0             # state MB that physically travelled
                                      # for this window's reconfiguration
    reason: str = REASON_STEADY       # why the window ended this way
                                      # (repro.obs.provenance.REASONS);
                                      # the fleet drivers upgrade
                                      # denied -> deferred and mark
                                      # preemption victims "shrunk"


class AutoScaler:
    def __init__(self, engine: StreamEngine, target_rate: float,
                 cfg: ControllerConfig = ControllerConfig(),
                 *, admission=None, policy: ScalingPolicy | None = None,
                 migration=None, tracer=None):
        self.engine = engine
        self.flow = engine.flow
        self.target = target_rate
        self.cfg = cfg
        # the policy drives every decision; by default it is constructed
        # from the registry under this episode's config
        self.policy = policy if policy is not None \
            else make_policy(cfg.policy, cfg)
        self.history: list[HistoryRow] = []
        self.steps = 0
        # optional ``admission(scaler, new_config, cpu, mem) -> bool``:
        # consulted before enacting a configuration that grows the resource
        # footprint (the cluster co-location arbitration point)
        self.admission = admission
        # co-location identity + quoting context, set by the cluster driver:
        # with a shared-TM ``cluster`` attached, admission quotes are the
        # tenant's amortized attribution under the shared placement
        self.tenant: str = ""
        self.cluster = None
        self.preemptions = 0          # forced give-backs suffered (not
                                      # counted in ``steps``: they are the
                                      # arbiter's reconfigs, not the
                                      # policy's)
        # optional ``repro.migration.MigrationRuntime``: prices every
        # enacted reconfiguration and converts the price into paused
        # engine time.  None (and the "instant" mechanism) are strict
        # no-ops — the golden traces' default.
        self.migration = migration
        # optional fleet-wide placement-quote cache, attached by the
        # vectorized co-location driver: private-fleet quotes are pure
        # functions of (query, base grant, transformed config), so one
        # dict shared across tenants collapses N identical bin_packs per
        # window into one per DISTINCT configuration.  None (the
        # default, and the scalar oracle) recomputes every quote.
        self.quote_cache: dict | None = None
        self._last_metrics: dict[str, dict] = {}
        # optional deterministic span tracer (repro.obs.trace): spans are
        # stamped with SIM time only, and the shared NULL_TRACER default
        # makes every record an O(1) no-op — decisions are byte-identical
        # with tracing on or off (pinned by tests/test_obs.py)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # per-operator (flushes, compactions) marks for the LSM window
        # spans — observability bookkeeping only, never read by decisions
        self._lsm_marks: dict[str, tuple[int, int]] = {}

    # ------------------------------------------------------------------ core
    def _window_s(self) -> float:
        return self.cfg.decision_window_s * self.cfg.sim_time_scale

    def decide(self, metrics: dict[str, dict]) -> dict[str, tuple[int, int | None]]:
        """Propose-and-commit in one call — the single-tenant convenience.
        NOT admission-aware: it commits the policy's decision history
        unconditionally, so co-located drivers must go through
        ``step_window`` (which defers the commit until the proposal is
        admitted)."""
        proposal = self.policy.propose(self.flow, metrics, self.target,
                                       self.cfg)
        self.policy.commit(metrics)
        return proposal.config

    def task_requests(self, config: dict | None = None) -> list[TaskRequest]:
        """The tenant-tagged task list ``config`` asks the packer for —
        what the shared-TM cluster packs alongside other tenants' tasks."""
        config = config if config is not None else self.flow.config()
        config = self.policy.resources_config(config)
        return placement_requests(config, base_mem_mb=self.cfg.base_mem_mb,
                                  exclude=set(self.flow.sources()),
                                  tenant=self.tenant)

    def resources(self, config: dict | None = None, *,
                  cluster=None) -> tuple[int, float]:
        """(CPU slots, memory MB) the placement needs for ``config`` —
        the *current* flow configuration when not given, or a proposed C^t
        (the admission hook's pre-enactment quote).  The policy's
        ``resources_config`` supplies the memory-coupling model (e.g. DS2
        keeps the uniform base grant on every slot — Takeaway 1).

        With a shared-TM ``cluster``, the quote is this tenant's amortized
        attribution under the cluster-level packing (its slots + managed
        grants + its slot-proportional share of each co-resident TM's
        ``base_mb``) instead of a private fleet's footprint."""
        if cluster is not None and getattr(cluster, "shared", False):
            return cluster.quote(self.tenant, self.task_requests(config))
        config = config if config is not None else self.flow.config()
        config = self.policy.resources_config(config)
        key = None
        if self.quote_cache is not None:
            key = (self.flow.name, self.cfg.base_mem_mb,
                   tuple(sorted(config.items())))
            hit = self.quote_cache.get(key)
            if hit is not None:
                return hit
        pl = placement_for_config(config, base_mem_mb=self.cfg.base_mem_mb,
                                  exclude=set(self.flow.sources()))
        out = (pl.cpu_cores, pl.memory_mb)
        if key is not None:
            self.quote_cache[key] = out
        return out

    def shrink_memory(self) -> tuple[int, float] | None:
        """Forced memory give-back — the §4.3 preemption mechanism.  Asks
        the policy for a one-level shrink proposal
        (:meth:`ScalingPolicy.propose_shrink`), enacts it through the
        normal reconfigure + stabilization path, and returns the new
        private (cpu, mem) footprint; ``None`` when nothing can shrink.
        Driven by the cluster arbiter when another tenant's admission
        needs the memory; the give-back is counted in
        ``preemptions``, not ``steps`` (it is the arbiter's
        reconfiguration, not this policy's)."""
        prop = self.policy.propose_shrink(self.flow, self.cfg)
        if prop is None or prop.config == self.flow.config():
            return None
        old_config = self.flow.config()
        self.policy.commit(self._last_metrics)
        t0 = self.engine.now
        cost = self._enact(prop.config)
        if cost is not None and self.history:
            # the give-back's price lands on the victim's current window
            row = self.history[-1]
            row.reconfig_downtime += cost.downtime_s
            row.moved_mb += cost.moved_mb
        self.preemptions += 1
        out = self.resources()
        self.tracer.record(
            "preempt.shrink", "preempt", t0, self.engine.now,
            tenant=self.tenant,
            args={"old": {op: list(pc) for op, pc in old_config.items()},
                  "new": {op: list(pc) for op, pc in prop.config.items()},
                  "cpu_cores": out[0], "memory_mb": out[1]})
        return out

    def _enact(self, new_config: dict, *, window: int | None = None):
        """Reconfigure + stabilize, pricing the re-shape through the
        migration runtime when one is attached: the planned downtime
        becomes PAUSED engine time (sources accrue backlog, nothing
        processes) before the stabilization window.  Returns the
        :class:`~repro.migration.costs.ReconfigCost`, or None without a
        runtime — in which case (and under the "instant" mechanism) the
        enactment is byte-identical to the pre-migration controller."""
        cost = None
        if self.migration is not None:
            # plan against the OLD config's live stores, before they are
            # re-partitioned away
            cost = self.migration.charge(self, self.flow.config(),
                                         new_config)
        self.engine.reconfigure(new_config)
        t0 = self.engine.now
        if cost is not None and cost.downtime_s > 0:
            self.engine.run_paused(
                cost.downtime_s * self.cfg.sim_time_scale, self.target)
            self.tracer.record(
                "migration.paused", "migration", t0, self.engine.now,
                tenant=self.tenant, window=window,
                args={"downtime_s": cost.downtime_s,
                      "moved_mb": cost.moved_mb})
        t1 = self.engine.now
        # stabilization: run and discard one short window
        self.engine.run(self.cfg.stabilization_s * self.cfg.sim_time_scale,
                        self.target)
        self.tracer.record("engine.stabilize", "engine", t1, self.engine.now,
                         tenant=self.tenant, window=window)
        self.engine.collect()
        return cost

    def _emit_lsm_spans(self, metrics: dict[str, dict], t0: float,
                        t1: float, w: int) -> None:
        """Per-stateful-operator storage span: the window's theta/tau plus
        flush/compaction work since the last mark.  Marks live in
        ``_lsm_marks`` (obs-only state); after a reconfigure the rebuilt
        stores restart their totals, so deltas clamp at zero and the mark
        re-baselines — never read by any decision."""
        for name, m in metrics.items():
            if not m["stateful"]:
                continue
            flushes = compactions = 0
            for tr in self.engine.tasks[name]:
                if tr.state is not None:
                    f, c = tr.state.metrics.maintenance()
                    flushes += f
                    compactions += c
            pf, pc = self._lsm_marks.get(name, (0, 0))
            self._lsm_marks[name] = (flushes, compactions)
            self.tracer.record(
                "lsm.window", "lsm", t0, t1, tenant=self.tenant, window=w,
                args={"op": name, "theta": m.get("theta"),
                      "tau_ms": m.get("tau_ms"),
                      "memory_level": m["memory_level"],
                      "flushes": max(flushes - pf, 0),
                      "compactions": max(compactions - pc, 0)})

    def step_window(self, w: int = 0, *, target_profile=None,
                    window_hook=None) -> bool:
        """Run ONE decision window: observe, maybe trigger, maybe
        reconfigure.  Returns True when the window saw no trigger (the
        single-tenant loop's convergence signal).  The co-location driver
        calls this directly to interleave N episodes in lockstep."""
        if target_profile is not None:
            self.target = float(target_profile(self.engine.now))
        if window_hook is not None:
            window_hook(self.engine, w)
        t0 = self.engine.now
        self.engine.run(self._window_s(), self.target)
        t_run = self.engine.now
        metrics = self.engine.collect()
        self._last_metrics = metrics
        src = sum(metrics[s]["rate_out"] for s in self.flow.sources())
        trig = (self.steps < self.cfg.max_reconfigs
                and self.policy.should_trigger(self.flow, metrics,
                                               self.target, self.cfg))
        cpu, mem = self.resources()
        row = HistoryRow(
            t=self.engine.now, step=self.steps, achieved_rate=src,
            cpu_cores=cpu, memory_mb=mem,
            config=self.flow.config(), triggered=trig,
            target=self.target,
            backlog=sum(m["backlog"] for m in metrics.values()))
        self.history.append(row)
        self.tracer.record("engine.window", "engine", t0, t_run,
                         tenant=self.tenant, window=w,
                         args={"target": self.target, "achieved_rate": src,
                               "backlog": row.backlog, "cpu_cores": cpu,
                               "memory_mb": mem})
        if self.tracer.enabled:
            self._emit_lsm_spans(metrics, t0, t_run, w)
        self.tracer.record("policy.should_trigger", "policy", t_run, t_run,
                         tenant=self.tenant, window=w,
                         args={"triggered": trig, "steps": self.steps,
                               "max_reconfigs": self.cfg.max_reconfigs})
        if not trig:
            return True
        row.reason = REASON_TRIGGERED
        proposal = self.policy.propose(self.flow, metrics, self.target,
                                       self.cfg)
        new_config = proposal.config
        if self.tracer.enabled:
            args = {"config": {op: list(pc)
                               for op, pc in new_config.items()},
                    "changed": new_config != self.flow.config()}
            if proposal.explain is not None:
                args.update(proposal.explain.to_dict())
            self.tracer.record("policy.propose", "policy", t_run, t_run,
                             tenant=self.tenant, window=w, args=args)
        if new_config != self.flow.config():
            # quote against the shared placement when a shared-TM cluster
            # is attached: admission gates growth of the tenant's
            # amortized attribution, not of a hypothetical private fleet
            # (a scalar cluster quotes private placements — identical to
            # the (cpu, mem) above, so don't re-pack)
            shared = self.cluster if self.cluster is not None \
                and self.cluster.shared else None
            cpu_new, mem_new = self.resources(new_config, cluster=shared)
            cpu_cur, mem_cur = (cpu, mem) if shared is None \
                else self.resources(cluster=shared)
            # epsilon-disciplined growth test: shared-TM attributions are
            # accumulated floats, and a drifted re-quote of an identical
            # footprint must not be gated (and possibly denied) as a
            # scale-up
            grows = cpu_new > cpu_cur or mem_exceeds(mem_new, mem_cur)
            admitted = None
            if grows and self.admission is not None:
                admitted = self.admission(self, new_config,
                                          cpu_new, mem_new)
            self.tracer.record(
                "admission.quote", "admission", t_run, t_run,
                tenant=self.tenant, window=w,
                args=explain_admission(
                    cpu_cur=cpu_cur, mem_cur=mem_cur, cpu_new=cpu_new,
                    mem_new=mem_new, grows=grows, admitted=admitted,
                    shared=shared is not None))
            if admitted is False:
                row.denied = True
                row.reason = REASON_DENIED
                return False        # retry at the next window boundary
        self.policy.commit(metrics)
        self.tracer.record("policy.commit", "policy", t_run, t_run,
                         tenant=self.tenant, window=w,
                         args={"enacting": new_config != self.flow.config()})
        if new_config != self.flow.config():
            self.steps += 1
            cost = self._enact(new_config, window=w)
            row.reason = REASON_RECONFIGURED
            if cost is not None:
                row.reconfig_downtime = cost.downtime_s
                row.moved_mb = cost.moved_mb
        return False

    def run(self, *, max_windows: int | None = None,
            target_profile=None, window_hook=None) -> list[HistoryRow]:
        """Run until converged (no trigger) or max_reconfigs spent.

        ``target_profile``: optional callable ``r(engine.now) -> events/s``
        sampled at each window boundary (the scenario subsystem's rate
        profiles).  With a profile the loop never declares convergence —
        the workload may move again — so it runs all ``max_windows``.
        ``window_hook``: optional callable ``(engine, window_idx)`` fired
        before each window (fault injection point).
        """
        # explicit None check: ``max_windows=0`` means zero windows, not
        # the default budget (the ``or`` idiom ran max_reconfigs + 4)
        windows = max_windows if max_windows is not None \
            else self.cfg.max_reconfigs + 4
        for w in range(windows):
            quiet = self.step_window(w, target_profile=target_profile,
                                     window_hook=window_hook)
            if quiet and w > 0 and target_profile is None:
                break       # converged after at least one observation
        return self.history

    # ------------------------------------------------------------- reporting
    def summary(self) -> dict:
        if not self.history:
            # zero-window summary: nothing observed yet, report the current
            # placement rather than crashing on history[-1]
            cpu, mem = self.resources()
            return {"policy": self.policy.name, "steps": self.steps,
                    "achieved_rate": 0.0, "target": self.target,
                    "cpu_cores": cpu, "memory_mb": mem,
                    "config": dict(self.flow.config()), "windows": 0,
                    "reconfig_downtime_s": 0.0, "moved_mb": 0.0,
                    "reasons": {}}
        last = self.history[-1]
        return {"policy": self.policy.name, "steps": self.steps,
                "achieved_rate": last.achieved_rate, "target": self.target,
                "cpu_cores": last.cpu_cores, "memory_mb": last.memory_mb,
                "config": {op: pc for op, pc in last.config.items()},
                "windows": len(self.history),
                "reconfig_downtime_s": sum(h.reconfig_downtime
                                           for h in self.history),
                "moved_mb": sum(h.moved_mb for h in self.history),
                "reasons": reason_counts(self.history)}
