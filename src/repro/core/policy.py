"""Pluggable scaling-policy API: the registry behind every ``--policy`` flag.

The paper positions Justin as one point in a *space* of auto-scaling
policies — it extends DS2 (Kalavri et al., OSDI'18) and evaluates against
it head-to-head; reactive threshold scalers (Dhalion, Floratou et al.,
VLDB'17) are the other obvious family.  This module makes that space a
first-class API so a new policy is an ``import`` + ``@register_policy``,
not a controller edit:

* :class:`ScalingPolicy` — the protocol the controller drives.  A policy
  owns the whole decision surface:

  - ``should_trigger(flow, metrics, target, cfg)`` — does this window need
    a reconfiguration?  (default: the unmodified DS2 trigger);
  - ``propose(flow, metrics, target, cfg)`` — compute the proposed C^t as
    a :class:`Proposal` WITHOUT committing any decision history;
  - ``commit(metrics)`` — the proposal was admitted: fold it into the
    policy's decision history (Justin's Algorithm-1 state lives here, so
    admission-denial semantics belong to the policy, not the controller);
  - ``resources_config(config)`` — the policy's memory-coupling model:
    how an enacted configuration translates into per-task memory grants
    when the placement is quoted (DS2-style packages keep the uniform
    base grant on every slot; Justin grants per level).

* :class:`Proposal` — the per-operator ``(parallelism, memory_level)`` map
  plus whatever pending decision state the policy needs at commit time.

* ``@register_policy("name")`` / :func:`make_policy` /
  :func:`available_policies` — the registry.  ``ControllerConfig.policy``
  is a registry name; the controller, scenario runner, cluster driver,
  evaluation grid and benchmark CLIs all construct policies through it.

Built-ins: ``ds2``, ``justin`` (ported from their modules — decision
traces are pinned byte-identical by ``tests/test_golden_trace.py``),
``static`` (fixed-allocation baseline) and ``threshold`` (Dhalion-style
backpressure-reactive scale-out with uniform memory).  See
docs/policies.md for the writing-a-new-policy walkthrough.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core import ds2 as _ds2
from repro.core.justin import (JustinState, OperatorDecision,
                               commit as _justin_commit, justin_policy)
from repro.obs.provenance import (Explain, explain_ds2, explain_justin,
                                  explain_static, explain_threshold)

# A configuration C^t: per-operator (parallelism, memory_level), where the
# level is None (⊥) for operators holding no managed memory.
Config = dict[str, tuple[int, int | None]]


@dataclass
class Proposal:
    """A policy's proposed C^t plus the decision state that must only be
    folded into the policy's history once the proposal is admitted."""
    config: Config
    pending: object | None = None     # policy-private (e.g. Justin's
                                      # OperatorDecision map)
    explain: Explain | None = None    # decision provenance: the signal
                                      # values the proposal was computed
                                      # from (repro.obs.provenance) —
                                      # pure observation, never read back
                                      # by the controller's decisions


class ScalingPolicy:
    """Base class / protocol for auto-scaling policies.

    Subclasses are constructed with the :class:`ControllerConfig` they will
    run under (``make_policy(name, cfg)``) and must implement ``propose``;
    the other hooks have DS2-shaped defaults.  A policy instance belongs to
    ONE episode: it may keep decision history across windows (Justin does).

    The base class does not retain ``cfg``: every hook receives the driving
    controller's cfg per call, which stays the single source of truth.  A
    subclass that needs construction-time parameters derives and stores
    them itself.
    """
    name: str = "?"                   # set by @register_policy

    def __init__(self, cfg):
        self._last: Proposal | None = None

    # ------------------------------------------------------------- protocol
    def should_trigger(self, flow, metrics: dict[str, dict], target: float,
                       cfg) -> bool:
        """Does this window warrant a reconfiguration?  Default: the
        unmodified DS2 trigger (under-rate, or busy + backlog)."""
        return _ds2.should_trigger(flow, metrics, target,
                                   busy_high=cfg.busy_high)

    def propose(self, flow, metrics: dict[str, dict], target: float,
                cfg) -> Proposal:
        """Compute the proposed C^t.  MUST NOT mutate policy history — a
        denied proposal never happened; history moves in ``commit``."""
        raise NotImplementedError

    def commit(self, metrics: dict[str, dict]) -> None:
        """The last proposal was admitted (or enacted): fold its pending
        decision state into the policy's history.  Default: stateless."""
        self._last = None

    def propose_shrink(self, flow, cfg) -> Proposal | None:
        """Preemptive reclamation (§4.3): propose giving back ONE storage
        level — drop the highest occupied memory level by one on the
        operator holding it.  The cluster arbiter drives this when
        another tenant's admission needs the memory
        (``AutoScaler.shrink_memory``; victims are selected fair-share —
        see ``scenarios.cluster``).  Returns ``None`` when no operator
        holds a level above 0 — uniform-package policies at the base
        grant have nothing to give back, which is exactly the §4.3
        asymmetry: only hybrid-scaled tenants can be re-shaped in place.
        Like ``propose``, MUST NOT mutate history; ``commit`` does."""
        config = flow.config()
        cands = [(lvl, op) for op, (_p, lvl) in config.items()
                 if lvl is not None and lvl > 0]
        if not cands:
            return None
        lvl, op = max(cands)
        new = dict(config)
        new[op] = (config[op][0], lvl - 1)
        self._last = Proposal(new)
        return self._last

    def resources_config(self, config: Config) -> Config:
        """Map an enacted configuration to the per-task memory grants the
        placement should be quoted with — the policy's memory-coupling
        model.  Default: grants are exactly what the configuration says
        (Justin's heterogeneous per-level model)."""
        return config


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type[ScalingPolicy]] = {}


def register_policy(name: str):
    """Class decorator: ``@register_policy("mine")`` makes the policy
    constructible everywhere a ``--policy``/``ControllerConfig.policy``
    name is accepted."""
    def deco(cls):
        if not (isinstance(cls, type) and issubclass(cls, ScalingPolicy)):
            raise TypeError(f"{cls!r} is not a ScalingPolicy subclass")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available_policies() -> list[str]:
    """Registered policy names, sorted."""
    return sorted(_REGISTRY)


def make_policy(name: str, cfg) -> ScalingPolicy:
    """Construct a registered policy for one episode under ``cfg``."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scaling policy {name!r} "
            f"(available: {', '.join(available_policies())})") from None
    return cls(cfg)


# ---------------------------------------------------------------------------
# Built-in policies
# ---------------------------------------------------------------------------

@register_policy("ds2")
class DS2Policy(ScalingPolicy):
    """CPU-only DS2 (OSDI'18): enact the parallelism proposal as-is; every
    slot keeps the uniform base managed-memory grant whether its task uses
    it or not — the one-size-fits-all package Takeaway 1 criticizes."""

    def propose(self, flow, metrics, target, cfg) -> Proposal:
        ds2_p = _ds2.ds2_parallelism(flow, metrics, target,
                                     target_busyness=cfg.target_busyness,
                                     max_parallelism=cfg.max_parallelism)
        # memory is coupled to slots: level 0 everywhere (the engine maps
        # stateless operators to ⊥ at enactment)
        self._last = Proposal(
            {op: (p, 0) for op, p in ds2_p.items()},
            explain=explain_ds2(metrics, ds2_p, target, cfg))
        return self._last

    def resources_config(self, config: Config) -> Config:
        return {op: (p, 0) for op, (p, lvl) in config.items()}


@register_policy("justin")
class JustinPolicy(ScalingPolicy):
    """Justin's hybrid policy: Algorithm 1 over the DS2 proposal.  The
    deferred commit lives here — a denied proposal leaves the decision
    history C^0..C^{t-1} untouched, so the same request is re-made at the
    next window boundary."""

    def __init__(self, cfg):
        super().__init__(cfg)
        self.state = JustinState()

    def propose(self, flow, metrics, target, cfg) -> Proposal:
        ds2_p = _ds2.ds2_parallelism(flow, metrics, target,
                                     target_busyness=cfg.target_busyness,
                                     max_parallelism=cfg.max_parallelism)
        decisions = justin_policy(flow, metrics, ds2_p, self.state,
                                  cfg.justin)
        self._last = Proposal(
            {op: (d.parallelism, d.memory_level)
             for op, d in decisions.items()},
            pending=decisions,
            explain=explain_justin(metrics, ds2_p, decisions, self.state,
                                   target, cfg.justin))
        return self._last

    def commit(self, metrics: dict[str, dict]) -> None:
        if self._last is not None and self._last.pending is not None:
            _justin_commit(self.state, self._last.pending, metrics)
        self._last = None

    def propose_shrink(self, flow, cfg) -> Proposal | None:
        """A forced give-back enters Algorithm 1's history as a plain
        (not-scaled-up) decision, so the next window evaluates pressure
        afresh instead of judging the shrink as a failed scale-up."""
        prop = super().propose_shrink(flow, cfg)
        if prop is not None:
            prop.pending = {op: OperatorDecision(p, lvl, False)
                            for op, (p, lvl) in prop.config.items()}
        return prop


@register_policy("static")
class StaticPolicy(ScalingPolicy):
    """Fixed-allocation baseline: whatever the episode started with, it
    keeps.  Never triggers, never reconfigures — the floor every elastic
    policy is compared against (and the control for SLO-violation counts
    under dynamic profiles)."""

    def should_trigger(self, flow, metrics, target, cfg) -> bool:
        return False

    def propose(self, flow, metrics, target, cfg) -> Proposal:
        self._last = Proposal({op: (m["parallelism"], m["memory_level"])
                               for op, m in metrics.items()},
                              explain=explain_static(metrics, target))
        return self._last


@register_policy("threshold")
class ThresholdPolicy(ScalingPolicy):
    """Dhalion-style reactive threshold scaler (Floratou et al., VLDB'17):
    no performance model — when the symptom (backpressure) appears, double
    the parallelism of every operator busier than ``busy_high``; memory
    stays a uniform per-slot package like DS2's.  Scale-ins are never
    proposed (the classic ratchet the model-based policies avoid)."""

    scale_factor: float = 2.0

    def propose(self, flow, metrics, target, cfg) -> Proposal:
        sources, sinks = set(flow.sources()), set(flow.sinks())
        out: Config = {op: (m["parallelism"], 0) for op, m in metrics.items()}
        scalable = [n for n in metrics
                    if n not in sources and n not in sinks]
        hot = [n for n in scalable
               if metrics[n]["busyness"] > cfg.busy_high]
        if not hot and scalable:
            # triggered on under-rate alone: without a model, blame the
            # busiest operator
            hot = [max(scalable, key=lambda n: metrics[n]["busyness"])]
        for name in hot:
            p = metrics[name]["parallelism"]
            out[name] = (min(math.ceil(p * self.scale_factor),
                             cfg.max_parallelism), 0)
        self._last = Proposal(
            out, explain=explain_threshold(flow, metrics, target, cfg,
                                           self.scale_factor))
        return self._last

    def resources_config(self, config: Config) -> Config:
        return {op: (p, 0) for op, (p, lvl) in config.items()}
