"""Justin's hybrid elastic-scaling policy — Algorithm 1 of the paper,
implemented verbatim over the DS2 proposal.

Per stateful operator o_i with a DS2 rescale proposal:
  * if it was scaled up last time (v^{t-1}):
      - improvement (θ↑ or τ↓)?  keep p, scale up again (until maxLevel)
      - no improvement?           roll the memory back, keep DS2's p
  * else: memory pressure (θ < Δθ or τ > Δτ) and headroom?  cancel the
    scale-out, scale up instead.
Stateless operators get m = ⊥ (no managed memory) — Takeaway 1.

Symbol map (paper → code):

=============  ==========================================================
paper          here
=============  ==========================================================
θ (theta)      effective in-memory hit rate of an operator's state reads
               (fraction that never probed an on-"disk" LSM level);
               computed per window in ``StreamEngine.collect`` from the
               LSM counters, read by the policy as ``metrics[op]["theta"]``
               — the §4.2 storage-performance signal
τ (tau)        mean state-access latency in ms over the window
               (``metrics[op]["tau_ms"]``), θ's companion signal
Δθ, Δτ         pressure thresholds (``JustinParams.delta_theta`` /
               ``delta_tau_ms``): θ below Δθ or τ above Δτ ⇒ the operator
               is memory-pressured (Algorithm 1 line 16)
m, maxLevel    the memory-level ladder: level ℓ grants base·2^ℓ MB of
               managed memory per task (``engine.level_mb``; base 158 MB,
               §5 testbed), capped at ``JustinParams.max_level``; ⊥
               (``None``) = no managed memory for stateless operators
v^t            ``OperatorDecision.scaled_up`` — "this window's decision
               was a memory scale-up", consulted at t+1 (line 7)
C^t            the per-operator ``(parallelism, memory_level)`` map the
               controller enacts (``AutoScaler._propose``)
footnote 3     ``JustinParams.hysteresis``: a scale-up must improve θ/τ
               by this relative margin to count (line 8), else line 14
               rolls the level back
=============  ==========================================================
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class JustinParams:
    delta_theta: float = 0.80        # Δθ: cache hit rate threshold
    delta_tau_ms: float = 1.0        # Δτ: state access latency threshold
    max_level: int = 3               # memory levels (0 => base grant)
    hysteresis: float = 0.10         # min relative improvement (footnote 3):
                                     # below this a scale-up "did not improve"
                                     # and is rolled back (Algorithm 1 l.14)


@dataclass
class OperatorDecision:
    parallelism: int
    memory_level: int | None        # None == ⊥
    scaled_up: bool = False          # v^t


@dataclass
class JustinState:
    """Decision history C^0..C^{t-1} plus last-window metrics."""
    prev_config: dict[str, OperatorDecision] = field(default_factory=dict)
    prev_metrics: dict[str, dict] = field(default_factory=dict)


def justin_policy(flow, metrics: dict[str, dict], ds2_p: dict[str, int],
                  state: JustinState, params: JustinParams = JustinParams()
                  ) -> dict[str, OperatorDecision]:
    """Algorithm 1.  Returns the new configuration C^t."""
    out: dict[str, OperatorDecision] = {}
    for name, m in metrics.items():
        p_new = ds2_p.get(name, m["parallelism"])
        prev = state.prev_config.get(
            name, OperatorDecision(m["parallelism"],
                                   m["memory_level"], False))
        prev_m = state.prev_metrics.get(name, m)

        if not m["stateful"]:                          # line 3-4
            out[name] = OperatorDecision(p_new, None, False)
            continue

        m_prev = prev.memory_level if prev.memory_level is not None else 0
        dec = OperatorDecision(p_new, m_prev, False)

        if p_new != prev.parallelism:                  # line 6: insufficient
            theta, tau = m.get("theta"), m.get("tau_ms")
            theta_p = prev_m.get("theta")
            tau_p = prev_m.get("tau_ms")
            if prev.scaled_up:                         # line 7
                improved = _improved(theta, tau, theta_p, tau_p,
                                     params.hysteresis)
                if improved and (m_prev + 1) < params.max_level:  # line 8-9
                    dec.parallelism = prev.parallelism  # line 10: cancel out
                    dec.memory_level = m_prev + 1       # line 11
                    dec.scaled_up = True                # line 12
                elif not improved:                      # line 13
                    dec.memory_level = max(0, m_prev - 1)  # line 14 rollback
            else:                                      # line 16
                pressure = ((theta is not None and theta < params.delta_theta)
                            or (tau is not None and tau > params.delta_tau_ms))
                if pressure and (m_prev + 1) < params.max_level:
                    dec.parallelism = prev.parallelism  # line 17: cancel out
                    dec.memory_level = m_prev + 1       # line 18
                    dec.scaled_up = True                # line 19
        out[name] = dec
    return out


def _improved(theta, tau, theta_prev, tau_prev, eps: float) -> bool:
    """Line 8: θ^t > θ^{t-1} or τ^t < τ^{t-1}, with hysteresis (footnote 3)."""
    if theta is not None and theta_prev is not None \
            and theta > theta_prev * (1 + eps):
        return True
    if tau is not None and tau_prev is not None \
            and tau < tau_prev * (1 - eps):
        return True
    return False


def commit(state: JustinState, config: dict[str, OperatorDecision],
           metrics: dict[str, dict]) -> None:
    state.prev_config = dict(config)
    state.prev_metrics = dict(metrics)
