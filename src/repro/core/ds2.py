"""DS2 (Kalavri et al., OSDI'18) — the CPU-only auto-scaler Justin extends.

DS2's model: an operator's *true processing rate* per task is its observed
processing rate divided by its busyness (the useful-time fraction).  Given a
target source rate, true rates propagate topologically through the dataflow
using observed selectivities; the new parallelism is

    p_i = ceil( true_input_rate_i(target) / true_rate_per_task_i )

optionally headroom-scaled so the resulting busyness sits below a target.

Symbol map (paper §2.2/§4 → code):

=================  ======================================================
paper              here
=================  ======================================================
busyness           ``metrics[op]["busyness"]`` — fraction of task time
                   spent processing (the engine's per-window measurement
                   of Flink's "busy time"); DS2's only scaling signal,
                   which is why it over-provisions memory-pressured
                   operators (§4: capacity estimates made under pressure
                   are too low, forcing several reconfiguration steps)
true rate/task     ``true_rate_per_task`` = processed / busy_s, events/s
                   one task sustains at 100% busyness
selectivity        ``metrics[op]["selectivity"]`` = out/in events over
                   the window, used to propagate the target through the
                   dataflow topologically
p_i                ``ds2_parallelism`` result — the CPU half of C^t; in
                   "ds2" mode every slot also keeps the uniform base
                   managed-memory grant (the one-size-fits-all package
                   Takeaway 1 criticizes; see ``AutoScaler.resources``)
trigger            ``should_trigger`` — unmodified DS2: source rate below
                   target, or any operator busy above ``busy_high`` with
                   a backlog (backpressure)
=================  ======================================================
"""
from __future__ import annotations

import math


def true_rate_per_task(m: dict) -> float:
    """Events/s one task can sustain at 100% busyness."""
    busy = max(m["busy_s"], 1e-9)
    return m["processed"] / busy


def ds2_parallelism(flow, metrics: dict[str, dict], target_rate: float,
                    *, target_busyness: float = 0.8,
                    max_parallelism: int = 64,
                    max_scale_factor: float = 3.0) -> dict[str, int]:
    """One DS2 step: {op: new parallelism}.  Sources/sinks keep p (paper §5:
    sources are injectors, sinks have fixed p=1 and are never a bottleneck).

    ``max_scale_factor`` clamps per-step growth (the Flink operator's
    scale-up.max-factor): per-task capacity estimates made under memory
    pressure improve after each scale-out, which is why DS2 "typically
    requires several reconfiguration steps" (§4).
    """
    topo = flow.topo_order()
    sources = set(flow.sources())
    sinks = set(flow.sinks())
    # propagate true input rates at the target
    true_in: dict[str, float] = {}
    true_out: dict[str, float] = {}
    for name in topo:
        m = metrics[name]
        if name in sources:
            true_in[name] = target_rate
            true_out[name] = target_rate
            continue
        rate_in = sum(true_out[u] for u in flow.upstream(name))
        true_in[name] = rate_in
        true_out[name] = rate_in * m["selectivity"]

    new_p: dict[str, int] = {}
    for name in topo:
        m = metrics[name]
        if name in sources or name in sinks:
            new_p[name] = m["parallelism"]
            continue
        cap = true_rate_per_task(m)
        if cap <= 0:
            new_p[name] = m["parallelism"]
            continue
        need = true_in[name] / (cap * target_busyness)
        p_cur = m["parallelism"]
        p_want = max(1, math.ceil(need))
        p_clamp = max(p_cur + 1, math.ceil(p_cur * max_scale_factor))
        new_p[name] = int(min(p_want, p_clamp, max_parallelism))
    return new_p


def should_trigger(flow, metrics: dict[str, dict], target_rate: float,
                   *, busy_high: float = 0.8, rate_slack: float = 0.98
                   ) -> bool:
    """Unmodified DS2 trigger: high busyness + backpressure, or the sources
    cannot reach the target rate."""
    sources = flow.sources()
    src_rate = sum(metrics[s]["rate_out"] for s in sources)
    if src_rate < rate_slack * target_rate:
        return True
    for name, m in metrics.items():
        if name in sources:
            continue
        if m["busyness"] > busy_high and m["backlog"] > 0:
            return True
    return False
