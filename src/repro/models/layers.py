"""Core building blocks shared by all model families.

Attention comes in two lowering-friendly flavours:

* ``chunked_attention`` — pure-jnp flash-style attention: python-unrolled over
  query chunks (so each chunk sees a *statically bounded* causal/banded KV
  range — no masked-out chunk is ever computed) with an online-softmax
  ``lax.scan`` over KV chunks inside (so peak memory is one [qc, kc] tile).
  This is the dry-run/CPU path and the oracle for the Pallas kernels.
* ``repro.kernels.flash_attn`` / ``decode_attn`` — the Pallas TPU targets.

All softmax/normalization statistics are computed in float32 regardless of
the compute dtype.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------

def rotary(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embedding.  x: [..., S, H, Dh]; positions: [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freq = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq          # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                                # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(seq: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal position embedding table [seq, dim] (f32)."""
    half = dim // 2
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Chunked flash-style attention (training / prefill)
# ---------------------------------------------------------------------------

def _kv_range(i: int, q_chunk: int, sq: int, skv: int, *, causal: bool,
              window: int | None, chunk_attn: int | None,
              kv_chunk: int) -> tuple[int, int]:
    """Static [lo, hi) KV range needed by query chunk ``i`` (python ints)."""
    q_lo = i * q_chunk
    q_hi = min((i + 1) * q_chunk, sq)
    hi = q_hi + (skv - sq) if causal else skv          # offset when skv > sq
    hi = min(max(hi, 1), skv)
    lo = 0
    if window is not None:
        lo = max(lo, q_lo + (skv - sq) - window + 1)
    if chunk_attn is not None:
        lo = max(lo, ((q_lo + (skv - sq)) // chunk_attn) * chunk_attn)
    lo = (lo // kv_chunk) * kv_chunk                   # align for clean tiles
    return lo, hi


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True,
                      window: int | None = None,
                      chunk_attn: int | None = None,
                      q_chunk: int = 512,
                      kv_chunk: int = 1024,
                      q_offset: int = 0,
                      f32_stats: bool = True) -> jax.Array:
    """Flash-style attention.

    q: [B, Hq, Sq, Dh];  k, v: [B, Hk, Skv, Dh] with Hq % Hk == 0.
    ``q_offset``: absolute position of q[0] minus absolute position of k[0]
    is ``Skv - Sq`` when causal (suffix alignment); q_offset shifts further.
    Returns [B, Hq, Sq, Dh] in q.dtype.
    """
    b, hq, sq, dh = q.shape
    hk, skv = k.shape[1], k.shape[2]
    if hq != hk:
        rep = hq // hk
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = dh ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq = cdiv(sq, q_chunk)
    pos_shift = (skv - sq) if causal else 0

    outs = []
    for i in range(nq):
        qc = min(q_chunk, sq - i * q_chunk)
        q_i = lax.slice_in_dim(q, i * q_chunk, i * q_chunk + qc, axis=2)
        q_i32 = q_i.astype(jnp.float32) * scale
        q_pos = (i * q_chunk + jnp.arange(qc) + pos_shift + q_offset)  # [qc]
        lo, hi = _kv_range(i, q_chunk, sq, skv, causal=causal, window=window,
                           chunk_attn=chunk_attn, kv_chunk=kv_chunk)
        nkv = cdiv(hi - lo, kv_chunk)
        starts = lo + jnp.arange(nkv) * kv_chunk

        def body(carry, start, q_i32=q_i32, q_pos=q_pos, qc=qc):
            m, l, acc = carry
            k_j = lax.dynamic_slice_in_dim(k, start, kv_chunk, axis=2)
            v_j = lax.dynamic_slice_in_dim(v, start, kv_chunk, axis=2)
            kv_pos = start + jnp.arange(kv_chunk)                     # [kc]
            s = jnp.einsum("bhqd,bhkd->bhqk", q_i32,
                           k_j.astype(jnp.float32),
                           preferred_element_type=jnp.float32)
            mask = kv_pos[None, :] < hi                               # edge pad
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            if chunk_attn is not None:
                mask &= (kv_pos[None, :] // chunk_attn
                         ) == (q_pos[:, None] // chunk_attn)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            # §Perf: bf16 probability tile halves the dominant HBM operand
            # of the p@v matmul (statistics m/l stay f32 either way)
            pd = jnp.float32 if f32_stats else q.dtype
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(pd), v_j.astype(pd),
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, hq, qc), NEG_INF, jnp.float32),
                jnp.zeros((b, hq, qc), jnp.float32),
                jnp.zeros((b, hq, qc, dh), jnp.float32))
        (m, l, acc), _ = lax.scan(body, init, starts)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out.astype(q.dtype))
    return jnp.concatenate(outs, axis=2) if len(outs) > 1 else outs[0]


# ---------------------------------------------------------------------------
# Decode attention (single new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_positions: jax.Array, t: jax.Array, *,
                     window: int | None = None,
                     chunk_attn: int | None = None) -> jax.Array:
    """Grouped decode attention without materializing repeated KV heads.

    q: [B, Hq, 1, Dh]; caches: [B, Hk, S, Dh]; kv_positions: [B, S] absolute
    position held by each cache slot (-1 = empty); t: current position [B] or
    scalar.  Returns [B, Hq, 1, Dh].
    """
    b, hq, _, dh = q.shape
    hk, s = k_cache.shape[1], k_cache.shape[2]
    g = hq // hk
    scale = dh ** -0.5
    qg = q.reshape(b, hk, g, dh).astype(jnp.float32) * scale
    sc = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    t = jnp.asarray(t)
    tb = t if t.ndim else jnp.broadcast_to(t, (b,))
    mask = (kv_positions >= 0) & (kv_positions <= tb[:, None])
    if window is not None:
        mask &= kv_positions > (tb[:, None] - window)
    if chunk_attn is not None:
        mask &= (kv_positions // chunk_attn) == (tb[:, None] // chunk_attn)
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    return o.reshape(b, hq, 1, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wo: jax.Array) -> jax.Array:
    dt = x.dtype
    g = jnp.einsum("...d,df->...f", x, wg.astype(dt))
    u = jnp.einsum("...d,df->...f", x, wu.astype(dt))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, wo.astype(dt))


def gelu_mlp(x: jax.Array, wi: jax.Array, bi: jax.Array,
             wo: jax.Array, bo: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, wi.astype(dt)) + bi.astype(dt))
    return jnp.einsum("...f,fd->...d", h, wo.astype(dt)) + bo.astype(dt)


# ---------------------------------------------------------------------------
# Causal depthwise conv (mamba2 / RG-LRU blocks)
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array | None = None
                  ) -> jax.Array:
    """x: [B, S, C]; w: [C, W] depthwise causal filter; returns [B, S, C]."""
    width = w.shape[-1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    s = x.shape[1]
    for i in range(width):                       # width is tiny (4): unroll
        out = out + xp[:, i:i + s].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out.astype(x.dtype)


def conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
                b: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Single decode step.  x_t: [B, C]; conv_state: [B, W-1, C]."""
    width = w.shape[-1]
    hist = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)   # [B, W, C]
    y = jnp.sum(hist.astype(jnp.float32)
                * w.T.astype(jnp.float32)[None], axis=1)
    if b is not None:
        y = y + b.astype(jnp.float32)
    new_state = hist[:, 1:] if width > 1 else conv_state
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes full [N, V] logits w/ remat)
# ---------------------------------------------------------------------------

def chunked_softmax_xent(h: jax.Array, unembed: jax.Array, labels: jax.Array,
                         weights: jax.Array, chunk: int) -> jax.Array:
    """h: [N, D] final hidden states; unembed: [D, V]; labels/weights: [N].

    Returns the sum of weighted token NLLs (caller divides by weight sum).
    Each chunk's logits are recomputed in the backward pass (jax.checkpoint).
    """
    n, d = h.shape
    chunk = min(chunk, n)
    pad = (-n) % chunk
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad))
        weights = jnp.pad(weights, (0, pad))
    nc = (n + pad) // chunk
    h = h.reshape(nc, chunk, d)
    labels = labels.reshape(nc, chunk)
    weights = weights.reshape(nc, chunk)

    @jax.checkpoint
    def body(carry, xs):
        h_c, y_c, w_c = xs
        logits = jnp.einsum("cd,dv->cv", h_c, unembed.astype(h_c.dtype)
                            ).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_c[:, None], axis=-1)[:, 0]
        return carry + jnp.sum((lse - gold) * w_c), None

    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (h, labels, weights))
    return total
