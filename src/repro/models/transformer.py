"""Decoder-only transformer (dense / MoE / VLM) and Whisper-style enc-dec.

Layers are stacked on a leading axis and executed under ``lax.scan`` (small
HLO, uniform sharding specs).  llama4-style interleaving (MoE every
``moe_period`` layers) scans over *groups* of ``moe_period`` layers whose
first ``moe_period - 1`` members are dense and last member is MoE.

Modes: ``train`` (full causal pass + chunked xent), ``prefill`` (build KV
caches, return last-token logits), ``decode`` (one token against caches).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import moe as moe_lib
from repro.models.config import ArchConfig
from repro.models.layers import (causal_conv1d, chunked_attention,
                                 chunked_softmax_xent, decode_attention,
                                 gelu_mlp, layer_norm, rms_norm, rotary,
                                 sinusoid_positions, swiglu)
from repro.models.sharding import MeshCtx

VIT_STUB_DIM = 1024     # the VLM/audio frontend stubs emit this width


# ---------------------------------------------------------------------------
# Cache geometry
# ---------------------------------------------------------------------------

def kv_cache_len(cfg: ArchConfig, seq_len: int) -> int:
    """Slots the decode KV cache needs for a context of ``seq_len``."""
    if cfg.chunk_attn is not None:
        return min(cfg.chunk_attn, seq_len)
    if cfg.window is not None:
        return min(cfg.window, seq_len)
    return seq_len


def _ring_positions(t, n_slots: int):
    """Absolute position held by each ring slot at time t (-1 if unwritten)."""
    j = jnp.arange(n_slots)
    pos = t - ((t - j) % n_slots)
    return jnp.where(pos >= 0, pos, -1)


# ---------------------------------------------------------------------------
# Attention block (pre-norm residual)
# ---------------------------------------------------------------------------

def _qkv(p, h, cfg: ArchConfig):
    b, s, _ = h.shape
    dt = h.dtype
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", h, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", h, p["wv"].astype(dt))
    q = q.reshape(b, s, cfg.padded_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def _constrain(x, mctx, spec):
    """Activation sharding constraint (no-op off-mesh / single device)."""
    if mctx is None or mctx.mesh.devices.size == 1:
        return x
    return jax.lax.with_sharding_constraint(x, mctx.sharding(spec))


def _attn_constraints(q, k, v, cfg: ArchConfig, mctx, mode: str):
    """§Perf: pin attention activation shardings so GSPMD cannot factorize
    the sharding across head_dim (which turns every score tile into a
    partial sum needing an all-reduce when num_heads % axis != 0)."""
    if mctx is None or cfg.attn_shard == "auto":
        return q, k, v
    from jax.sharding import PartitionSpec as P
    b = q.shape[0]
    dp = mctx.dp if b % mctx.dp_size == 0 else None
    if cfg.attn_shard == "heads":       # [B, H, S, Dh]: H over model (uneven
        q = _constrain(q, mctx, P(dp, "model", None, None))   # => pad, no
        k = _constrain(k, mctx, P(dp, "model", None, None))   # Dh split)
        v = _constrain(v, mctx, P(dp, "model", None, None))
    elif cfg.attn_shard == "seq" and mode != "decode":
        # context parallel: q positions over model, KV replicated
        q = _constrain(q, mctx, P(dp, None, "model", None))
        k = _constrain(k, mctx, P(dp, None, None, None))
        v = _constrain(v, mctx, P(dp, None, None, None))
    return q, k, v


def attn_block(p, x, cfg: ArchConfig, *, mode: str, positions, cache, t,
               use_rotary: bool = True, causal: bool = True,
               window: int | None = "cfg", kv_override=None, mctx=None):
    """Returns (x + attn_out, new_cache).  cache: {"k","v"} [B, K, S, Dh]."""
    if window == "cfg":
        window = cfg.window
    h = rms_norm(x, p["ln1"])
    b, s, _ = x.shape
    if kv_override is not None:                     # cross-attention
        q = jnp.einsum("bsd,dh->bsh", h, p["wq"].astype(h.dtype))
        q = q.reshape(b, s, cfg.padded_heads, cfg.head_dim)
        k, v = kv_override
    else:
        q, k, v = _qkv(p, h, cfg)
        if use_rotary:
            q = rotary(q, positions, cfg.rope_theta)
            k = rotary(k, positions, cfg.rope_theta)

    new_cache = cache
    if mode == "decode" and kv_override is None:
        n_slots = cache["k"].shape[2]
        slot = t % n_slots
        kc = lax.dynamic_update_slice_in_dim(
            cache["k"], k.transpose(0, 2, 1, 3), slot, axis=2)
        vc = lax.dynamic_update_slice_in_dim(
            cache["v"], v.transpose(0, 2, 1, 3), slot, axis=2)
        new_cache = {"k": kc, "v": vc}
        if n_slots == cache["k"].shape[2] and window is None \
                and cfg.chunk_attn is None:
            kv_pos = jnp.broadcast_to(jnp.arange(n_slots)[None], (b, n_slots))
        else:
            kv_pos = jnp.broadcast_to(_ring_positions(t, n_slots)[None],
                                      (b, n_slots))
        o = decode_attention(q.transpose(0, 2, 1, 3), kc, vc, kv_pos, t,
                             window=window, chunk_attn=cfg.chunk_attn)
    elif mode == "decode":                          # cross-attn decode
        kv_pos = jnp.broadcast_to(
            jnp.arange(k.shape[2])[None], (b, k.shape[2]))
        o = decode_attention(q.transpose(0, 2, 1, 3), k, v, kv_pos,
                             jnp.asarray(k.shape[2] - 1), window=None)
    else:
        kT = k.transpose(0, 2, 1, 3) if kv_override is None else k
        vT = v.transpose(0, 2, 1, 3) if kv_override is None else v
        qT = q.transpose(0, 2, 1, 3)
        qT, kT, vT = _attn_constraints(qT, kT, vT, cfg, mctx, mode)
        o = chunked_attention(qT, kT, vT,
                              causal=causal and kv_override is None,
                              window=window, chunk_attn=cfg.chunk_attn,
                              q_chunk=cfg.attn_q_chunk,
                              kv_chunk=cfg.attn_kv_chunk,
                              f32_stats=cfg.attn_f32_scores)
        if mode == "prefill" and kv_override is None:
            new_cache = {"k": kT, "v": vT}
    if cfg.padded_heads != cfg.num_heads:    # drop layout-padding heads:
        kvh = max(cfg.num_kv_heads, 1)       # padding is group-major so the
        gp = cfg.padded_heads // kvh         # GQA q->kv mapping is unchanged
        gr = cfg.num_heads // kvh
        o = o.reshape(b, kvh, gp, s, cfg.head_dim)[:, :, :gr].reshape(
            b, cfg.num_heads, s, cfg.head_dim)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.num_heads * cfg.head_dim)
    return x + jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(x.dtype)), new_cache


def dense_ffn_block(p, x):
    h = rms_norm(x, p["ln2"])
    return x + swiglu(h, p["wg"], p["wu"], p["wd"])


def moe_ffn_block(p, x, cfg, mctx):
    h = rms_norm(x, p["ln2"])
    return x + moe_lib.moe_ffn(p["moe"], h, cfg, mctx)


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _attn_shapes(cfg: ArchConfig, n: int) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    return {
        "ln1": (n, d),
        "wq": (n, d, cfg.padded_heads * hd),
        "wk": (n, d, cfg.num_kv_heads * hd),
        "wv": (n, d, cfg.num_kv_heads * hd),
        "wo": (n, cfg.num_heads * hd, d),
    }


def _dense_ffn_shapes(cfg: ArchConfig, n: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {"ln2": (n, d), "wg": (n, d, f), "wu": (n, d, f), "wd": (n, f, d)}


def _layer_group_shapes(cfg: ArchConfig) -> dict:
    """Shape tree for the scanned decoder stack."""
    if cfg.num_experts and cfg.moe_period > 1:
        n = cfg.num_layers // cfg.moe_period
        group: dict = {}
        for j in range(cfg.moe_period - 1):
            group[f"dense{j}"] = _attn_shapes(cfg, n) | _dense_ffn_shapes(cfg, n)
        group["moe"] = (_attn_shapes(cfg, n)
                        | {"ln2": (n, cfg.d_model),
                           "moe": moe_lib.moe_param_shapes(cfg, n)})
        return group
    n = cfg.num_layers
    if cfg.num_experts:
        return {"moe": _attn_shapes(cfg, n)
                | {"ln2": (n, cfg.d_model),
                   "moe": moe_lib.moe_param_shapes(cfg, n)}}
    return {"dense0": _attn_shapes(cfg, n) | _dense_ffn_shapes(cfg, n)}


def decoder_param_shapes(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    shapes: dict = {"embed": (cfg.padded_vocab, d),
                    "ln_f": (d,),
                    "layers": _layer_group_shapes(cfg)}
    if not cfg.tie_embeddings:
        shapes["unembed"] = (d, cfg.padded_vocab)
    if cfg.family == "vlm":
        shapes["img_proj"] = (VIT_STUB_DIM, d)
    return shapes


def _attn_specs(dp) -> dict:
    return {"ln1": P(None, None),
            "wq": P(None, dp, "model"), "wk": P(None, dp, "model"),
            "wv": P(None, dp, "model"), "wo": P(None, "model", dp)}


def _dense_ffn_specs(dp) -> dict:
    return {"ln2": P(None, None), "wg": P(None, dp, "model"),
            "wu": P(None, dp, "model"), "wd": P(None, "model", dp)}


def decoder_param_specs(cfg: ArchConfig, mctx: MeshCtx) -> dict:
    dp = mctx.dp if cfg.fsdp else None
    layers: dict = {}
    group = _layer_group_shapes(cfg)
    for name in group:
        if name.startswith("dense"):
            layers[name] = _attn_specs(dp) | _dense_ffn_specs(dp)
        else:
            layers[name] = _attn_specs(dp) | {
                "ln2": P(None, None), "moe": moe_lib.moe_param_specs(cfg, dp)}
    specs: dict = {"embed": P("model", None), "ln_f": P(None),
                   "layers": layers}
    if not cfg.tie_embeddings:
        specs["unembed"] = P(None, "model")
    if cfg.family == "vlm":
        specs["img_proj"] = P(None, None)
    return specs


def _init_from_shapes(shapes, key, dtype, scale: float = 0.02):
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, shp in zip(keys, leaves):
        if len(shp) >= 2:
            out.append((jax.random.normal(k, shp, jnp.float32) * scale
                        ).astype(dtype))
        else:                                       # norms start at 1
            out.append(jnp.ones(shp, dtype))
    return jax.tree.unflatten(treedef, out)


def init_decoder_params(cfg: ArchConfig, key: jax.Array) -> dict:
    return _init_from_shapes(decoder_param_shapes(cfg), key,
                             jnp.dtype(cfg.param_dtype))


# ---------------------------------------------------------------------------
# Stack execution
# ---------------------------------------------------------------------------

def _group_body(cfg: ArchConfig, mctx: MeshCtx, mode: str):
    """Body applied to one scanned group (1 layer, or moe_period layers)."""

    def body(x, gp, positions, gcache, t):
        new_cache = {}
        for name in sorted(gp):        # dense0..denseK then moe (sorted ok)
            p = gp[name]
            c = gcache.get(name) if gcache else None
            x, nc = attn_block(p, x, cfg, mode=mode, positions=positions,
                               cache=c, t=t, mctx=mctx)
            if name.startswith("dense"):
                x = dense_ffn_block(p, x)
            else:
                x = moe_ffn_block(p, x, cfg, mctx)
            new_cache[name] = nc
        return x, new_cache

    return body


def _run_stack(params, x, cfg: ArchConfig, mctx: MeshCtx, mode: str,
               positions, caches=None, t=None):
    body = _group_body(cfg, mctx, mode)

    def scan_fn(carry, xs):
        gp, gcache = xs
        y, nc = body(carry, gp, positions, gcache, t)
        return y, nc

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else None)
        scan_fn = jax.checkpoint(scan_fn, policy=policy,
                                 prevent_cse=False)
    if not cfg.scan_layers:     # unrolled: for roofline cost accounting
        return _unrolled(scan_fn, x, params["layers"], caches)
    if caches is None:
        x, new_caches = lax.scan(
            lambda c, gp: scan_fn(c, (gp, {k: None for k in gp})),
            x, params["layers"])
    else:
        x, new_caches = lax.scan(scan_fn, x, (params["layers"], caches))
    return x, new_caches


def scan_or_unroll(cfg: ArchConfig, fn, x, xs):
    """lax.scan, or an unrolled python loop when cfg.scan_layers=False
    (roofline accounting — see _unrolled)."""
    if cfg.scan_layers:
        return lax.scan(fn, x, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x, y = fn(x, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        return x, jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return x, None


def _unrolled(scan_fn, x, stacked, caches):
    """Python-loop execution of a stacked layer group (same math as scan).

    XLA's cost_analysis counts a while-loop body once regardless of trip
    count; the roofline tool lowers shallow *unrolled* variants so per-layer
    costs appear explicitly (EXPERIMENTS.md §Roofline methodology)."""
    n = jax.tree.leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        gp = jax.tree.map(lambda a: a[i], stacked)
        gcache = (jax.tree.map(lambda a: a[i], caches) if caches is not None
                  else {k: None for k in gp})
        x, nc = scan_fn(x, (gp, gcache))
        ys.append(nc)
    stacked_ys = jax.tree.map(lambda *a: jnp.stack(a), *ys) \
        if ys and jax.tree.leaves(ys[0]) else ys[0]
    return x, stacked_ys


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def _embed_tokens(params, tokens, cfg: ArchConfig):
    return params["embed"].astype(cfg.compute_dtype)[tokens]


def _unembed_matrix(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def _prepend_images(params, x_tok, batch, cfg: ArchConfig):
    if cfg.family != "vlm":
        return x_tok, None
    img = batch["img_emb"].astype(cfg.compute_dtype)
    img_x = jnp.einsum("bnd,dm->bnm", img,
                       params["img_proj"].astype(cfg.compute_dtype))
    return jnp.concatenate([img_x, x_tok], axis=1), img.shape[1]


# ---------------------------------------------------------------------------
# Public decoder entry points
# ---------------------------------------------------------------------------

def decoder_loss(params, batch, cfg: ArchConfig, mctx: MeshCtx) -> jax.Array:
    tokens = batch["tokens"]
    b, s_tok = tokens.shape
    x = _embed_tokens(params, tokens, cfg)
    x, n_img = _prepend_images(params, x, batch, cfg)
    s = x.shape[1]
    positions = jnp.arange(s)
    x, _ = _run_stack(params, x, cfg, mctx, "train", positions)
    x = rms_norm(x, params["ln_f"])

    # next-token prediction on the text positions only
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    weights = jnp.ones((b, s_tok), jnp.float32).at[:, -1].set(0.0)
    h_txt = x[:, n_img:] if n_img else x
    loss_sum = chunked_softmax_xent(
        h_txt.reshape(b * s_tok, -1), _unembed_matrix(params, cfg),
        labels.reshape(-1), weights.reshape(-1), cfg.loss_chunk)
    return loss_sum / jnp.maximum(jnp.sum(weights), 1.0)


def decoder_prefill(params, batch, cfg: ArchConfig, mctx: MeshCtx):
    tokens = batch["tokens"]
    x = _embed_tokens(params, tokens, cfg)
    x, n_img = _prepend_images(params, x, batch, cfg)
    positions = jnp.arange(x.shape[1])
    x, caches = _run_stack(params, x, cfg, mctx, "prefill", positions)
    x = rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        _unembed_matrix(params, cfg).astype(jnp.float32))
    return logits, caches


def decoder_decode_step(params, caches, tokens, t, cfg: ArchConfig,
                        mctx: MeshCtx):
    """tokens: [B, 1] new token ids; t: scalar absolute position."""
    x = _embed_tokens(params, tokens, cfg)
    positions = jnp.asarray(t)[None]
    x, new_caches = _run_stack(params, x, cfg, mctx, "decode", positions,
                               caches=caches, t=t)
    x = rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        _unembed_matrix(params, cfg).astype(jnp.float32))
    return logits, new_caches


def decoder_cache_shapes(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    """Abstract KV-cache tree matching the scanned stack layout."""
    group = _layer_group_shapes(cfg)
    n_slots = kv_cache_len(cfg, seq_len)
    caches = {}
    for name, shapes in group.items():
        n = shapes["ln1"][0]
        kv = (n, batch, cfg.num_kv_heads, n_slots, cfg.head_dim)
        caches[name] = {"k": kv, "v": kv}
    return caches


def kv_spec(cfg: ArchConfig, mctx: MeshCtx, n_slots: int,
            lead_dims: int = 1) -> P:
    """Pick the model-axis placement for a KV cache [*, B, K, S, Dh]:
    shard heads when they divide the axis, else the slot (sequence) dim —
    split-KV decode, FlashDecoding-style."""
    lead = (None,) * lead_dims
    tp = mctx.tp_size
    if cfg.num_kv_heads % tp == 0:
        return P(*lead, mctx.dp, "model", None, None)
    if n_slots % tp == 0:
        return P(*lead, mctx.dp, None, "model", None)
    return P(*lead, mctx.dp, None, None, None)


def decoder_cache_specs(cfg: ArchConfig, mctx: MeshCtx,
                        seq_len: int = 0) -> dict:
    group = _layer_group_shapes(cfg)
    n_slots = kv_cache_len(cfg, seq_len) if seq_len else 0
    spec = kv_spec(cfg, mctx, n_slots)
    return {name: {"k": spec, "v": spec} for name in group}


# ---------------------------------------------------------------------------
# Whisper-style encoder-decoder
# ---------------------------------------------------------------------------

def encdec_param_shapes(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    ne, nd = cfg.enc_layers, cfg.num_layers
    enc = _attn_shapes(cfg, ne) | _dense_ffn_shapes(cfg, ne)
    dec = (_attn_shapes(cfg, nd)
           | {f"x_{k}": v for k, v in _attn_shapes(cfg, nd).items()}
           | _dense_ffn_shapes(cfg, nd))
    return {"embed": (cfg.padded_vocab, d),
            "frame_proj": (VIT_STUB_DIM, d),
            "enc_layers": enc, "dec_layers": dec,
            "ln_enc": (d,), "ln_f": (d,),
            "unembed": (d, cfg.padded_vocab)}


def encdec_param_specs(cfg: ArchConfig, mctx: MeshCtx) -> dict:
    dp = mctx.dp if cfg.fsdp else None
    enc = _attn_specs(dp) | _dense_ffn_specs(dp)
    dec = (_attn_specs(dp)
           | {f"x_{k}": v for k, v in _attn_specs(dp).items()}
           | _dense_ffn_specs(dp))
    return {"embed": P("model", None), "frame_proj": P(None, None),
            "enc_layers": enc, "dec_layers": dec,
            "ln_enc": P(None), "ln_f": P(None),
            "unembed": P(None, "model")}


def init_encdec_params(cfg: ArchConfig, key: jax.Array) -> dict:
    return _init_from_shapes(encdec_param_shapes(cfg), key,
                             jnp.dtype(cfg.param_dtype))


def _encode(params, frames, cfg: ArchConfig, mctx: MeshCtx):
    x = jnp.einsum("bsd,dm->bsm", frames.astype(cfg.compute_dtype),
                   params["frame_proj"].astype(cfg.compute_dtype))
    x = x + sinusoid_positions(x.shape[1], cfg.d_model
                               ).astype(cfg.compute_dtype)[None]

    def body(c, p):
        y, _ = attn_block(p, c, cfg, mode="train", positions=None, cache=None,
                          t=None, use_rotary=False, causal=False)
        return dense_ffn_block(p, y), None

    if cfg.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = scan_or_unroll(cfg, body, x, params["enc_layers"])
    return rms_norm(x, params["ln_enc"])


def _dec_body(cfg, mctx, mode, enc_kv=None):
    def body(x, p, positions, cache, t):
        c_self = cache.get("self") if cache else None
        x, nc_self = attn_block(p, x, cfg, mode=mode, positions=positions,
                                cache=c_self, t=t, use_rotary=True)
        xp = {k[2:]: v for k, v in p.items() if k.startswith("x_")}
        if enc_kv is not None:
            kv = enc_kv
        else:
            kv = (cache["cross_k"], cache["cross_v"])
        x, _ = attn_block(xp, x, cfg, mode=mode, positions=positions,
                          cache=None, t=t, kv_override=kv)
        x = dense_ffn_block(p, x)
        return x, {"self": nc_self}
    return body


def encdec_loss(params, batch, cfg: ArchConfig, mctx: MeshCtx) -> jax.Array:
    enc_out = _encode(params, batch["frames"], cfg, mctx)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed_tokens(params, tokens, cfg)
    positions = jnp.arange(s)

    def scan_fn(c, p):
        kT = jnp.einsum("bsd,dh->bsh", enc_out,
                        p["x_wk"].astype(enc_out.dtype)
                        ).reshape(b, -1, cfg.num_kv_heads, cfg.head_dim)
        vT = jnp.einsum("bsd,dh->bsh", enc_out,
                        p["x_wv"].astype(enc_out.dtype)
                        ).reshape(b, -1, cfg.num_kv_heads, cfg.head_dim)
        body = _dec_body(cfg, mctx, "train",
                         enc_kv=(kT.transpose(0, 2, 1, 3),
                                 vT.transpose(0, 2, 1, 3)))
        y, _ = body(c, p, positions, None, None)
        return y, None

    if cfg.remat != "none":
        scan_fn = jax.checkpoint(scan_fn, prevent_cse=False)
    x, _ = scan_or_unroll(cfg, scan_fn, x, params["dec_layers"])
    x = rms_norm(x, params["ln_f"])
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    weights = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
    loss_sum = chunked_softmax_xent(
        x.reshape(b * s, -1), params["unembed"], labels.reshape(-1),
        weights.reshape(-1), cfg.loss_chunk)
    return loss_sum / jnp.maximum(jnp.sum(weights), 1.0)


def encdec_prefill(params, batch, cfg: ArchConfig, mctx: MeshCtx):
    enc_out = _encode(params, batch["frames"], cfg, mctx)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed_tokens(params, tokens, cfg)
    positions = jnp.arange(s)

    def scan_fn(c, p):
        kT = jnp.einsum("bsd,dh->bsh", enc_out, p["x_wk"].astype(enc_out.dtype)
                        ).reshape(b, -1, cfg.num_kv_heads, cfg.head_dim
                                  ).transpose(0, 2, 1, 3)
        vT = jnp.einsum("bsd,dh->bsh", enc_out, p["x_wv"].astype(enc_out.dtype)
                        ).reshape(b, -1, cfg.num_kv_heads, cfg.head_dim
                                  ).transpose(0, 2, 1, 3)
        body = _dec_body(cfg, mctx, "prefill", enc_kv=(kT, vT))
        y, nc = body(c, p, positions, None, None)
        return y, (nc["self"], {"k": kT, "v": vT})

    x, (self_c, cross_c) = scan_or_unroll(cfg, scan_fn, x,
                                          params["dec_layers"])
    x = rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        params["unembed"].astype(jnp.float32))
    caches = {"self": self_c, "cross_k": cross_c["k"], "cross_v": cross_c["v"]}
    return logits, caches


def encdec_decode_step(params, caches, tokens, t, cfg: ArchConfig,
                       mctx: MeshCtx):
    x = _embed_tokens(params, tokens, cfg)
    positions = jnp.asarray(t)[None]
    body = _dec_body(cfg, mctx, "decode")

    def scan_fn(c, xs):
        p, self_c, ck, cv = xs
        y, nc = body(c, p, positions,
                     {"self": self_c, "cross_k": ck, "cross_v": cv}, t)
        return y, nc["self"]

    x, new_self = scan_or_unroll(
        cfg, scan_fn, x, (params["dec_layers"], caches["self"],
                          caches["cross_k"], caches["cross_v"]))
    x = rms_norm(x, params["ln_f"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        params["unembed"].astype(jnp.float32))
    return logits, {"self": new_self, "cross_k": caches["cross_k"],
                    "cross_v": caches["cross_v"]}


def encdec_cache_shapes(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    nd = cfg.num_layers
    kv = (nd, batch, cfg.num_kv_heads, seq_len, cfg.head_dim)
    xkv = (nd, batch, cfg.num_kv_heads, cfg.enc_seq, cfg.head_dim)
    return {"self": {"k": kv, "v": kv}, "cross_k": xkv, "cross_v": xkv}


def encdec_cache_specs(cfg: ArchConfig, mctx: MeshCtx,
                       seq_len: int = 0) -> dict:
    spec = kv_spec(cfg, mctx, seq_len)
    xspec = kv_spec(cfg, mctx, cfg.enc_seq)
    return {"self": {"k": spec, "v": spec}, "cross_k": xspec, "cross_v": xspec}
