"""Mamba2 (SSD — state-space duality) blocks.

Train/prefill use the chunked SSD algorithm from arXiv:2405.21060 (intra-chunk
quadratic attention-like term + inter-chunk recurrence expressed as a small
chunk-level matmul).  Decode is the O(1)-state recurrent step — which is why
mamba2 runs the long_500k cell: its decode state is constant in context
length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import (causal_conv1d, chunked_softmax_xent,
                                 conv1d_step, rms_norm)
from repro.models.sharding import MeshCtx


def _segsum(x):
    """x: [..., q] -> lower-triangular pairwise cumulative sums [..., q, q]."""
    q = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    d = xc[..., :, None] - xc[..., None, :]
    i = jnp.arange(q)
    return jnp.where(i[:, None] >= i[None, :], d, -jnp.inf)


def ssd_chunked(x, a_bar, b_mat, c_mat, chunk: int):
    """SSD scan.  x: [B,S,H,Pd] (dt-premultiplied); a_bar: [B,S,H] (dt*A);
    b_mat/c_mat: [B,S,N] (single group, broadcast over heads).
    Returns y [B,S,H,Pd] and final state [B,H,Pd,N]."""
    bsz, s0, h, pd = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s0)
    pad = (-s0) % q
    if pad:     # a_bar=0 => decay 1 (state preserved); x=0 => no contribution
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_bar = jnp.pad(a_bar, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    s = s0 + pad
    nc = s // q

    xc = x.reshape(bsz, nc, q, h, pd)
    ac = a_bar.reshape(bsz, nc, q, h).transpose(0, 3, 1, 2)       # [b,h,c,q]
    bc = b_mat.reshape(bsz, nc, q, n)
    cc = c_mat.reshape(bsz, nc, q, n)
    a_cum = jnp.cumsum(ac, axis=-1)                                # [b,h,c,q]

    # 1. intra-chunk (diagonal blocks)
    l_mat = jnp.exp(_segsum(ac))                                   # [b,h,c,q,q]
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", cc, bc, l_mat, xc)

    # 2. per-chunk final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)                # [b,h,c,q]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bc, decay_states, xc)

    # 3. inter-chunk recurrence as a (c+1)x(c+1) decay matmul
    chunk_decay = jnp.exp(_segsum(jnp.pad(a_cum[..., -1],
                                          ((0, 0), (0, 0), (1, 0)))))
    states_in = jnp.concatenate(
        [jnp.zeros_like(states[:, :1]), states], axis=1)           # [b,c+1,h,p,n]
    all_states = jnp.einsum("bhzc,bchpn->bzhpn", chunk_decay, states_in)
    prev_states, final_state = all_states[:, :-1], all_states[:, -1]

    # 4. inter-chunk contribution to outputs
    out_decay = jnp.exp(a_cum)                                     # [b,h,c,q]
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cc, prev_states, out_decay)
    y = (y_diag + y_off).reshape(bsz, s, h, pd)[:, :s0]
    return y, final_state


# ---------------------------------------------------------------------------
# Block
# ---------------------------------------------------------------------------

def mamba_block(p, x, cfg: ArchConfig, *, mode: str, cache=None):
    """x: [B, S, D].  cache: {"conv": [B,W-1,Cc], "state": [B,H,Pd,N]}."""
    bsz, s, _ = x.shape
    d_in, ds, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    u = rms_norm(x, p["ln"])
    # z / xBC / dt projections are separate params so each output dim shards
    # evenly over the model axis (a fused in_proj would have a ragged width)
    z = jnp.einsum("bsd,dp->bsp", u, p["wz"].astype(u.dtype))
    xbc = jnp.einsum("bsd,dp->bsp", u, p["wxbc"].astype(u.dtype))
    dt = jnp.einsum("bsd,dp->bsp", u, p["wdt"].astype(u.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))       # [B,S,H]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                   # [H]

    new_cache = cache
    if mode == "decode":
        xbc_t, conv_state = conv1d_step(xbc[:, 0], cache["conv"],
                                        p["conv_w"], p["conv_b"])
        xbc_t = jax.nn.silu(xbc_t)
        xs = xbc_t[..., :d_in].reshape(bsz, h, pd)
        b_t = xbc_t[..., d_in:d_in + ds]
        c_t = xbc_t[..., d_in + ds:]
        dt_t = dt[:, 0]                                            # [B,H]
        a_bar = jnp.exp(dt_t * a[None])                            # [B,H]
        st = cache["state"].astype(jnp.float32)
        st = (a_bar[..., None, None] * st
              + jnp.einsum("bh,bhp,bn->bhpn", dt_t, xs.astype(jnp.float32),
                           b_t.astype(jnp.float32)))
        y = jnp.einsum("bhpn,bn->bhp", st, c_t.astype(jnp.float32))
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(bsz, 1, d_in).astype(x.dtype)
        new_cache = {"conv": conv_state, "state": st.astype(cache["state"].dtype)}
    else:
        xbc_c = jax.nn.silu(causal_conv1d(xbc, p["conv_w"], p["conv_b"]))
        xs = xbc_c[..., :d_in].reshape(bsz, s, h, pd)
        b_mat = xbc_c[..., d_in:d_in + ds]
        c_mat = xbc_c[..., d_in + ds:]
        x_bar = (xs.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
        y, final_state = ssd_chunked(x_bar, dt * a[None, None],
                                     b_mat, c_mat, cfg.ssd_chunk)
        y = y + p["D"].astype(y.dtype)[None, None, :, None] * xs.astype(y.dtype)
        y = y.reshape(bsz, s, d_in).astype(x.dtype)
        if mode == "prefill":
            w = p["conv_w"].shape[-1]
            conv_state = xbc[:, s - (w - 1):, :] if s >= w - 1 else jnp.pad(
                xbc, ((0, 0), (w - 1 - s, 0), (0, 0)))
            new_cache = {"conv": conv_state,
                         "state": final_state.astype(x.dtype)}

    y = rms_norm(y * jax.nn.silu(z if mode != "decode" else z[:, :1]),
                 p["ssm_ln"])
    return x + jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype)), \
        new_cache


# ---------------------------------------------------------------------------
# Model-level
# ---------------------------------------------------------------------------

def ssm_param_shapes(cfg: ArchConfig) -> dict:
    n, d = cfg.num_layers, cfg.d_model
    d_in, ds, g, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    conv_dim = d_in + 2 * g * ds
    layers = {
        "ln": (n, d),
        "wz": (n, d, d_in),
        "wxbc": (n, d, conv_dim),
        "wdt": (n, d, h),
        "conv_w": (n, conv_dim, cfg.conv_width),
        "conv_b": (n, conv_dim),
        "A_log": (n, h), "D": (n, h), "dt_bias": (n, h),
        "ssm_ln": (n, d_in),
        "out_proj": (n, d_in, d),
    }
    return {"embed": (cfg.padded_vocab, d), "ln_f": (d,), "layers": layers}


def ssm_param_specs(cfg: ArchConfig, mctx: MeshCtx) -> dict:
    dp = mctx.dp if cfg.fsdp else None
    layers = {
        "ln": P(None, None),
        "wz": P(None, dp, "model"),
        "wxbc": P(None, dp, "model"),
        "wdt": P(None, dp, None),            # nheads may not divide the axis
        "conv_w": P(None, "model", None),
        "conv_b": P(None, "model"),
        "A_log": P(None, None), "D": P(None, None), "dt_bias": P(None, None),
        "ssm_ln": P(None, "model"),
        "out_proj": P(None, "model", dp),
    }
    return {"embed": P("model", None), "ln_f": P(None), "layers": layers}


def _stack_scan(params, x, cfg, mctx, mode, caches):
    def scan_fn(c, xs):
        p, cache = xs
        y, nc = mamba_block(p, c, cfg, mode=mode, cache=cache)
        return y, nc

    if cfg.remat != "none" and mode == "train":
        scan_fn = jax.checkpoint(scan_fn, prevent_cse=False)
    if not cfg.scan_layers:     # unrolled (roofline accounting; see tfm.py)
        n = jax.tree.leaves(params["layers"])[0].shape[0]
        ys = []
        for i in range(n):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            c = jax.tree.map(lambda a: a[i], caches) \
                if caches is not None else None
            x, nc = scan_fn(x, (p, c))
            ys.append(nc)
        new = jax.tree.map(lambda *a: jnp.stack(a), *ys) \
            if ys and jax.tree.leaves(ys[0]) else None
        return x, new
    if caches is None:
        x, new = lax.scan(lambda c, p: scan_fn(c, (p, None)),
                          x, params["layers"])
    else:
        x, new = lax.scan(scan_fn, x, (params["layers"], caches))
    return x, new


def ssm_loss(params, batch, cfg: ArchConfig, mctx: MeshCtx):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x, _ = _stack_scan(params, x, cfg, mctx, "train", None)
    x = rms_norm(x, params["ln_f"])
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    weights = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    loss = chunked_softmax_xent(x.reshape(b * s, -1), unembed,
                                labels.reshape(-1), weights.reshape(-1),
                                cfg.loss_chunk)
    return loss / jnp.maximum(jnp.sum(weights), 1.0)


def ssm_prefill(params, batch, cfg: ArchConfig, mctx: MeshCtx):
    tokens = batch["tokens"]
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x, caches = _stack_scan(params, x, cfg, mctx, "prefill", None)
    x = rms_norm(x, params["ln_f"])
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        unembed.astype(jnp.float32))
    return logits, caches


def ssm_decode_step(params, caches, tokens, t, cfg: ArchConfig, mctx: MeshCtx):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x, new_caches = _stack_scan(params, x, cfg, mctx, "decode", caches)
    x = rms_norm(x, params["ln_f"])
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        unembed.astype(jnp.float32))
    return logits, new_caches


def ssm_cache_shapes(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    n = cfg.num_layers
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {"conv": (n, batch, cfg.conv_width - 1, conv_dim),
            "state": (n, batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state)}


def ssm_cache_specs(cfg: ArchConfig, mctx: MeshCtx, seq_len: int = 0) -> dict:
    dp = mctx.dp
    tp = mctx.tp_size
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    conv_spec = P(None, dp, None, "model") if conv_dim % tp == 0 \
        else P(None, dp, None, None)
    state_spec = P(None, dp, None, None, "model") if cfg.ssm_state % tp == 0 \
        else P(None, dp, None, None, None)
    return {"conv": conv_spec, "state": state_spec}
