"""RecurrentGemma / Griffin-style hybrid: RG-LRU recurrent blocks interleaved
with local (sliding-window, MQA) attention in the configured block pattern
(default 2 recurrent : 1 attention).

The RG-LRU recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
is evaluated with ``lax.associative_scan`` (parallel prefix) for train and
prefill — the TPU-idiomatic replacement for the sequential CUDA scan — and as
a single fused step for decode.  Decode state is O(1) in context length, so
recurrentgemma runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import (causal_conv1d, chunked_softmax_xent,
                                 conv1d_step, rms_norm)
from repro.models.sharding import MeshCtx
from repro.models import transformer as tfm

_LRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _lru_coeffs(p, xc):
    """Gate computations shared by scan and step.  xc: [..., R] (post-conv)."""
    r = jax.nn.sigmoid(jnp.einsum("...r,rk->...k", xc,
                                  p["wa"].astype(xc.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...r,rk->...k", xc,
                                  p["wi"].astype(xc.dtype)).astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xc.astype(jnp.float32))
    return a, b


def rg_lru_scan(p, xc):
    """xc: [B, S, R] -> h: [B, S, R] (f32 math, returns xc.dtype)."""
    a, b = _lru_coeffs(p, xc)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(xc.dtype)


def rg_lru_step(p, xc_t, h_prev):
    """xc_t: [B, R]; h_prev: [B, R]."""
    a, b = _lru_coeffs(p, xc_t)
    h = a * h_prev.astype(jnp.float32) + b
    return h.astype(xc_t.dtype)


def rec_block(p, x, cfg: ArchConfig, *, mode: str, cache=None):
    """Griffin recurrent mixer + gated output.  cache: {"conv","h"}."""
    u = rms_norm(x, p["ln1"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", u, p["wy"].astype(u.dtype)))
    xb = jnp.einsum("bsd,dr->bsr", u, p["wx"].astype(u.dtype))
    new_cache = cache
    if mode == "decode":
        xc_t, conv_state = conv1d_step(xb[:, 0], cache["conv"],
                                       p["conv_w"], p["conv_b"])
        h = rg_lru_step(p, xc_t, cache["h"])
        new_cache = {"conv": conv_state, "h": h}
        hs = h[:, None]
    else:
        xc = causal_conv1d(xb, p["conv_w"], p["conv_b"])
        hs = rg_lru_scan(p, xc)
        if mode == "prefill":
            w = p["conv_w"].shape[-1]
            s = xb.shape[1]
            conv_state = xb[:, s - (w - 1):, :] if s >= w - 1 else jnp.pad(
                xb, ((0, 0), (w - 1 - s, 0), (0, 0)))
            new_cache = {"conv": conv_state, "h": hs[:, -1]}
    out = jnp.einsum("bsr,rd->bsd", hs * gate, p["wo"].astype(x.dtype))
    x = x + out
    x = tfm.dense_ffn_block(p, x)
    return x, new_cache


def _attn_layer(p, x, cfg, *, mode, positions, cache, t, mctx=None):
    x, nc = tfm.attn_block(p, x, cfg, mode=mode, positions=positions,
                           cache=cache, t=t, window=cfg.window, mctx=mctx)
    x = tfm.dense_ffn_block(p, x)
    return x, nc


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _rec_shapes(cfg: ArchConfig, n: int) -> dict:
    d, r = cfg.d_model, cfg.lru_width
    return ({"ln1": (n, d), "wx": (n, d, r), "wy": (n, d, r),
             "conv_w": (n, r, cfg.conv_width), "conv_b": (n, r),
             "lam": (n, r), "wa": (n, r, r), "wi": (n, r, r),
             "wo": (n, r, d)}
            | tfm._dense_ffn_shapes(cfg, n))


def _rec_specs(dp) -> dict:
    return ({"ln1": P(None, None), "wx": P(None, dp, "model"),
             "wy": P(None, dp, "model"),
             "conv_w": P(None, "model", None), "conv_b": P(None, "model"),
             "lam": P(None, "model"), "wa": P(None, "model", None),
             "wi": P(None, "model", None), "wo": P(None, "model", dp)}
            | tfm._dense_ffn_specs(dp))


def _pattern_counts(cfg: ArchConfig) -> tuple[int, int]:
    """(full groups, leftover leading-pattern layers)."""
    plen = len(cfg.block_pattern)
    return cfg.num_layers // plen, cfg.num_layers % plen


def hybrid_param_shapes(cfg: ArchConfig) -> dict:
    g, tail = _pattern_counts(cfg)
    group = {}
    for idx, kind in enumerate(cfg.block_pattern):
        group[f"{idx}_{kind}"] = (_rec_shapes(cfg, g) if kind == "rec"
                                  else tfm._attn_shapes(cfg, g)
                                  | tfm._dense_ffn_shapes(cfg, g))
    shapes = {"embed": (cfg.padded_vocab, cfg.d_model), "ln_f": (cfg.d_model,),
              "groups": group}
    for j in range(tail):
        kind = cfg.block_pattern[j]
        shapes[f"tail{j}_{kind}"] = (
            _rec_shapes(cfg, 1) if kind == "rec"
            else tfm._attn_shapes(cfg, 1) | tfm._dense_ffn_shapes(cfg, 1))
    return shapes


def hybrid_param_specs(cfg: ArchConfig, mctx: MeshCtx) -> dict:
    dp = mctx.dp if cfg.fsdp else None
    g, tail = _pattern_counts(cfg)
    group = {}
    for idx, kind in enumerate(cfg.block_pattern):
        group[f"{idx}_{kind}"] = (_rec_specs(dp) if kind == "rec"
                                  else tfm._attn_specs(dp)
                                  | tfm._dense_ffn_specs(dp))
    specs = {"embed": P("model", None), "ln_f": P(None), "groups": group}
    for j in range(tail):
        kind = cfg.block_pattern[j]
        specs[f"tail{j}_{kind}"] = (
            _rec_specs(dp) if kind == "rec"
            else tfm._attn_specs(dp) | tfm._dense_ffn_specs(dp))
    return specs


# ---------------------------------------------------------------------------
# Stack
# ---------------------------------------------------------------------------

def _apply_block(name, p, x, cfg, mode, positions, cache, t, mctx=None):
    if name.split("_")[1] == "rec":
        return rec_block(p, x, cfg, mode=mode, cache=cache)
    return _attn_layer(p, x, cfg, mode=mode, positions=positions,
                       cache=cache, t=t, mctx=mctx)


def _run_hybrid(params, x, cfg, mctx, mode, positions, caches=None, t=None):
    names = sorted(params["groups"])

    def scan_fn(c, xs):
        gp, gcache = xs
        new = {}
        y = c
        for name in names:
            cc = gcache.get(name) if gcache else None
            y, nc = _apply_block(name, gp[name], y, cfg, mode, positions,
                                 cc, t, mctx)
            new[name] = nc
        return y, new

    if cfg.remat != "none" and mode == "train":
        scan_fn = jax.checkpoint(scan_fn, prevent_cse=False)
    if not cfg.scan_layers:     # unrolled (roofline accounting; see tfm.py)
        n = jax.tree.leaves(params["groups"])[0].shape[0]
        ys = []
        for i in range(n):
            gp = jax.tree.map(lambda a: a[i], params["groups"])
            gc = jax.tree.map(lambda a: a[i], caches["groups"]) \
                if caches is not None else {m: None for m in names}
            x, nc = scan_fn(x, (gp, gc))
            ys.append(nc)
        new_g = jax.tree.map(lambda *a: jnp.stack(a), *ys) \
            if ys and jax.tree.leaves(ys[0]) else None
        new_caches = {"groups": new_g}
    elif caches is None:
        x, new_g = lax.scan(
            lambda c, gp: scan_fn(c, (gp, {n: None for n in names})),
            x, params["groups"])
        new_caches = {"groups": new_g}
    else:
        x, new_g = lax.scan(scan_fn, x, (params["groups"], caches["groups"]))
        new_caches = {"groups": new_g}

    for key in sorted(k for k in params if k.startswith("tail")):
        p1 = jax.tree.map(lambda a: a[0], params[key])
        cc = caches.get(key) if caches else None
        x, nc = _apply_block("t_" + key.split("_")[1], p1, x, cfg, mode,
                             positions, cc, t, mctx)
        new_caches[key] = nc
    return x, new_caches


def _logits(params, x, cfg):
    unembed = params["embed"].T
    return jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                      unembed.astype(jnp.float32))


def hybrid_loss(params, batch, cfg: ArchConfig, mctx: MeshCtx):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    positions = jnp.arange(s)
    x, _ = _run_hybrid(params, x, cfg, mctx, "train", positions)
    x = rms_norm(x, params["ln_f"])
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    weights = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
    loss = chunked_softmax_xent(x.reshape(b * s, -1), params["embed"].T,
                                labels.reshape(-1), weights.reshape(-1),
                                cfg.loss_chunk)
    return loss / jnp.maximum(jnp.sum(weights), 1.0)


def hybrid_prefill(params, batch, cfg: ArchConfig, mctx: MeshCtx):
    tokens = batch["tokens"]
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    positions = jnp.arange(tokens.shape[1])
    x, caches = _run_hybrid(params, x, cfg, mctx, "prefill", positions)
    x = rms_norm(x, params["ln_f"])
    return _logits(params, x, cfg), caches


def hybrid_decode_step(params, caches, tokens, t, cfg: ArchConfig,
                       mctx: MeshCtx):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    positions = jnp.asarray(t)[None]
    x, new_caches = _run_hybrid(params, x, cfg, mctx, "decode", positions,
                                caches=caches, t=t)
    x = rms_norm(x, params["ln_f"])
    return _logits(params, x, cfg), new_caches


def hybrid_cache_shapes(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    g, tail = _pattern_counts(cfg)
    r = cfg.lru_width
    n_slots = min(cfg.window, seq_len)
    rec_c = {"conv": (g, batch, cfg.conv_width - 1, r), "h": (g, batch, r)}
    attn_c = {"k": (g, batch, cfg.num_kv_heads, n_slots, cfg.head_dim),
              "v": (g, batch, cfg.num_kv_heads, n_slots, cfg.head_dim)}
    group = {}
    for idx, kind in enumerate(cfg.block_pattern):
        group[f"{idx}_{kind}"] = rec_c if kind == "rec" else attn_c
    caches = {"groups": group}
    for j in range(tail):
        kind = cfg.block_pattern[j]
        if kind == "rec":
            caches[f"tail{j}_{kind}"] = {
                "conv": (batch, cfg.conv_width - 1, r), "h": (batch, r)}
        else:
            caches[f"tail{j}_{kind}"] = {
                "k": (batch, cfg.num_kv_heads, n_slots, cfg.head_dim),
                "v": (batch, cfg.num_kv_heads, n_slots, cfg.head_dim)}
    return caches


def hybrid_cache_specs(cfg: ArchConfig, mctx: MeshCtx,
                       seq_len: int = 0) -> dict:
    dp = mctx.dp
    tp = mctx.tp_size
    r_ax = "model" if cfg.lru_width % tp == 0 else None
    n_slots = min(cfg.window, seq_len) if seq_len else cfg.window
    rec_c = {"conv": P(None, dp, None, r_ax), "h": P(None, dp, r_ax)}
    kv = tfm.kv_spec(cfg, mctx, n_slots)
    attn_c = {"k": kv, "v": kv}
    group = {}
    for idx, kind in enumerate(cfg.block_pattern):
        group[f"{idx}_{kind}"] = rec_c if kind == "rec" else attn_c
    specs = {"groups": group}
    g, tail = _pattern_counts(cfg)
    kv_t = tfm.kv_spec(cfg, mctx, n_slots, lead_dims=0)
    for j in range(tail):
        kind = cfg.block_pattern[j]
        if kind == "rec":
            specs[f"tail{j}_{kind}"] = {"conv": P(dp, None, r_ax),
                                        "h": P(dp, r_ax)}
        else:
            specs[f"tail{j}_{kind}"] = {"k": kv_t, "v": kv_t}
    return specs


def init_hybrid_params(cfg: ArchConfig, key: jax.Array) -> dict:
    return tfm._init_from_shapes(hybrid_param_shapes(cfg), key,
                                 jnp.dtype(cfg.param_dtype))
