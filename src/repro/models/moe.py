"""Mixture-of-Experts block: sort-free capacity-bounded routing under shard_map.

Design (see DESIGN.md §6): activations are data-sharded and replicated over
the ``model`` axis; expert weights are either

* ``moe_shard="expert"`` — experts sharded over ``model`` (expert parallelism,
  llama4: 128 experts / 16 shards).  Each mesh cell routes its row's tokens to
  *its local experts only* (gather into a capacity buffer), runs the expert
  FFNs, and the per-cell partial outputs are combined with one ``psum`` over
  ``model`` — the same reduction a TP dense FFN needs, so no extra collective
  class is introduced.
* ``moe_shard="ffn"`` — every expert on every shard with its hidden dim
  TP-sharded (mixtral: 8 experts < 16 shards would waste half the axis under
  EP).  Same psum combine.

Routing is capacity-bounded with silent drops (MaxText-style "dropping" MoE);
rank-within-expert is computed with a cumsum over a [tokens, E_local] one-hot,
which never materializes a [T, E, C] dispatch tensor in the HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

try:                                   # jax >= 0.5 exports it at top level
    _shard_map_impl = jax.shard_map
    _SHMAP_CHECK_KW = "check_vma"
except AttributeError:                 # jax 0.4.x: experimental, check_rep kw
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _SHMAP_CHECK_KW = "check_rep"


def _shard_map(fn, *, mesh, in_specs, out_specs, check_vma):
    return _shard_map_impl(fn, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs,
                           **{_SHMAP_CHECK_KW: check_vma})

from repro.models.config import ArchConfig
from repro.models.layers import cdiv
from repro.models.sharding import MeshCtx


def _route_local(x, router, n_local_experts, expert_offset, cfg: ArchConfig):
    """Token->local-expert assignment with capacity bound.

    x: [N, D]; returns (buf [E_loc*C+1, D], flat_pos [N, K], gates [N, K]).
    The last buffer row is the drop bin.
    """
    n, d = x.shape
    k = cfg.top_k
    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, sel = lax.top_k(probs, k)                      # [N, K]
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    cap = max(1, cdiv(int(n * k * cfg.capacity_factor), cfg.num_experts))
    local = sel - expert_offset                           # [N, K]
    mine = (local >= 0) & (local < n_local_experts)
    local_c = jnp.where(mine, local, 0)

    # rank of each (token, k) assignment within its expert, in token order
    onehot = (jax.nn.one_hot(local_c, n_local_experts, dtype=jnp.int32)
              * mine[..., None].astype(jnp.int32))       # [N, K, E_loc]
    flat_oh = onehot.reshape(n * k, n_local_experts)
    ranks = (jnp.cumsum(flat_oh, axis=0) - flat_oh)       # exclusive cumsum
    rank = jnp.sum(ranks * flat_oh, axis=-1).reshape(n, k)

    keep = mine & (rank < cap)
    flat_pos = jnp.where(keep, local_c * cap + rank, n_local_experts * cap)

    buf = jnp.zeros((n_local_experts * cap + 1, d), x.dtype)
    xk = jnp.broadcast_to(x[:, None], (n, k, d)).reshape(n * k, d)
    buf = buf.at[flat_pos.reshape(-1)].add(xk, mode="drop")
    return buf, flat_pos, gates.astype(x.dtype), cap


def moe_ffn(params: dict, x: jax.Array, cfg: ArchConfig, mctx: MeshCtx
            ) -> jax.Array:
    """x: [B, S, D] (sharded P(dp, None, None)); returns same shape/sharding."""
    b, s, d = x.shape
    if cfg.moe_shard == "2d" and b % mctx.dp_size == 0 \
            and mctx.mesh.devices.size > 1:
        return moe_ffn_2d(params, x, cfg, mctx)
    tp = mctx.tp
    # batch=1 decode cells can't split tokens over dp — replicate instead
    dp = mctx.dp if b % mctx.dp_size == 0 else None
    ep = cfg.moe_shard == "expert"
    e = cfg.num_experts

    if ep:
        w_spec = P(tp, None, None)        # experts sharded
        sh_spec = P(None, tp)             # shared expert: TP on hidden dim
    else:
        w_spec = P(None, None, tp)        # hidden dim sharded
        sh_spec = P(None, tp)
    w_spec_out = P(tp, None, None) if ep else P(None, tp, None)

    in_specs = [P(dp, None, None), P(None, None),
                w_spec, w_spec, w_spec_out]
    args = [x, params["router"], params["wg"], params["wu"], params["wo"]]
    if cfg.shared_expert:
        in_specs += [sh_spec, sh_spec, P(tp, None)]
        args += [params["sh_wg"], params["sh_wu"], params["sh_wo"]]

    def local_fn(x_loc, router, wg, wu, wo, *shared):
        nloc = x_loc.shape[0] * x_loc.shape[1]
        xf = x_loc.reshape(nloc, d)
        if ep:
            e_loc = wg.shape[0]
            off = lax.axis_index(tp) * e_loc
        else:
            e_loc, off = e, 0
        buf, flat_pos, gates, cap = _route_local(xf, router, e_loc, off, cfg)
        buf_e = buf[:-1].reshape(e_loc, cap, d)
        h = jnp.einsum("ecd,edf->ecf", buf_e, wg.astype(buf_e.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf_e, wu.astype(buf_e.dtype))
        out_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                           wo.astype(buf_e.dtype))
        out_flat = jnp.concatenate(
            [out_e.reshape(e_loc * cap, d), jnp.zeros((1, d), out_e.dtype)], 0)
        gathered = out_flat[flat_pos.reshape(-1)].reshape(nloc, cfg.top_k, d)
        y = jnp.sum(gathered * gates[..., None], axis=1)
        if shared:
            swg, swu, swo = shared
            g = jnp.einsum("nd,df->nf", xf, swg.astype(xf.dtype))
            uu = jnp.einsum("nd,df->nf", xf, swu.astype(xf.dtype))
            y = y + jnp.einsum("nf,fd->nd", jax.nn.silu(g) * uu,
                               swo.astype(xf.dtype))
        y = lax.psum(y, tp)
        return y.reshape(x_loc.shape)

    return _shard_map(local_fn, mesh=mctx.mesh, in_specs=tuple(in_specs),
                      out_specs=P(dp, None, None), check_vma=False)(*args)


def moe_ffn_2d(params: dict, x: jax.Array, cfg: ArchConfig, mctx: MeshCtx
               ) -> jax.Array:
    """Fully-sharded expert weights (E over model x F over dp) with token
    movement instead of weight movement (§Perf cell B).

    Decode steps carry ~KBs of activations but EP+FSDP weight-gathering moves
    ~GBs of expert weights per step; here every cell all-gathers the token
    batch over dp (tiny), runs its (E_loc, F_loc) weight shard, and one psum
    over (model, dp) completes both partial dims.  Intended for serving
    (small token counts); training keeps the "expert"/"ffn" modes.
    """
    b, s, d = x.shape
    tp = mctx.tp
    dp = mctx.dp
    e, k = cfg.num_experts, cfg.top_k

    in_specs = [P(dp, None, None), P(None, None),
                P(tp, None, dp), P(tp, None, dp), P(tp, dp, None)]
    args = [x, params["router"], params["wg"], params["wu"], params["wo"]]
    if cfg.shared_expert:
        in_specs += [P(dp, tp), P(dp, tp), P(tp, None)]
        args += [params["sh_wg"], params["sh_wu"], params["sh_wo"]]

    dp_size = mctx.dp_size

    def local_fn(x_loc, router, wg, wu, wo, *shared):
        # gather the token batch over dp (tiny for decode)
        x_all = lax.all_gather(x_loc, dp, axis=0, tiled=True)  # [B, S, D]
        n = x_all.shape[0] * x_all.shape[1]
        xf = x_all.reshape(n, d)
        e_loc = wg.shape[0]
        off = lax.axis_index(tp) * e_loc
        buf, flat_pos, gates, cap = _route_local(xf, router, e_loc, off, cfg)
        buf_e = buf[:-1].reshape(e_loc, cap, d)
        h = jnp.einsum("ecd,edf->ecf", buf_e, wg.astype(buf_e.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf_e, wu.astype(buf_e.dtype))
        out_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                           wo.astype(buf_e.dtype))
        out_flat = jnp.concatenate(
            [out_e.reshape(e_loc * cap, d), jnp.zeros((1, d), out_e.dtype)], 0)
        gathered = out_flat[flat_pos.reshape(-1)].reshape(n, k, d)
        y = jnp.sum(gathered * gates[..., None], axis=1)
        if shared:
            # shared expert: D sharded over dp, F over tp; finish the dp
            # partial-sum before the nonlinearity, then pre-scale by 1/dp
            # so the joint (tp, dp) psum below stays exact
            swg, swu, swo = shared
            row = lax.axis_index(dp[0])
            for ax in dp[1:]:
                row = row * lax.axis_size(ax) + lax.axis_index(ax)
            d_loc = swg.shape[0]
            xs = lax.dynamic_slice(xf, (0, row * d_loc), (n, d_loc))
            g = lax.psum(jnp.einsum("nd,df->nf", xs, swg.astype(xs.dtype)),
                         dp)
            uu = lax.psum(jnp.einsum("nd,df->nf", xs, swu.astype(xs.dtype)),
                          dp)
            y_sh = jnp.einsum("nf,fd->nd", jax.nn.silu(g) * uu,
                              swo.astype(xs.dtype))
            y = y + y_sh / dp_size
        y = lax.psum(y, (tp,) + tuple(dp))
        # return this cell's dp slice of the token batch
        row = lax.axis_index(dp[0])
        for ax in dp[1:]:
            row = row * lax.axis_size(ax) + lax.axis_index(ax)
        b_loc = b // dp_size
        y = y.reshape(b, s, d)
        return lax.dynamic_slice(y, (row * b_loc, 0, 0), (b_loc, s, d))

    return _shard_map(local_fn, mesh=mctx.mesh, in_specs=tuple(in_specs),
                      out_specs=P(dp, None, None), check_vma=False)(*args)


def moe_param_shapes(cfg: ArchConfig, n_layers: int) -> dict:
    """Abstract shapes for one stacked MoE-FFN group ([L, ...] leaves)."""
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    shapes = {
        "router": (n_layers, d, e),
        "wg": (n_layers, e, d, f),
        "wu": (n_layers, e, d, f),
        "wo": (n_layers, e, f, d),
    }
    if cfg.shared_expert:
        shapes |= {"sh_wg": (n_layers, d, f), "sh_wu": (n_layers, d, f),
                   "sh_wo": (n_layers, f, d)}
    return shapes


def moe_param_specs(cfg: ArchConfig, dp=("data",)) -> dict:
    """PartitionSpecs for stacked MoE params (leading layer dim unsharded).

    EP mode shards experts over ``model`` AND FSDP-shards the d_model dim over
    ``dp`` (gathered per layer inside the scan, like every other weight) —
    without the dp factor a 400B MoE puts ~48 GB/chip of expert weights on
    each device.
    """
    if cfg.moe_shard == "2d":       # E over model, F over dp: no gathers
        w = P(None, "model", None, dp)
        wo = P(None, "model", dp, None)
        specs = {"router": P(None, None, None), "wg": w, "wu": w, "wo": wo}
        if cfg.shared_expert:
            specs |= {"sh_wg": P(None, dp, "model"),
                      "sh_wu": P(None, dp, "model"),
                      "sh_wo": P(None, "model", dp)}
        return specs
    ep = cfg.moe_shard == "expert"
    w = P(None, "model", dp, None) if ep else P(None, None, dp, "model")
    wo = P(None, "model", None, dp) if ep else P(None, None, "model", dp)
    specs = {"router": P(None, None, None), "wg": w, "wu": w, "wo": wo}
    if cfg.shared_expert:
        specs |= {"sh_wg": P(None, dp, "model"),
                  "sh_wu": P(None, dp, "model"),
                  "sh_wo": P(None, "model", dp)}
    return specs
