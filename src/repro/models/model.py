"""Unified model API: one entry point per (family), dispatched from ArchConfig.

Exposes abstract shapes (for the allocation-free dry-run) and concrete
init/loss/prefill/decode functions with matching PartitionSpec trees.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import hybrid, ssm, transformer as tfm
from repro.models.config import ArchConfig, ShapeConfig
from repro.models.sharding import MeshCtx
from repro.models.transformer import VIT_STUB_DIM


@dataclass(frozen=True)
class ModelFns:
    param_shapes: Callable[[ArchConfig], dict]
    param_specs: Callable[[ArchConfig, MeshCtx], dict]
    init: Callable[[ArchConfig, jax.Array], dict]
    loss: Callable[..., jax.Array]
    prefill: Callable[..., tuple]
    decode: Callable[..., tuple]
    cache_shapes: Callable[[ArchConfig, int, int], dict]
    cache_specs: Callable[[ArchConfig, MeshCtx], dict]


def get_model(cfg: ArchConfig) -> ModelFns:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return ModelFns(tfm.decoder_param_shapes, tfm.decoder_param_specs,
                        tfm.init_decoder_params, tfm.decoder_loss,
                        tfm.decoder_prefill, tfm.decoder_decode_step,
                        tfm.decoder_cache_shapes, tfm.decoder_cache_specs)
    if fam == "encdec":
        return ModelFns(tfm.encdec_param_shapes, tfm.encdec_param_specs,
                        tfm.init_encdec_params, tfm.encdec_loss,
                        tfm.encdec_prefill, tfm.encdec_decode_step,
                        tfm.encdec_cache_shapes, tfm.encdec_cache_specs)
    if fam == "ssm":
        return ModelFns(ssm.ssm_param_shapes, ssm.ssm_param_specs,
                        lambda c, k: tfm._init_from_shapes(
                            ssm.ssm_param_shapes(c), k, jnp.dtype(c.param_dtype)),
                        ssm.ssm_loss, ssm.ssm_prefill, ssm.ssm_decode_step,
                        ssm.ssm_cache_shapes, ssm.ssm_cache_specs)
    if fam == "hybrid":
        return ModelFns(hybrid.hybrid_param_shapes, hybrid.hybrid_param_specs,
                        hybrid.init_hybrid_params, hybrid.hybrid_loss,
                        hybrid.hybrid_prefill, hybrid.hybrid_decode_step,
                        hybrid.hybrid_cache_shapes, hybrid.hybrid_cache_specs)
    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct stand-ins — never allocated)
# ---------------------------------------------------------------------------

def _batch_axes(b: int, mctx: MeshCtx | None):
    if mctx is None:
        return None
    return mctx.dp if b % mctx.dp_size == 0 else None


def drop_dp_axes(specs, mctx: MeshCtx):
    """Replace data-parallel axes with None (for unshardable batch=1 cells)."""
    dpset = set(mctx.dp)

    def fix(p: P) -> P:
        ent = []
        for e in p:
            if e in dpset:
                ent.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a not in dpset)
                ent.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            else:
                ent.append(e)
        return P(*ent)

    return jax.tree.map(fix, specs, is_leaf=lambda x: isinstance(x, P))


def batch_shapes(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Abstract shapes for the data-pipeline inputs of one step."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        out: dict[str, Any] = {}
        if cfg.family == "vlm":
            out["tokens"] = ((b, s - cfg.img_tokens), jnp.int32)
            out["img_emb"] = ((b, cfg.img_tokens, VIT_STUB_DIM), jnp.float32)
        elif cfg.family == "encdec":
            out["tokens"] = ((b, s), jnp.int32)
            out["frames"] = ((b, cfg.enc_seq, VIT_STUB_DIM), jnp.float32)
        else:
            out["tokens"] = ((b, s), jnp.int32)
        return out
    return {"tokens": ((b, 1), jnp.int32)}


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mctx: MeshCtx) -> dict:
    dp = _batch_axes(shape.global_batch, mctx)
    shapes = batch_shapes(cfg, shape)
    return {k: P(*((dp,) + (None,) * (len(v[0]) - 1)))
            for k, v in shapes.items()}


def abstract_batch(cfg: ArchConfig, shape: ShapeConfig,
                   mctx: MeshCtx | None = None) -> dict:
    shapes = batch_shapes(cfg, shape)
    if mctx is None:
        return {k: jax.ShapeDtypeStruct(v[0], v[1]) for k, v in shapes.items()}
    specs = batch_specs(cfg, shape, mctx)
    return {k: jax.ShapeDtypeStruct(v[0], v[1],
                                    sharding=mctx.sharding(specs[k]))
            for k, v in shapes.items()}


def abstract_params(cfg: ArchConfig, mctx: MeshCtx | None = None):
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(cfg, jax.random.key(0)))
    if mctx is None:
        return shapes
    specs = model.param_specs(cfg, mctx)
    return jax.tree.map(
        lambda sh, sp: jax.ShapeDtypeStruct(sh.shape, sh.dtype,
                                            sharding=mctx.sharding(sp)),
        shapes, specs, is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))


def abstract_cache(cfg: ArchConfig, shape: ShapeConfig,
                   mctx: MeshCtx | None = None):
    model = get_model(cfg)
    dtype = jnp.dtype(cfg.compute_dtype)
    shapes = model.cache_shapes(cfg, shape.global_batch, shape.seq_len)
    tree = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, dtype), shapes,
                        is_leaf=lambda x: isinstance(x, tuple))
    if mctx is None:
        return tree
    specs = model.cache_specs(cfg, mctx, shape.seq_len)
    if shape.global_batch % mctx.dp_size != 0:
        specs = drop_dp_axes(specs, mctx)
    return jax.tree.map(
        lambda sh, sp: jax.ShapeDtypeStruct(sh.shape, sh.dtype,
                                            sharding=mctx.sharding(sp)),
        tree, specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
