"""Mesh context + partition-spec helpers.

The production mesh is ``("data", "model")`` single-pod or
``("pod", "data", "model")`` multi-pod; the pod axis is folded into every
data-parallel spec (gradient sync crosses pods, everything else intra-pod).
CPU tests use a (1, 1) mesh with the same axis names so one code path serves
both.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class MeshCtx:
    mesh: Mesh

    @property
    def dp(self) -> tuple[str, ...]:
        """Data-parallel axis name(s) — includes the pod axis when present."""
        names = self.mesh.axis_names
        return ("pod", "data") if "pod" in names else ("data",)

    @property
    def tp(self) -> str:
        return "model"

    @property
    def dp_size(self) -> int:
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return int(jax.tree_util.tree_reduce(
            lambda a, b: a * b, [sizes[a] for a in self.dp], 1))

    @property
    def tp_size(self) -> int:
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return sizes["model"]

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def cpu_mesh_ctx() -> MeshCtx:
    """1x1 mesh over the local device — used by smoke tests and examples."""
    dev = jax.devices()[0]
    import numpy as np
    return MeshCtx(Mesh(np.array([[dev]]), ("data", "model")))


def logical_to_sharding(tree_specs, mctx: MeshCtx):
    return jax.tree.map(lambda s: mctx.sharding(s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
