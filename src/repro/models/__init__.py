from repro.models.config import ArchConfig, ShapeConfig, SHAPES
from repro.models.model import (ModelFns, abstract_batch, abstract_cache,
                                abstract_params, get_model)
from repro.models.sharding import MeshCtx, cpu_mesh_ctx

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ModelFns", "get_model",
           "abstract_batch", "abstract_cache", "abstract_params", "MeshCtx",
           "cpu_mesh_ctx"]
