"""Architecture + input-shape configuration for the repro model zoo.

Every assigned architecture is expressed as an ``ArchConfig``; the four
assigned input shapes are ``ShapeConfig``s.  ``reduced()`` derives a tiny
same-family config used by CPU smoke tests (the full configs are only ever
lowered via the dry-run, never allocated).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- attention ---
    window: int | None = None        # sliding-window attention width (tokens)
    chunk_attn: int | None = None    # llama4 iRoPE-style chunked-local width
    rope_theta: float = 10_000.0

    # --- mixture of experts ---
    num_experts: int = 0
    top_k: int = 0
    moe_period: int = 1              # every k-th layer is MoE (1 = all layers)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    moe_shard: str = "expert"        # "expert" (EP over model axis) | "ffn" (TP)

    # --- state-space (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 256

    # --- hybrid (recurrentgemma / griffin) ---
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 0                 # frontend-stub sequence length (frames)

    # --- vision-language (internvl) ---
    img_tokens: int = 0              # frontend-stub patch-embedding count

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_dtype: str = "float32"
    tie_embeddings: bool = False

    # --- execution knobs (hillclimb surface) ---
    remat: str = "full"              # full | dots | none
    loss_chunk: int = 2048           # tokens per chunked-xent slice
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    scan_layers: bool = True
    attention_impl: str = "chunked"  # chunked (pure-jnp) | pallas (TPU target)
    # Perf-iteration knobs (see EXPERIMENTS.md §Perf)
    pad_heads_to: int = 0            # explicit head padding (0 = GSPMD implicit)
    seq_shard_decode: bool = False   # shard long-context cache over data axis
    attn_shard: str = "auto"         # auto | heads | seq — activation-sharding
                                     # constraint inside attention (§Perf):
                                     # "heads" pins H over model (uneven ok,
                                     # stops GSPMD head_dim-factorized partial
                                     # sums); "seq" shards q positions over
                                     # model with replicated KV (context-
                                     # parallel, no head-count waste)
    attn_f32_scores: bool = True     # f32 online-softmax statistics; False
                                     # keeps score tiles in bf16 (hillclimb)
    fsdp: bool = True                # False: TP-only weights (serving layout
                                     # — no per-layer weight gathers / no
                                     # activation reduces over the data axis)

    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a 128 multiple so embedding tables shard evenly
        over the 16-way model axis (Megatron-style vocab padding)."""
        return -(-self.vocab_size // 128) * 128

    @property
    def padded_heads(self) -> int:
        """Q-head count used for layout (>= num_heads).  Padding is pure
        compute-layout waste with zero semantic change: pad-head outputs are
        sliced off before the output projection and their wq slices stay
        zero (§Perf: stops GSPMD factorizing the sharding across head_dim
        when num_heads doesn't divide the model axis)."""
        return self.pad_heads_to if self.pad_heads_to else self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_swa(self) -> bool:
        return self.window is not None or self.chunk_attn is not None

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode-state archs run long_500k (see DESIGN.md §5)."""
        return self.family in ("ssm", "hybrid") or self.is_swa

    @property
    def has_decoder(self) -> bool:
        return True   # no assigned arch is encoder-only

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 4) if not self.block_pattern
            else len(self.block_pattern) + 1,
            d_model=64,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16 if self.num_heads else self.head_dim,
            loss_chunk=64,
            attn_q_chunk=32,
            attn_kv_chunk=32,
            ssd_chunk=16,
            remat="none",
        )
        if self.window is not None:
            kw["window"] = 32
        if self.chunk_attn is not None:
            kw["chunk_attn"] = 32
        if self.num_experts:
            kw["num_experts"] = 4
            kw["top_k"] = min(self.top_k, 2)
        if self.family == "ssm":
            kw["ssm_state"] = 16
            kw["ssm_headdim"] = 16
        if self.family == "hybrid":
            kw["lru_width"] = 64
            kw["num_kv_heads"] = 1
        if self.enc_layers:
            kw["enc_layers"] = 2
            kw["enc_seq"] = 16
        if self.img_tokens:
            kw["img_tokens"] = 8
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int

    def reduced(self) -> "ShapeConfig":
        return ShapeConfig(self.name + "-reduced", self.kind,
                           seq_len=min(self.seq_len, 128),
                           global_batch=min(self.global_batch, 2))


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """The assigned shape cells that run for this arch (skips per DESIGN.md)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        names.append("long_500k")
    return names
