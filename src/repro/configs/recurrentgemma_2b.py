"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1:2 ratio.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.  [arXiv:2402.19427]
Block pattern (rec, rec, attn); bounded state => long_500k applicable.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    window=2048,
    tie_embeddings=True,
)
