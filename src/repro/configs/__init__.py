"""Registry of assigned architectures (``--arch <id>``)."""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig, ShapeConfig, SHAPES, applicable_shapes

_MODULES = {
    "whisper-medium": "whisper_medium",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "mixtral-8x7b": "mixtral_8x7b",
    "llama3.2-3b": "llama3_2_3b",
    "internlm2-20b": "internlm2_20b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "minitron-4b": "minitron_4b",
    "mamba2-130m": "mamba2_130m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "internvl2-26b": "internvl2_26b",
}


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = ["list_archs", "get_config", "get_shape", "SHAPES",
           "applicable_shapes", "ArchConfig", "ShapeConfig"]
