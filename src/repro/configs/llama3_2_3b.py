"""llama3.2-3b [dense]: small llama3, tied embeddings.

28L d_model=3072 24H (kv=8) d_ff=8192 vocab=128256.  [hf:meta-llama/Llama-3.2]
Pure full attention => long_500k skipped (DESIGN.md §5).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=500_000.0,
)
