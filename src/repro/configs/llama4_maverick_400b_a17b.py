"""llama4-maverick-400b-a17b [moe]: interleaved MoE + shared expert.

48L d_model=5120 40H (kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
MoE on every 2nd layer + shared expert -> ~400B total / ~17B active.
Chunked local attention (iRoPE-style, 8192) => long_500k applicable.
bf16 params + bf16 optimizer states so train_4k fits 16 GB/chip at 256 chips
(see EXPERIMENTS.md §Dry-run).  [hf:meta-llama/Llama-4 family]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    top_k=1,
    moe_period=2,
    shared_expert=True,
    moe_shard="expert",
    chunk_attn=8192,
    param_dtype="bfloat16",
    opt_dtype="bfloat16",
)
