"""internvl2-26b [vlm]: InternViT frontend (stubbed) + InternLM2 backbone.

48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92553.  [arXiv:2404.16821]
input_specs() supplies precomputed patch embeddings (256 image tokens).
Pure full attention => long_500k skipped (DESIGN.md §5).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    img_tokens=256,
)
