"""minitron-4b [dense]: pruned nemotron, huge vocab.

32L d_model=3072 24H (kv=8) d_ff=9216 vocab=256000.  [arXiv:2407.14679]
Pure full attention => long_500k skipped (DESIGN.md §5).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
)
