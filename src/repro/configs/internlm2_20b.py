"""internlm2-20b [dense]: GQA.

48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92544.  [arXiv:2403.17297]
Pure full attention => long_500k skipped (DESIGN.md §5).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
)
