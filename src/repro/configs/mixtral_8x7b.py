"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (kv=8) d_ff=14336 vocab=32000.  [arXiv:2401.04088]
8 experts < 16-way model axis => experts replicated, expert FFN dim TP-sharded
("ffn" MoE sharding).  SWA => long_500k applicable.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    top_k=2,
    moe_shard="ffn",
    window=4096,
)
