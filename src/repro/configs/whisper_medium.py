"""whisper-medium [audio]: enc-dec, conv frontend stubbed as precomputed frames.

24L (24 enc + 24 dec) d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
[arXiv:2212.04356]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    enc_layers=24,
    enc_seq=1500,                 # post-conv mel frames (stub supplies these)
)
