from repro.train.optimizer import (AdamWConfig, adamw_update, init_opt_state,
                                   opt_state_specs)
from repro.train.train_step import (make_loss_fn, make_prefill_step,
                                    make_serve_step, make_train_step)
