"""Int8 gradient compression with error feedback (distributed-optimization
trick for cross-pod gradient sync).

Per-tensor symmetric int8 quantization; the quantization residual is carried
in an error-feedback buffer so the compression bias vanishes over steps
(Karimireddy et al., "Error Feedback Fixes SignSGD").  Used by the train step
when ``compress_grads=True``: gradients are quantized *before* the cross-pod
all-reduce (4x less ICI traffic on the pod axis) and dequantized after.

On the dry-run mesh the quantize/dequantize pair brackets the psum so the
lowered HLO carries int8 collective operands — visible in §Roofline's
collective-bytes term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, error_buf):
    """Returns (quantized tree, scales tree, new error buffer)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return q, s, (g32 - deq).astype(e.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_buf)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qs = jax.tree.unflatten(treedef, [o[0] for o in out])
    ss = jax.tree.unflatten(treedef, [o[1] for o in out])
    es = jax.tree.unflatten(treedef, [o[2] for o in out])
    return qs, ss, es


def init_error_buf(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
