"""Sharded AdamW with dtype-configurable states + optional compression hooks.

Optimizer states mirror the parameter PartitionSpecs exactly (same tree), so
m/v are FSDP+TP sharded wherever the weights are.  ``opt_dtype="bfloat16"``
halves optimizer memory for the 400B config (documented in EXPERIMENTS.md
§Dry-run memory analysis).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    opt_dtype: str = "float32"


def init_opt_state(params, ocfg: AdamWConfig):
    dt = jnp.dtype(ocfg.opt_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return {"m": zeros,
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(param_specs):
    from jax.sharding import PartitionSpec as P
    return {"m": param_specs, "v": param_specs, "step": P()}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, ocfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, ocfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    dt = jnp.dtype(ocfg.opt_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m32 = ocfg.b1 * m.astype(jnp.float32) + (1 - ocfg.b1) * g32
        v32 = ocfg.b2 * v.astype(jnp.float32) + (1 - ocfg.b2) * g32 * g32
        mhat = m32 / (1 - ocfg.b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - ocfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + ocfg.eps)
        if p.ndim >= 2:                       # decay weights, not norms/bias
            delta = delta + ocfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - ocfg.lr * delta).astype(p.dtype),
                m32.astype(dt), v32.astype(dt))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
