"""Sharded, manifest-driven checkpointing (fault tolerance substrate).

Layout:  <dir>/step_<N>/
            manifest.json      — step, config hash, tree structure, shapes
            shard_<i>.npz      — flattened leaves (split across files)
            _COMMITTED         — written last; restore ignores uncommitted dirs

Writes go to a temp dir + atomic rename, so a preemption mid-save never
corrupts the latest checkpoint.  Restore reshapes/redistributes onto the
current mesh (leaves are stored unsharded; device placement happens on the
next step's in_shardings), which is what makes elastic restart onto a
*different* device count work.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _tree_paths(tree) -> list[tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), np.asarray(leaf))
            for path, leaf in flat]


def config_hash(cfg) -> str:
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree, *, cfg=None,
         shard_mb: int = 256, keep: int = 3) -> str:
    """Atomic sharded save; prunes to the newest ``keep`` checkpoints."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = _tree_paths(tree)
    shards: list[dict[str, np.ndarray]] = [{}]
    size = 0
    limit = shard_mb * 1024 * 1024
    index = {}
    for name, arr in leaves:
        if size + arr.nbytes > limit and shards[-1]:
            shards.append({})
            size = 0
        shards[-1][name.replace("/", "_")] = arr
        index[name] = {"shard": len(shards) - 1,
                       "key": name.replace("/", "_"),
                       "shape": list(arr.shape), "dtype": str(arr.dtype)}
        size += arr.nbytes
    for i, shard in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{i}.npz"), **shard)
    manifest = {"step": step, "n_shards": len(shards), "index": index,
                "config_hash": config_hash(cfg) if cfg is not None else None}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # prune old checkpoints
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp")
                   and os.path.exists(os.path.join(ckpt_dir, d, "_COMMITTED")))
    for old in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, old))
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "_COMMITTED"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like, *, step: int | None = None, cfg=None):
    """Restore into the structure of ``tree_like``; verifies config hash."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if cfg is not None and manifest["config_hash"] is not None \
            and manifest["config_hash"] != config_hash(cfg):
        raise ValueError("checkpoint/config mismatch: "
                         f"{manifest['config_hash']} != {config_hash(cfg)}")
    shards = [np.load(os.path.join(d, f"shard_{i}.npz"))
              for i in range(manifest["n_shards"])]
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        info = manifest["index"][name]
        arr = shards[info["shard"]][info["key"]]
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), step
