"""The jitted train / prefill / serve steps for every architecture.

``make_train_step`` closes over (cfg, mctx) and returns a function
(params, opt_state, batch) -> (params, opt_state, metrics) suitable for
``jax.jit`` with explicit in/out shardings — the object the multi-pod
dry-run lowers and compiles.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, ShapeConfig
from repro.models.model import get_model
from repro.models.sharding import MeshCtx
from repro.train.optimizer import AdamWConfig, adamw_update


def make_loss_fn(cfg: ArchConfig, mctx: MeshCtx):
    model = get_model(cfg)

    def loss_fn(params, batch):
        return model.loss(params, batch, cfg, mctx)

    return loss_fn


def make_train_step(cfg: ArchConfig, mctx: MeshCtx,
                    ocfg: AdamWConfig | None = None,
                    microbatch: int = 1):
    """Returns train_step(params, opt_state, batch)."""
    ocfg = ocfg or AdamWConfig(opt_dtype=cfg.opt_dtype)
    loss_fn = make_loss_fn(cfg, mctx)

    def train_step(params, opt_state, batch):
        if microbatch > 1:
            def micro(i, acc):
                mb = jax.tree.map(
                    lambda x: x.reshape(microbatch, -1, *x.shape[1:])[i], batch)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (acc[0] + l,
                        jax.tree.map(lambda a, b: a + b, acc[1], g))
            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            loss, grads = jax.lax.fori_loop(0, microbatch, micro, zero)
            loss = loss / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, ocfg)
        return new_params, new_opt, {"loss": loss, **om}

    return train_step


def make_prefill_step(cfg: ArchConfig, mctx: MeshCtx):
    model = get_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch, cfg, mctx)

    return prefill_step


def make_serve_step(cfg: ArchConfig, mctx: MeshCtx):
    """One decode step: new token against the KV/state cache."""
    model = get_model(cfg)

    def serve_step(params, caches, tokens, t):
        return model.decode(params, caches, tokens, t, cfg, mctx)

    return serve_step
