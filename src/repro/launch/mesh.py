"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single device.
"""
from __future__ import annotations

import jax

from repro.models.sharding import MeshCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def production_mesh_ctx(*, multi_pod: bool = False) -> MeshCtx:
    return MeshCtx(make_production_mesh(multi_pod=multi_pod))


# --- TPU v5e hardware model (roofline constants; see EXPERIMENTS.md) ------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
