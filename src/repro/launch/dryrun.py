import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines — jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes, with no array allocation (ShapeDtypeStruct inputs).
(No ``from __future__ import annotations`` here: the XLA_FLAGS lines above
must stay the first statements in the file.)

For each cell this prints/records:
  * compiled.memory_analysis()  — proves the step fits per-chip HBM
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * collective-bytes parsed from the stablehlo/HLO text (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import applicable_shapes, get_config, get_shape, list_archs
from repro.launch.mesh import make_production_mesh
from repro.models.config import ArchConfig, ShapeConfig
from repro.models.model import (abstract_batch, abstract_cache,
                                abstract_params, batch_specs, drop_dp_axes,
                                get_model)
from repro.models.sharding import MeshCtx
from repro.train.optimizer import AdamWConfig, init_opt_state, opt_state_specs
from repro.train.train_step import (make_prefill_step, make_serve_step,
                                    make_train_step)

_COLL_RE = re.compile(
    r"\"(all-gather(?:-start)?|all-reduce(?:-start)?|reduce-scatter"
    r"|all-to-all|collective-permute(?:-start)?)"
    r"[^\"]*\"[^f]*?((?:f32|f16|bf16|f64|s32|s8|u32|u8|pred|s64|u64)"
    r"\[[0-9,]*\])", re.S)

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "f16": 2, "bf16": 2, "s8": 1, "u8": 1, "pred": 1}


def collective_bytes_from_text(hlo: str) -> dict:
    """Sum output-shape bytes of every collective op in compiled HLO text."""
    out: dict[str, float] = {}
    for line in hlo.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
                     r"\(?((?:f32|f16|bf16|f64|s32|s8|u32|u8|pred|s64|u64)"
                     r"\[[0-9,]*\])", s)
        if not m:
            continue
        op = None
        for name in ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute"):
            if re.search(rf"=\s*\(?[\w\[\],\s{{}}]*\)?\s*{name}(-start)?\(",
                         s):
                op = name
                break
        if op is None:
            continue
        total = 0
        for tm in re.finditer(r"((?:f32|f16|bf16|f64|s32|s8|u32|u8|pred|s64"
                              r"|u64))\[([0-9,]*)\]", s.split("(")[0] + "("
                              + m.group(1)):
            dt, dims = tm.group(1), tm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
            break                                   # first shape = output
        out[op] = out.get(op, 0) + total
    return out


def _shard_tree(mctx: MeshCtx, specs):
    from jax.sharding import PartitionSpec as P
    return jax.tree.map(lambda s: mctx.sharding(s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mctx: MeshCtx):
    """Build the jitted step for one cell and return (lowered, meta)."""
    from jax.sharding import PartitionSpec as P
    model = get_model(cfg)
    params = abstract_params(cfg, mctx)
    pspecs = model.param_specs(cfg, mctx)
    dp_ok = shape.global_batch % mctx.dp_size == 0
    dp = mctx.dp if dp_ok else None

    if shape.kind == "train":
        ocfg = AdamWConfig(opt_dtype=cfg.opt_dtype)
        opt = jax.eval_shape(lambda: init_opt_state(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params), ocfg))
        ospecs = opt_state_specs(pspecs)
        opt = jax.tree.map(
            lambda sh, sp: jax.ShapeDtypeStruct(sh.shape, sh.dtype,
                                                sharding=mctx.sharding(sp)),
            opt, ospecs,
            is_leaf=lambda x: isinstance(
                x, (jax.ShapeDtypeStruct, jax.sharding.PartitionSpec)))
        batch = abstract_batch(cfg, shape, mctx)
        step = make_train_step(cfg, mctx, ocfg)
        out_sh = (_shard_tree(mctx, pspecs), _shard_tree(mctx, ospecs),
                  {"loss": mctx.sharding(P()),
                   "grad_norm": mctx.sharding(P())})
        jitted = jax.jit(step, donate_argnums=(0, 1), out_shardings=out_sh)
        return jitted.lower(params, opt, batch), "train_step"

    cspecs = model.cache_specs(cfg, mctx, shape.seq_len)
    if not dp_ok:
        cspecs = drop_dp_axes(cspecs, mctx)
    logits_sh = mctx.sharding(P(dp, "model"))

    if shape.kind == "prefill":
        batch = abstract_batch(cfg, shape, mctx)
        step = make_prefill_step(cfg, mctx)
        jitted = jax.jit(step,
                         out_shardings=(logits_sh, _shard_tree(mctx, cspecs)))
        return jitted.lower(params, batch), "prefill_step"

    # decode
    caches = abstract_cache(cfg, shape, mctx)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                  sharding=mctx.sharding(P(dp, None)))
    t = jax.ShapeDtypeStruct((), jnp.int32)
    step = make_serve_step(cfg, mctx)
    jitted = jax.jit(step, donate_argnums=(1,),
                     out_shardings=(logits_sh, _shard_tree(mctx, cspecs)))
    return jitted.lower(params, caches, tokens, t), "serve_step"


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mctx = MeshCtx(mesh)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "step": None, "ok": False}
    t0 = time.time()
    try:
        with mesh:
            lowered, step_name = lower_cell(cfg, shape, mctx)
            rec["step"] = step_name
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        coll = collective_bytes_from_text(hlo)
        n_dev = mesh.devices.size
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1),
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            peak_bytes_per_device=int(
                getattr(mem, "peak_memory_in_bytes", 0)),
            collective_bytes=coll,
            collective_bytes_total=float(sum(coll.values())),
            n_devices=n_dev,
        )
        if verbose:
            print(f"[ok] {arch} x {shape_name} ({rec['mesh']}, {step_name}) "
                  f"lower={rec['lower_s']}s compile={rec['compile_s']}s")
            print(f"     memory_analysis: args={rec['argument_bytes']:,} "
                  f"out={rec['output_bytes']:,} temp={rec['temp_bytes']:,} "
                  f"peak/dev={rec['peak_bytes_per_device']:,}")
            print(f"     cost_analysis: flops={rec['flops']:.3e} "
                  f"bytes={rec['bytes_accessed']:.3e}")
            print(f"     collectives: { {k: f'{v:.3e}' for k, v in coll.items()} }")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} ({rec['mesh']}): "
                  f"{rec['error'][:400]}")
            traceback.print_exc()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in list_archs():
            for sh in applicable_shapes(get_config(arch)):
                cells.append((arch, sh))
    else:
        if not args.arch or not args.shape:
            ap.error("need --arch and --shape (or --all)")
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    records = []
    for arch, sh in cells:
        for mp in meshes:
            records.append(run_cell(arch, sh, multi_pod=mp))
    n_ok = sum(r["ok"] for r in records)
    print(f"\n{n_ok}/{len(records)} cells passed")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    if n_ok != len(records):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
