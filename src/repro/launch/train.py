"""Training driver: any assigned arch, with checkpoint/restart fault
tolerance and elastic restart.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
      --steps 50 --ckpt-dir /tmp/ckpt [--resume auto] [--fail-at 20]

``--fail-at N`` injects a simulated preemption at step N (process keeps
running, the restart path is exercised in-process: restore from the last
committed checkpoint and continue).  ``--microbatch`` enables gradient
accumulation; ``--compress-grads`` int8+error-feedback gradient compression.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import abstract_params, cpu_mesh_ctx, get_model
from repro.models.sharding import MeshCtx
from repro.train import checkpoint as ckpt_lib
from repro.train.grad_compress import (compress_with_feedback,
                                       dequantize_int8, init_error_buf)
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.train.train_step import make_loss_fn


def synth_batch(cfg, batch: int, seq: int, key) -> dict:
    """Deterministic synthetic LM data (self-contained data pipeline)."""
    k1, k2 = jax.random.split(key)
    out = {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        out["tokens"] = out["tokens"][:, :seq - cfg.img_tokens]
        out["img_emb"] = jax.random.normal(k2, (batch, cfg.img_tokens, 1024))
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(k2, (batch, cfg.enc_seq, 1024))
    return out


def train(arch: str, *, reduced: bool = True, steps: int = 20,
          batch: int = 2, seq: int = 128, ckpt_dir: str | None = None,
          ckpt_every: int = 10, resume: str = "no", fail_at: int | None = None,
          microbatch: int = 1, compress_grads: bool = False,
          lr: float = 1e-3, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mctx = cpu_mesh_ctx()
    model = get_model(cfg)
    ocfg = AdamWConfig(lr=lr, opt_dtype=cfg.opt_dtype)
    loss_fn = make_loss_fn(cfg, mctx)

    params = model.init(cfg, jax.random.key(0))
    opt_state = init_opt_state(params, ocfg)
    err_buf = init_error_buf(params) if compress_grads else None
    start = 0

    if resume == "auto" and ckpt_dir and ckpt_lib.latest_step(ckpt_dir) is not None:
        (params, opt_state), start = ckpt_lib.restore(
            ckpt_dir, (params, opt_state), cfg=cfg)
        if verbose:
            print(f"[train] resumed from step {start}")

    @jax.jit
    def step_fn(params, opt_state, batch_data, err):
        if microbatch > 1:
            def micro(i, acc):
                mb = jax.tree.map(
                    lambda x: x.reshape(microbatch, -1, *x.shape[1:])[i],
                    batch_data)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g))
            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            loss, grads = jax.lax.fori_loop(0, microbatch, micro, zero)
            loss = loss / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch_data)
        new_err = err
        if err is not None:   # int8 compression + error feedback before sync
            qs, ss, new_err = compress_with_feedback(grads, err)
            grads = jax.tree.map(dequantize_int8, qs, ss)
        p2, o2, m = adamw_update(params, grads, opt_state, ocfg)
        return p2, o2, m, new_err, loss

    losses = []
    restarts = 0
    i = start
    t0 = time.time()
    while i < steps:
        try:
            if fail_at is not None and i == fail_at:
                fail_at = None          # fail exactly once
                raise RuntimeError("injected node failure")
            data = synth_batch(cfg, batch, seq, jax.random.key(1000 + i))
            params, opt_state, metrics, err_buf, loss = step_fn(
                params, opt_state, data, err_buf)
            losses.append(float(loss))
            i += 1
            if ckpt_dir and i % ckpt_every == 0:
                ckpt_lib.save(ckpt_dir, i, (params, opt_state), cfg=cfg)
            if verbose and i % max(1, steps // 10) == 0:
                print(f"[train] step {i}: loss={float(loss):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
        except RuntimeError as e:
            if "injected" not in str(e):
                raise
            restarts += 1
            if verbose:
                print(f"[train] {e} at step {i} — restarting from checkpoint")
            if ckpt_dir and ckpt_lib.latest_step(ckpt_dir) is not None:
                (params, opt_state), i = ckpt_lib.restore(
                    ckpt_dir, (params, opt_state), cfg=cfg)
            else:                        # no checkpoint yet: cold restart
                params = model.init(cfg, jax.random.key(0))
                opt_state = init_opt_state(params, ocfg)
                i = 0
    wall = time.time() - t0
    result = {"arch": arch, "steps": steps, "final_loss": losses[-1],
              "first_loss": losses[0], "restarts": restarts,
              "wall_s": round(wall, 1)}
    if verbose:
        print(f"[train] done: {result}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", default="no", choices=["no", "auto"])
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    train(args.arch, reduced=args.reduced, steps=args.steps,
          batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
          ckpt_every=args.ckpt_every, resume=args.resume,
          fail_at=args.fail_at, microbatch=args.microbatch,
          compress_grads=args.compress_grads)


if __name__ == "__main__":
    main()
