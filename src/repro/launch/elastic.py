"""Elastic mesh derivation: rebuild the largest usable (data, model) mesh
from whatever devices survive a failure, and restart from the latest
checkpoint (checkpoints store unsharded leaves, so restore onto a different
device count is just new in_shardings — see train/checkpoint.py).

Policy: keep the model axis at the largest power-of-two that still fits the
per-device memory budget (TP degree is dictated by model size, not device
count), and give every remaining device to the data axis.  Stragglers /
partial pods therefore cost data-parallel throughput, never correctness.
"""
from __future__ import annotations

import jax

from repro.models.sharding import MeshCtx


def derive_mesh(n_devices: int, *, model_parallel: int = 16,
                devices=None) -> MeshCtx:
    """Largest (data, model) mesh from ``n_devices`` survivors."""
    devices = devices if devices is not None else jax.devices()[:n_devices]
    tp = min(model_parallel, len(devices))
    while len(devices) % tp:
        tp //= 2
    dp = len(devices) // tp
    import numpy as np
    grid = np.array(devices[:dp * tp]).reshape(dp, tp)
    return MeshCtx(jax.sharding.Mesh(grid, ("data", "model")))


def elastic_restart_plan(old_devices: int, surviving: int,
                         *, model_parallel: int = 16) -> dict:
    """What a controller does on failure: derive the new mesh, report the
    throughput hit, and point at the restore path."""
    tp = min(model_parallel, surviving)
    while surviving % tp:
        tp //= 2
    dp = surviving // tp
    used = dp * tp
    return {
        "surviving": surviving,
        "mesh": (dp, tp),
        "devices_used": used,
        "devices_idle": surviving - used,
        "throughput_fraction": used / old_devices,
        "action": "restore latest committed checkpoint with new in_shardings",
    }
