"""Serving driver: batched decode with a KV cache on a reduced config (CPU)
or abstract lowering of the full config (TPU target).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
      --requests 4 --prompt-len 32 --decode 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import cpu_mesh_ctx, get_model
from repro.models.transformer import VIT_STUB_DIM


def serve(arch: str, *, requests: int = 4, prompt_len: int = 32,
          decode: int = 16, reduced: bool = True, verbose: bool = True
          ) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mctx = cpu_mesh_ctx()
    model = get_model(cfg)
    params = model.init(cfg, jax.random.key(0))

    key = jax.random.key(7)
    toks = jax.random.randint(key, (requests, prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["img_emb"] = jax.random.normal(
            key, (requests, cfg.img_tokens, VIT_STUB_DIM))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (requests, cfg.enc_seq, VIT_STUB_DIM))

    t0 = time.time()
    logits, caches = model.prefill(params, batch, cfg, mctx)
    # grow KV caches so decode can append (prefill returns exactly S slots)
    from jax.tree_util import DictKey, tree_map_with_path

    def pad_kv(c):
        def f(path, x):
            keys = [p.key for p in path if isinstance(p, DictKey)]
            if keys and keys[-1] in ("k", "v"):
                pad = [(0, 0)] * x.ndim
                pad[-2] = (0, decode)
                return jnp.pad(x, pad)
            return x
        return tree_map_with_path(f, c)

    caches = pad_kv(caches)
    decode_fn = jax.jit(
        lambda p, c, tok, t: model.decode(p, c, tok, t, cfg, mctx),
        donate_argnums=(1,))
    out_tokens = [jnp.argmax(logits, -1)]
    for i in range(decode):
        tok = out_tokens[-1][:, None]
        logits, caches = decode_fn(params, caches, tok,
                                   jnp.int32(prompt_len + i))
        out_tokens.append(jnp.argmax(logits, -1))
    wall = time.time() - t0
    gen = jnp.stack(out_tokens, 1)
    result = {"arch": arch, "requests": requests,
              "generated": decode + 1,
              "tokens_per_s": round(requests * (decode + 1) / wall, 1),
              "wall_s": round(wall, 2),
              "sample": [int(x) for x in gen[0][:8]]}
    if verbose:
        print(f"[serve] {result}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode", type=int, default=16)
    args = ap.parse_args()
    serve(args.arch, requests=args.requests, prompt_len=args.prompt_len,
          decode=args.decode)


if __name__ == "__main__":
    main()
