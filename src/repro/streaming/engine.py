"""Discrete-time streaming executor.

Events are *really processed* (real LSM state, real vectorized operator
compute); only wall-clock is modeled: each task has a per-tick time budget
and each processed chunk charges ``events x cpu_cost + measured state
latency`` against it (DESIGN.md §3 — this container has neither a TPU nor
the paper's SSD testbed, so capacity comes from the calibrated service-time
model over real executed work).

Mechanics faithful to Flink/the paper:
  * hash partitioning of keyed streams onto an operator's tasks,
  * bounded inter-op queues -> backpressure (upstream blocks when a
    downstream task queue is full),
  * busyness = fraction of the tick spent processing (DS2's trigger metric),
  * θ / τ read from each task's LSM metrics (Justin's trigger metrics),
  * epoch-barrier snapshots + restore (fault tolerance),
  * reconfiguration with state re-partitioning (scale out/in) and state
    backend resize (scale up/down),
  * straggler mitigation: queue re-balancing for stateless tasks; slowdown
    injection for tests.

Fast-path invariants (the coalesced processing path MUST preserve these —
they are what the golden-trace regression test pins down):

  * **Budget semantics.**  A task keeps processing while its per-tick time
    budget is positive; events left unprocessed stay queued so backlog and
    backpressure build exactly as before.  Coalescing only changes the
    *granularity*: instead of fixed 2048-event chunks, each ``op.process``
    call takes ``budget / cost_per_event`` events sized by a per-task cost
    estimate measured from the previous call (first call after (re)start is
    one chunk, to calibrate).  Overshoot past the budget is bounded by the
    estimate drift, as the chunked path's was bounded by one chunk cost.
  * **Charge model.**  Cost per call is still ``events x cpu_cost_us +
    measured state-latency delta``, scaled by the straggler slowdown.  The
    state-latency delta is read from O(1) scalar metric counters
    (``LSMMetrics.counters()``) — no dict snapshots on the hot path.
  * **Ordering.**  Events are processed in queue order; a partially-taken
    batch's remainder returns to the queue head.  Per-tick topological op
    order and intra-op task order are unchanged.
  * **Backpressure.**  ``_downstream_room`` is evaluated once per op per
    tick (as before) but from incrementally-maintained over-capacity
    counters rather than a scan of every downstream task queue.
  * **State visibility.**  Within one coalesced batch an operator sees its
    own writes exactly as it did within one chunk; pairs that formerly
    matched *across* chunks of the same tick may now fall in one call
    (joins resolve them in the probe direction that stored first).  This
    shifts per-window selectivity by O(chunk/tick_events) but leaves rate,
    busyness, θ and τ statistics — and therefore DS2/Justin decisions —
    unchanged on the golden traces.

Paper-symbol map (what ``collect()`` hands the policies):

=============  ==========================================================
paper          here
=============  ==========================================================
busyness       ``busy_s / task_time_s`` per window — DS2's signal (§2.2)
θ (theta)      ``1 - level_probes/reads``: the fraction of state reads
               served without probing an on-"disk" LSM level (memtable +
               block cache hits + bloom-filtered negatives) — Justin's
               cache-hit-rate signal (§4.2); ``None`` for operators that
               did no reads this window
τ (tau_ms)     ``latency_ms / (reads+writes)``: mean state-access latency
               measured by the LSM store — Justin's latency signal (§4.2)
memory ladder  ``level_mb(level)`` = 158·2^level MB of managed memory per
               task (§5's base grant); ``memory_level=None`` is ⊥, the
               no-managed-memory grant for stateless operators; enacting
               a new level goes through ``reconfigure`` → the state
               backend ``resize`` (scale up/down) with a cold cache — the
               stabilization period §5 describes
C^t            ``reconfigure(new_config)`` applies the controller's
               per-operator ``(parallelism, memory_level)``: parallelism
               changes re-partition state by key hash, level changes
               resize the backend
=============  ==========================================================
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.streaming.events import EventBatch, hash_partition
from repro.streaming.graph import Dataflow
from repro.streaming.operators import (JoinOp, Operator, SessionWindowOp,
                                       SinkOp, SourceOp, WindowAggOp)

BASE_MEM_MB = 158.0                  # default managed memory per slot (§5)


def level_mb(level: int | None, base_mb: float = BASE_MEM_MB) -> float:
    """Justin memory levels: level x doubles the base grant; ⊥ -> 0."""
    return 0.0 if level is None else base_mb * (2 ** level)


def state_partition_keys(op: Operator, state_keys: np.ndarray) -> np.ndarray:
    """Recover the event key a state entry belongs to (for re-partitioning)."""
    if isinstance(op, WindowAggOp):
        return state_keys // np.int64(1 << 20)
    if isinstance(op, JoinOp):
        k = state_keys
        if op.window_s is not None:
            k = k // np.int64(1 << 16)
        return k // np.int64(4)
    return state_keys


def _partition_groups(part: np.ndarray, p: int):
    """Yield per-partition index arrays in one O(n log n) pass instead of p
    boolean-mask scans.  The stable sort preserves the original relative
    order within each partition (so downstream consumers see the exact
    sequences the masked path produced)."""
    order = np.argsort(part, kind="stable")
    bounds = np.searchsorted(part[order], np.arange(p + 1))
    for i in range(p):
        yield order[bounds[i]:bounds[i + 1]]


@dataclass
class TaskRuntime:
    queue: deque = field(default_factory=deque)
    queued_events: int = 0
    state: object = None             # LSMStore | None
    busy_s: float = 0.0
    processed: int = 0
    slowdown: float = 1.0            # straggler injection factor
    cost_per_event: float | None = None   # EWMA of measured s/event (incl.
                                          # slowdown); None until calibrated


@dataclass
class OpWindowStats:
    """Metrics over one observation window (reset on collect)."""
    in_events: int = 0
    out_events: int = 0
    processed: int = 0
    busy_s: float = 0.0
    task_time_s: float = 0.0
    blocked: bool = False
    cache_hits: int = 0
    cache_misses: int = 0
    level_probes: int = 0
    reads: int = 0
    writes: int = 0
    latency_ms: float = 0.0


class StreamEngine:
    def __init__(self, flow: Dataflow, *, tick_s: float = 1.0,
                 chunk_events: int = 2048, queue_cap_events: int = 200_000,
                 base_mem_mb: float = BASE_MEM_MB, seed: int = 0,
                 warm: bool = True):
        self.flow = flow
        self.tick_s = tick_s
        self.chunk = chunk_events
        self.queue_cap = queue_cap_events
        self.base_mem_mb = base_mem_mb
        self.rng = np.random.default_rng(seed)
        self.now = 0.0
        self.topo = flow.topo_order()
        self.tasks: dict[str, list[TaskRuntime]] = {}
        self.stats: dict[str, OpWindowStats] = {}
        self._lsm_marks: dict[tuple[str, int], tuple] = {}
        self._down = {n: flow.downstream(n) for n in self.topo}
        self._over: dict[str, int] = {}   # tasks per op with queue over cap
        self.source_emitted = 0
        self.source_target_rate = 0.0
        for name in self.topo:
            self._init_op(name, warm=warm)

    # ------------------------------------------------------------- lifecycle
    def _init_op(self, name: str, *, warm: bool,
                 snapshots: list[dict] | None = None) -> None:
        node = self.flow.nodes[name]
        p = node.parallelism
        tasks = []
        for i in range(p):
            tr = TaskRuntime()
            if node.op.stateful:
                mb = level_mb(node.memory_level, self.base_mem_mb)
                tr.state = node.op.make_state(mb, seed=i)
            tasks.append(tr)
        self.tasks[name] = tasks
        self.stats[name] = OpWindowStats()
        self._over[name] = 0
        if node.op.stateful:
            if snapshots is not None:
                self._load_state(name, snapshots)
            elif warm:
                self._warm(name)
        for i, tr in enumerate(tasks):
            if tr.state is not None:
                self._lsm_marks[(name, i)] = tr.state.metrics.counters()

    def _warm(self, name: str) -> None:
        node = self.flow.nodes[name]
        probe = node.op.make_state(1.0)
        if not hasattr(node.op, "warm_state"):
            return
        # build the full keyspace once, partition onto tasks
        tmp = node.op.make_state(64.0, seed=123)
        node.op.warm_state(tmp, self.rng)
        keys, vals = tmp.items()
        if len(keys) == 0:
            return
        self._install_partitions(name, [{"keys": keys, "vals": vals}])

    def _install_partitions(self, name: str, sources: list[dict]) -> None:
        """Distribute state snapshots onto the op's tasks.

        Replaces the old global ``np.lexsort((keys, part))`` with per-source
        work that exploits what snapshots guarantee: keys are already
        sorted.  Per source, one stable sort by destination partition keeps
        each destination slice key-sorted; per destination, the per-source
        slices are sorted runs merged by a single stable argsort over their
        concatenation (ties resolve in source order — exactly the order the
        global lexsort produced, duplicates across sources included).  Each
        task gets its merged partition as one installed run plus a cache
        prewarm over the partition in original arrival order (per-source
        ascending positions, sources in order — the order the lexsort-slice
        path fed the sampler, so the shared rng draws identically)."""
        from repro.state.lsm import get_store_impl, stable_argsort_keys
        if get_store_impl() == "legacy":
            self._install_partitions_lexsort(name, sources)
            return
        node = self.flow.nodes[name]
        p = len(self.tasks[name])
        assert p <= (1 << 16)    # partition ids must survive the uint16
        dk = [[] for _ in range(p)]          # key-sorted run fragments
        dw = [[] for _ in range(p)]
        dv = [[] for _ in range(p)]
        ak = [[] for _ in range(p)]          # arrival-order prewarm fragments
        av = [[] for _ in range(p)]
        for s in sources:
            keys = np.asarray(s["keys"], np.int64)
            if not len(keys):
                continue
            vals = np.asarray(s["vals"], np.int32)
            w = s.get("weights")
            w = np.ones(len(keys), np.int64) if w is None \
                else np.asarray(w, np.int64)
            part = hash_partition(state_partition_keys(node.op, keys), p)
            # uint16 cast => numpy radix-sorts the partition ids (p < 2^16)
            order = np.argsort(part.astype(np.uint16), kind="stable")
            bounds = np.searchsorted(part[order], np.arange(p + 1))
            for i in range(p):
                # stable sort on partition only => each slice is already in
                # original arrival order, so the install fragment doubles as
                # the prewarm fragment (no second gather)
                sl = order[bounds[i]:bounds[i + 1]]
                if not len(sl):
                    continue
                kk, vv = keys[sl], vals[sl]
                dk[i].append(kk)
                dw[i].append(w[sl])
                dv[i].append(vv)
                ak[i].append(kk)
                av[i].append(vv)
        for i in range(p):
            tr = self.tasks[name][i]
            if dk[i]:
                if len(dk[i]) == 1:
                    mk, mw, mv = dk[i][0], dw[i][0], dv[i][0]
                else:
                    mk = np.concatenate(dk[i])
                    mw = np.concatenate(dw[i])
                    mv = np.concatenate(dv[i])
                if len(mk) > 1 and (len(dk[i]) > 1
                                    or np.any(mk[1:] < mk[:-1])):
                    o = stable_argsort_keys(mk)
                    mk, mw, mv = mk[o], mw[o], mv[o]
                tr.state.install_run(mk, mv, mw)
                wk = ak[i][0] if len(ak[i]) == 1 else np.concatenate(ak[i])
                wv = av[i][0] if len(av[i]) == 1 else np.concatenate(av[i])
                tr.state.prewarm_cache(wk, wv, self.rng)
            tr.state.metrics.reset()

    def _install_partitions_lexsort(self, name: str,
                                    sources: list[dict]) -> None:
        """The pre-columnar installer (one global ``np.lexsort``), kept
        verbatim so the frozen legacy store runs in its own historical
        configuration — ``benchmarks/run.py lsm`` A/Bs the two backends
        like for like (store + install path together)."""
        node = self.flow.nodes[name]
        keys = np.concatenate([np.asarray(s["keys"], np.int64)
                               for s in sources])
        vals = np.concatenate([np.asarray(s["vals"], np.int32)
                               for s in sources])
        p = len(self.tasks[name])
        part = hash_partition(state_partition_keys(node.op, keys), p)
        srt = np.lexsort((keys, part))           # by partition, then key
        bounds = np.searchsorted(part[srt], np.arange(p + 1))
        for i in range(p):
            tr = self.tasks[name][i]
            run = srt[bounds[i]:bounds[i + 1]]
            if len(run):
                tr.state.install_run(keys[run], vals[run])
                sl = np.sort(run)                # original order
                tr.state.prewarm_cache(keys[sl], vals[sl], self.rng)
            tr.state.metrics.reset()

    # ------------------------------------------------------------ snapshots
    def snapshot(self) -> dict:
        """Epoch-barrier snapshot of all operator state + clock."""
        snap = {"now": self.now, "source_emitted": self.source_emitted,
                "ops": {}}
        for name, tasks in self.tasks.items():
            if self.flow.nodes[name].op.stateful:
                snap["ops"][name] = [t.state.snapshot() for t in tasks]
        return snap

    def restore(self, snap: dict) -> None:
        self.now = snap["now"]
        self.source_emitted = snap["source_emitted"]
        for name in self.topo:
            if name in snap["ops"]:
                self._init_op(name, warm=False, snapshots=snap["ops"][name])

    def _load_state(self, name: str, snapshots: list[dict]) -> None:
        sources = [s for s in snapshots if len(s["keys"])]
        if sources:
            self._install_partitions(name, sources)

    # -------------------------------------------------------- reconfiguration
    def reconfigure(self, new_config: dict[str, tuple[int, int | None]]
                    ) -> None:
        """Apply C^t: scale out/in re-partitions state; scale up/down resizes
        the state backend (both incur a cold cache — the stabilization period
        the paper describes)."""
        for name, (p, lvl) in new_config.items():
            node = self.flow.nodes[name]
            p_old, lvl_old = node.parallelism, node.memory_level
            lvl = lvl if node.op.stateful else None
            if p == p_old and lvl == lvl_old:
                continue
            snaps = None
            if node.op.stateful:
                snaps = [t.state.snapshot() for t in self.tasks[name]]
            node.parallelism = p
            node.memory_level = lvl
            self._init_op(name, warm=False, snapshots=snaps)

    # ---------------------------------------------------------- fault hooks
    def kill_task(self, name: str, idx: int) -> None:
        """Simulate a task/TM loss: its state and queue are gone."""
        node = self.flow.nodes[name]
        tr = TaskRuntime()
        if node.op.stateful:
            tr.state = node.op.make_state(
                level_mb(node.memory_level, self.base_mem_mb), seed=idx)
            self._lsm_marks[(name, idx)] = tr.state.metrics.counters()
        self.tasks[name][idx] = tr
        self._over[name] = sum(t.queued_events > self.queue_cap
                               for t in self.tasks[name])

    def set_straggler(self, name: str, idx: int, factor: float) -> None:
        self.tasks[name][idx].slowdown = factor

    # ------------------------------------------------------------- execution
    def _queued_delta(self, name: str, tr: TaskRuntime, delta: int) -> None:
        """Adjust a task's queued-event count, maintaining the per-op
        over-capacity counter ``_downstream_room`` reads."""
        if delta == 0:
            return
        was_over = tr.queued_events > self.queue_cap
        tr.queued_events += delta
        if (tr.queued_events > self.queue_cap) != was_over:
            self._over[name] += -1 if was_over else 1

    def _emit(self, name: str, out: EventBatch) -> None:
        if len(out) == 0:
            return
        for d in self._down[name]:
            dn = self.flow.nodes[d]
            if dn.op.stateful:
                part = hash_partition(out.key, dn.parallelism)
                for i, sl in enumerate(
                        _partition_groups(part, dn.parallelism)):
                    if len(sl):
                        sub = out.select(sl)
                        t = self.tasks[d][i]
                        t.queue.append(sub)
                        self._queued_delta(d, t, len(sub))
            else:                                   # rebalance round-robin
                # stable: quicksort's tie order diverges from index order
                # at >=17 tasks, making the rebalance assignment depend on
                # sort-algorithm internals instead of task index
                order = np.argsort([t.queued_events for t in self.tasks[d]],
                                   kind="stable")
                # same contiguous ranges np.array_split produces, as views
                q, r = divmod(len(out), dn.parallelism)
                lo = 0
                for j, i in enumerate(order):
                    hi = lo + q + (1 if j < r else 0)
                    if hi > lo:
                        sub = out.slice(lo, hi)
                        t = self.tasks[d][i]
                        t.queue.append(sub)
                        self._queued_delta(d, t, len(sub))
                    lo = hi
            self.stats[d].in_events += len(out)

    def _downstream_room(self, name: str) -> bool:
        for d in self._down[name]:
            if self._over[d]:
                return False
        return True

    def _take(self, name: str, tr: TaskRuntime, n: int) -> EventBatch:
        """Pop up to ``n`` events off the head batch of a task queue; a
        partially-consumed batch's remainder returns to the queue head.
        Deliberately does NOT coalesce across queued-batch boundaries:
        the chunked path processed each queued batch's tail fragment as
        its own (cheap) call, and those fragment ticks are part of the
        throughput profile the golden traces pin down."""
        b = tr.queue.popleft()
        if len(b) > n:
            b, rest = b.split(n)
            tr.queue.appendleft(rest)
        self._queued_delta(name, tr, -len(b))
        return b

    def _charge(self, name: str, idx: int) -> float:
        """State-latency delta (s) since the last mark for this task —
        O(1) scalar counter reads, no dict snapshot."""
        tr = self.tasks[name][idx]
        if tr.state is None:
            return 0.0
        mt = tr.state.metrics
        r0, w0, h0, m0, p0, l0 = self._lsm_marks[(name, idx)]
        st = self.stats[name]
        st.reads += mt.reads - r0
        st.writes += mt.writes - w0
        st.cache_hits += mt.cache_hits - h0
        st.cache_misses += mt.cache_misses - m0
        st.level_probes += mt.level_probes - p0
        d_lat = mt.access_latency_total_ms - l0
        st.latency_ms += d_lat
        self._lsm_marks[(name, idx)] = mt.counters()
        return d_lat / 1e3

    def run_tick(self, target_rate: float) -> None:
        self.source_target_rate = target_rate
        for name in self.topo:
            node = self.flow.nodes[name]
            op = node.op
            st = self.stats[name]
            if isinstance(op, SourceOp):
                if self._downstream_room(name):
                    n = int(target_rate * self.tick_s)
                    out = op.emit(n, self.now)
                    self.source_emitted += len(out)
                    st.in_events += len(out)
                    st.out_events += len(out)
                    st.processed += len(out)
                    # source busyness: proportional to emitted volume
                    per_task = len(out) * op.cpu_cost_us * 1e-6 \
                        / node.parallelism
                    for tr in self.tasks[name]:
                        tr.busy_s += min(per_task, self.tick_s)
                    self._emit(name, out)
                else:
                    st.blocked = True
                st.task_time_s += self.tick_s * node.parallelism
                continue

            room = self._downstream_room(name)
            for idx, tr in enumerate(self.tasks[name]):
                budget = self.tick_s
                while budget > 0 and tr.queue and room:
                    # coalesce queued batches into one vectorized process
                    # call sized by the task's measured per-event cost.
                    # Takes are chunk-quantized and never target more than
                    # a third of the tick, so the tick ends on single-chunk
                    # takes — reproducing the chunked path's last-chunk
                    # budget-overshoot profile (which DS2's capacity
                    # estimate is mildly sensitive to) at a fraction of
                    # the process-call count.
                    if tr.cost_per_event is None:    # calibration take
                        n_take = self.chunk
                    else:
                        plan = int(min(budget, self.tick_s / 3)
                                   / tr.cost_per_event)
                        n_take = max(self.chunk, plan // self.chunk
                                     * self.chunk)
                    batch = self._take(name, tr, n_take)
                    out = op.process(tr.state, batch)
                    cost = (len(batch) * op.cpu_cost_us * 1e-6
                            + self._charge(name, idx))
                    cost *= tr.slowdown
                    per = cost / len(batch)
                    tr.cost_per_event = per if tr.cost_per_event is None \
                        else 0.5 * tr.cost_per_event + 0.5 * per
                    budget -= cost
                    tr.busy_s += cost
                    tr.processed += len(batch)
                    st.processed += len(batch)
                    st.out_events += len(out)
                    self._emit(name, out)
                st.busy_s += min(self.tick_s, self.tick_s - budget) \
                    if budget < self.tick_s else self.tick_s - budget
                st.task_time_s += self.tick_s
                if not room:
                    st.blocked = True
            # straggler mitigation: re-balance stateless task queues
            if not op.stateful and node.parallelism > 1:
                self._rebalance(name)
        self.now += self.tick_s

    def _rebalance(self, name: str) -> None:
        tasks = self.tasks[name]
        loads = np.array([t.queued_events for t in tasks])
        if loads.max() > 4 * max(1, np.median(loads)) + self.chunk:
            src = tasks[int(loads.argmax())]
            dst = tasks[int(loads.argmin())]
            move = len(src.queue) // 2
            for _ in range(move):
                b = src.queue.pop()
                self._queued_delta(name, src, -len(b))
                dst.queue.append(b)
                self._queued_delta(name, dst, len(b))

    def run(self, seconds: float, target_rate: float) -> None:
        for _ in range(int(round(seconds / self.tick_s))):
            self.run_tick(target_rate)

    def run_paused(self, seconds: float, target_rate: float) -> None:
        """Reconfiguration downtime: the job is stopped, the world is not.
        Sources keep producing (they model external arrival — a Kafka
        topic does not pause for a savepoint) until backpressure blocks
        them, but NO operator processes, so arrivals accrue as queued
        backlog the resumed configuration must drain — the catch-up the
        SLO metrics measure.  Task time accrues for every operator so a
        caller collecting over the pause sees diluted busyness; on the
        controller path these stats are discarded with the stabilization
        window, and the cost surfaces through the backlog alone."""
        for _ in range(int(round(seconds / self.tick_s))):
            for name in self.topo:
                node = self.flow.nodes[name]
                st = self.stats[name]
                st.task_time_s += self.tick_s * node.parallelism
                if isinstance(node.op, SourceOp):
                    if self._downstream_room(name):
                        out = node.op.emit(int(target_rate * self.tick_s),
                                           self.now)
                        self.source_emitted += len(out)
                        st.in_events += len(out)
                        st.out_events += len(out)
                        st.processed += len(out)
                        self._emit(name, out)
                    else:
                        st.blocked = True
            self.now += self.tick_s

    # --------------------------------------------------------------- metrics
    def collect(self, reset: bool = True) -> dict[str, dict]:
        out = {}
        for name in self.topo:
            node = self.flow.nodes[name]
            st = self.stats[name]
            dur = max(st.task_time_s / max(node.parallelism, 1), 1e-9)
            sops = st.reads + st.writes
            # θ: effective in-memory hit rate — the fraction of reads that
            # avoided the slow tier (memtable + block cache + bloom-filtered
            # negatives; paper §4: "a significant fraction of accesses ...
            # used the disk").  Block-cache-only rate is kept in the LSM
            # metrics for diagnostics.
            theta = max(0.0, 1.0 - st.level_probes / st.reads) \
                if st.reads else None
            out[name] = {
                "stateful": node.op.stateful,
                "parallelism": node.parallelism,
                "memory_level": node.memory_level,
                "rate_in": st.in_events / dur,
                "rate_out": st.out_events / dur,
                "rate_processed": st.processed / dur,
                "busyness": st.busy_s / max(st.task_time_s, 1e-9),
                "busy_s": st.busy_s,
                "processed": st.processed,
                "selectivity": st.out_events / max(st.in_events, 1),
                "theta": theta,
                "tau_ms": (st.latency_ms / sops) if sops else None,
                "blocked": st.blocked,
                "backlog": sum(t.queued_events for t in self.tasks[name]),
            }
            if reset:
                self.stats[name] = OpWindowStats()
        return out
