"""Event batches: struct-of-arrays, vector-processed end to end."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

PAYLOAD_WORDS = 4       # physical payload words (logical entry = 1000 B, §3)


@dataclass
class EventBatch:
    key: np.ndarray                  # int64 [n]
    value: np.ndarray                # int32 [n, PAYLOAD_WORDS]
    ts: np.ndarray                   # float64 [n] event time, seconds
    kind: np.ndarray                 # int8  [n] event type tag

    def __len__(self) -> int:
        return len(self.key)

    @classmethod
    def empty(cls, value_words: int = PAYLOAD_WORDS) -> "EventBatch":
        return cls(np.empty(0, np.int64), np.empty((0, value_words), np.int32),
                   np.empty(0, np.float64), np.empty(0, np.int8))

    def select(self, mask_or_idx) -> "EventBatch":
        m = mask_or_idx
        if isinstance(m, np.ndarray) and m.dtype == np.bool_ and m.all():
            return self        # batches are immutable (see ``slice``)
        return EventBatch(self.key[m], self.value[m], self.ts[m],
                          self.kind[m])

    def slice(self, lo: int, hi: int) -> "EventBatch":
        """Contiguous sub-batch as O(1) numpy views (no copy).  Safe because
        operators never mutate batch arrays in place."""
        return EventBatch(self.key[lo:hi], self.value[lo:hi],
                          self.ts[lo:hi], self.kind[lo:hi])

    def split(self, n: int) -> tuple["EventBatch", "EventBatch"]:
        """(first n events, remainder) — both O(1) views."""
        return self.slice(0, n), self.slice(n, len(self.key))

    @staticmethod
    def concat(batches: list["EventBatch"]) -> "EventBatch":
        batches = [b for b in batches if len(b)]
        if not batches:
            return EventBatch.empty()
        if len(batches) == 1:      # immutable batches: no defensive copy
            return batches[0]
        return EventBatch(np.concatenate([b.key for b in batches]),
                          np.concatenate([b.value for b in batches]),
                          np.concatenate([b.ts for b in batches]),
                          np.concatenate([b.kind for b in batches]))


def hash_partition(keys: np.ndarray, p: int) -> np.ndarray:
    """Flink-style murmur-ish key partitioning onto p tasks."""
    h = keys.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    h ^= h >> np.uint64(31)
    return ((h >> np.uint64(1)).astype(np.int64) % p)
