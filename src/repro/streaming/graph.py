"""Dataflow graph: operators + edges, with per-operator parallelism and
memory level (the configuration C^t that Justin/DS2 produce)."""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.streaming.operators import Operator, SinkOp, SourceOp


@dataclass
class OpNode:
    op: Operator
    parallelism: int = 1
    memory_level: int | None = 0     # None == ⊥ (no managed memory)


@dataclass
class Dataflow:
    name: str
    nodes: dict[str, OpNode] = field(default_factory=dict)
    edges: list[tuple[str, str]] = field(default_factory=list)

    def add(self, op: Operator, parallelism: int = 1,
            memory_level: int | None = 0, after: str | None = None) -> str:
        if op.name in self.nodes:
            raise ValueError(f"duplicate operator {op.name}")
        self.nodes[op.name] = OpNode(op, parallelism,
                                     memory_level if op.stateful else None)
        if after is not None:
            self.edges.append((after, op.name))
        return op.name

    def chain(self, *ops: Operator) -> "Dataflow":
        prev = None
        for op in ops:
            self.add(op, after=prev)
            prev = op.name
        return self

    def upstream(self, name: str) -> list[str]:
        return [s for s, d in self.edges if d == name]

    def downstream(self, name: str) -> list[str]:
        return [d for s, d in self.edges if s == name]

    def topo_order(self) -> list[str]:
        indeg = {n: len(self.upstream(n)) for n in self.nodes}
        order, queue = [], [n for n, d in indeg.items() if d == 0]
        while queue:
            n = queue.pop(0)
            order.append(n)
            for d in self.downstream(n):
                indeg[d] -= 1
                if indeg[d] == 0:
                    queue.append(d)
        if len(order) != len(self.nodes):
            raise ValueError("dataflow has a cycle")
        return order

    def sources(self) -> list[str]:
        return [n for n, node in self.nodes.items()
                if isinstance(node.op, SourceOp)]

    def sinks(self) -> list[str]:
        return [n for n, node in self.nodes.items()
                if isinstance(node.op, SinkOp)]

    def config(self) -> dict[str, tuple[int, int | None]]:
        """C^t as {op: (parallelism, memory_level)}."""
        return {n: (node.parallelism, node.memory_level)
                for n, node in self.nodes.items()}
