"""Streaming operators.  Stateless operators never touch the state backend
(Justin strips their managed memory — Takeaway 1); stateful operators access
their per-task ``LSMStore`` with the read/write profile the paper's §3
microbenchmarks characterize:

* ``KeyedStateOp(mode="read")``   — pure lookups (Read workload)
* ``KeyedStateOp(mode="write")``  — blind writes (Write workload)
* ``KeyedStateOp(mode="update")`` — read-modify-write (Update workload)
* ``WindowAggOp`` / ``SessionWindowOp`` / ``JoinOp`` — the Nexmark patterns.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.state.lsm import LSMStore, LatencyModel, make_store
from repro.streaming.events import EventBatch, PAYLOAD_WORDS


class Operator:
    """Base: subclasses implement process(task_state, batch) -> out batch."""
    stateful = False
    cpu_cost_us = 1.0                   # per-event CPU service time component
    entry_bytes = 1000                  # logical state-entry size (§3: 1 KB)

    def __init__(self, name: str):
        self.name = name

    def make_state(self, memory_mb: float, seed: int = 0) -> LSMStore | None:
        if not self.stateful:
            return None
        # built through the store factory so benchmarks and the
        # differential harness can swap implementations engine-wide
        return make_store(memory_mb, value_words=PAYLOAD_WORDS,
                          entry_bytes=self.entry_bytes, seed=seed)

    def process(self, state: LSMStore | None, batch: EventBatch) -> EventBatch:
        raise NotImplementedError

    def warm_state(self, state: LSMStore, rng: np.random.Generator) -> None:
        """Optional pre-population (paper §3 pre-populates every key)."""


class SourceOp(Operator):
    """Workload injector: emits up to ``rate`` events/s, subject to
    backpressure (paper: sources are excluded from the resource count)."""
    cpu_cost_us = 0.2

    def __init__(self, name: str, generator: Callable[[int, float], EventBatch]):
        super().__init__(name)
        self.generator = generator

    def emit(self, n: int, now_s: float) -> EventBatch:
        return self.generator(n, now_s)

    def process(self, state, batch):
        return batch


class MapOp(Operator):
    def __init__(self, name: str, fn: Callable[[EventBatch], EventBatch],
                 cpu_cost_us: float = 1.2):
        super().__init__(name)
        self.fn = fn
        self.cpu_cost_us = cpu_cost_us

    def process(self, state, batch):
        return self.fn(batch)


class FilterOp(Operator):
    def __init__(self, name: str, pred: Callable[[EventBatch], np.ndarray],
                 cpu_cost_us: float = 0.8):
        super().__init__(name)
        self.pred = pred
        self.cpu_cost_us = cpu_cost_us

    def process(self, state, batch):
        return batch.select(self.pred(batch))


class FlatMapOp(Operator):
    def __init__(self, name: str, fn: Callable[[EventBatch], EventBatch],
                 cpu_cost_us: float = 1.5):
        super().__init__(name)
        self.fn = fn
        self.cpu_cost_us = cpu_cost_us

    def process(self, state, batch):
        return self.fn(batch)


class SinkOp(Operator):
    cpu_cost_us = 0.5

    def __init__(self, name: str = "sink"):
        super().__init__(name)
        self.received = 0

    def process(self, state, batch):
        self.received += len(batch)
        return EventBatch.empty()


@dataclass
class _StateProfile:
    keyspace: int = 1_000_000
    prepopulate: bool = True


class KeyedStateOp(Operator):
    """§3 microbenchmark operator: one state access per event."""
    stateful = True
    cpu_cost_us = 2.0

    def __init__(self, name: str, mode: str, keyspace: int = 1_000_000,
                 prepopulate: bool = True):
        super().__init__(name)
        assert mode in ("read", "write", "update")
        self.mode = mode
        self.keyspace = keyspace
        self.prepopulate = prepopulate

    def warm_state(self, state: LSMStore, rng: np.random.Generator) -> None:
        if not self.prepopulate:
            return
        keys = np.arange(self.keyspace, dtype=np.int64)
        vals = rng.integers(0, 2**31 - 1, (self.keyspace, PAYLOAD_WORDS),
                            dtype=np.int64).astype(np.int32)
        state.bulk_load(keys, vals)
        state.metrics.reset()

    def process(self, state: LSMStore, batch: EventBatch) -> EventBatch:
        if self.mode == "read":
            vals, _ = state.get_batch(batch.key)
            out = batch.value + vals[:, :batch.value.shape[1]]
            return EventBatch(batch.key, out.astype(np.int32), batch.ts,
                              batch.kind)
        if self.mode == "write":
            state.put_batch(batch.key, batch.value)
            return batch
        vals, _ = state.get_batch(batch.key)           # update = read + write
        new = (vals + batch.value).astype(np.int32)
        state.put_batch(batch.key, new)
        return EventBatch(batch.key, new, batch.ts, batch.kind)


class WindowAggOp(Operator):
    """Keyed tumbling/sliding window aggregation (count/sum).

    State key = (key, window_id); each event is a read-modify-write.  Sliding
    windows touch size/slide window ids per event — q5's 'complex access
    pattern'.  Window results are emitted when event time passes window end.
    """
    stateful = True
    cpu_cost_us = 2.5
    entry_bytes = 500                    # window aggregates are small records

    def __init__(self, name: str, size_s: float, slide_s: float | None = None,
                 emit: bool = True):
        super().__init__(name)
        self.size_s = size_s
        self.slide_s = slide_s or size_s
        self.emit = emit
        self._watermark = 0.0

    def _state_key(self, keys, window_id):
        return keys * np.int64(1 << 20) + (window_id % (1 << 20))

    def process(self, state: LSMStore, batch: EventBatch) -> EventBatch:
        if len(batch) == 0:
            return EventBatch.empty()
        # compaction filter: drop windows older than the retention horizon
        if len(batch):
            wm = int(batch.ts.max() // self.size_s)
            state.compact_filter = \
                lambda keys, w=wm: (keys % (1 << 20)) >= max(0, w - 4)
        n_windows = max(1, int(round(self.size_s / self.slide_s)))
        outs = []
        for w in range(n_windows):
            wid = ((batch.ts - w * self.slide_s) // self.size_s).astype(np.int64)
            sk = self._state_key(batch.key, wid)
            vals, _ = state.get_batch(sk)
            vals[:, 0] += 1                             # count
            vals[:, 1] = (vals[:, 1] + batch.value[:, 0]).astype(np.int32)
            state.put_batch(sk, vals)
            if w == 0:
                outs.append(EventBatch(batch.key, vals, batch.ts, batch.kind))
        self._watermark = max(self._watermark, float(batch.ts.max()))
        out = outs[0]
        if not self.emit:
            return EventBatch.empty()
        # emit current aggregates for closed-ish windows (downstream load)
        return out


class SessionWindowOp(Operator):
    """q11: per-user session tracking — update-heavy, working set = active
    users (the memory-pressured operator where Justin's scale-up wins)."""
    stateful = True
    cpu_cost_us = 3.0
    entry_bytes = 500                    # session records are small

    def __init__(self, name: str, gap_s: float = 10.0,
                 keyspace: int = 1_000_000):
        super().__init__(name)
        self.gap_s = gap_s
        self.keyspace = keyspace

    def warm_state(self, state: LSMStore, rng: np.random.Generator) -> None:
        state.bulk_load(np.arange(self.keyspace, dtype=np.int64),
                        np.zeros((self.keyspace, PAYLOAD_WORDS), np.int32))
        state.metrics.reset()

    def process(self, state: LSMStore, batch: EventBatch) -> EventBatch:
        if len(batch) == 0:
            return EventBatch.empty()
        vals, found = state.get_batch(batch.key)
        last_ts = vals[:, 0].astype(np.float64)
        expired = (batch.ts - last_ts) > self.gap_s
        emitted = batch.select(expired & found)          # closed sessions
        vals[:, 0] = np.minimum(batch.ts, 2**30).astype(np.int32)
        vals[:, 1] = np.where(expired, 1, vals[:, 1] + 1)  # bids in session
        state.put_batch(batch.key, vals)
        return emitted


class JoinOp(Operator):
    """Two-sided keyed join.  Events with kind==left_kind are stored and
    probe the right side (and vice versa).  ``windowed=True`` scopes state
    keys by tumbling window id (q8); otherwise the join is incremental and
    unbounded (q3)."""
    stateful = True
    cpu_cost_us = 3.0
    entry_bytes = 500                    # join-side records are small

    def __init__(self, name: str, left_kind: int, right_kind: int,
                 window_s: float | None = None, keyspace: int = 0):
        super().__init__(name)
        self.left_kind = left_kind
        self.right_kind = right_kind
        self.window_s = window_s
        self.keyspace = keyspace         # pre-populated steady-state size

    def warm_state(self, state, rng: np.random.Generator) -> None:
        """Steady-state pre-population: both sides of the live window(s) —
        the paper's queries run for minutes before each decision window."""
        if not self.keyspace:
            return
        wids = (0, 1) if self.window_s is not None else (None,)
        all_keys, all_vals = [], []
        for side in (0, 1):
            for wid in wids:
                keys = np.arange(self.keyspace, dtype=np.int64) * 4 + side
                if wid is not None:
                    keys = keys * np.int64(1 << 16) + wid
                vals = rng.integers(0, 2**31 - 1,
                                    (self.keyspace, PAYLOAD_WORDS),
                                    dtype=np.int64).astype(np.int32)
                all_keys.append(keys)
                all_vals.append(vals)
        state.bulk_load(np.concatenate(all_keys), np.concatenate(all_vals))
        state.metrics.reset()

    def _skey(self, keys, ts, side: int) -> np.ndarray:
        k = keys * np.int64(4) + side
        if self.window_s is not None:
            wid = (ts // self.window_s).astype(np.int64)
            k = k * np.int64(1 << 16) + (wid % (1 << 16))
        return k

    def process(self, state: LSMStore, batch: EventBatch) -> EventBatch:
        if len(batch) == 0:
            return EventBatch.empty()
        if self.window_s is not None:
            wm = int(batch.ts.max() // self.window_s)
            state.compact_filter = \
                lambda keys, w=wm: (keys % (1 << 16)) >= max(0, w - 2)
        left = batch.kind == self.left_kind
        right = batch.kind == self.right_kind
        out = []
        for mask, mine, other in ((left, 0, 1), (right, 1, 0)):
            if not mask.any():
                continue
            sub = batch.select(mask)
            d = state.put_batch(self._skey(sub.key, sub.ts, mine), sub.value)
            if d is not None:
                # probe keys are the put keys shifted by a constant (the
                # side bit is below the window bits), so the put batch's
                # delta decomposition doubles as the probe's sorted-unique
                # hint — one sort serves both Z-set operations
                shift = np.int64((other - mine)
                                 * ((1 << 16) if self.window_s is not None
                                    else 1))
                vals, found = state.get_batch(
                    self._skey(sub.key, sub.ts, other),
                    uhint=(d[0] + shift, d[1]))
            else:
                vals, found = state.get_batch(
                    self._skey(sub.key, sub.ts, other))
            if found.any():
                joined = sub.select(found)
                out.append(EventBatch(joined.key, vals[found], joined.ts,
                                      joined.kind))
        return EventBatch.concat(out) if out else EventBatch.empty()
