"""Pure-jnp oracle for flash attention (full-materialization softmax)."""
from __future__ import annotations

import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None):
    """q/k/v: [B, H, S, D] (same head count — caller repeats KV for GQA)."""
    b, h, s, d = q.shape
    skv = k.shape[2]
    scale = d ** -0.5
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None] + (skv - s)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((s, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jnp.exp(scores - jnp.max(scores, -1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
